"""Training driver.

Real execution runs the REDUCED variant of any assigned arch on the local
device(s); the FULL configs are exercised via the dry-run (lowering only).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --steps 100
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, data_iterator
from repro.models import build_model
from repro.train.checkpoint import save_checkpoint
from repro.train.loop import train_loop
from repro.train.optimizer import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    if cfg.family in ("vlm",):
        raise SystemExit("use the dry-run for VLM training shapes (stub frontend)")
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch, seed=args.seed)
    it = data_iterator(dc)
    if cfg.family == "audio":
        base = it

        def with_feats(gen):
            rng = jax.random.PRNGKey(args.seed)
            for b in gen:
                feats = jax.random.normal(
                    rng, (args.batch, cfg.encdec.encoder_seq, cfg.d_model))
                yield dict(b, encoder_feats=feats)

        it = with_feats(base)

    opt = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                      total_steps=args.steps)

    def log(i, m):
        print(f"step {m['step']:5d} loss {m['loss']:.4f} gnorm {m['grad_norm']:.3f} "
              f"lr {m['lr']:.2e} wall {m['wall_s']:.1f}s")

    state, history = train_loop(model, it, steps=args.steps, opt_cfg=opt,
                                rng=jax.random.PRNGKey(args.seed), callback=log)
    assert history[-1]["loss"] < history[0]["loss"], "training failed to reduce loss"
    if args.checkpoint:
        save_checkpoint(args.checkpoint, state, step=args.steps)
        print(f"saved checkpoint to {args.checkpoint}")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()
