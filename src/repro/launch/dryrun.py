import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

__doc__ = """Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) and for both production meshes
(single-pod 16x16 = 256 chips; multi-pod 2x16x16 = 512 chips), lower and
compile the appropriate step function (train_step / prefill / serve_step)
with ShapeDtypeStruct inputs — no allocation — and record
``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs/bytes for the
roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import cost_analysis_dict
from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, get_shape
from repro.models import build_model
from repro.models.transformer import Model
from repro.sharding.rules import (
    PerfOptions,
    ShardingRules,
    batch_specs,
    cache_specs,
    infer_param_specs,
    make_activation_constrainer,
)
from repro.train.loop import make_train_step
from repro.train.optimizer import AdamWConfig, init_opt_state
from .input_specs import input_specs, skip_reason
from .mesh import dp_axes, make_production_mesh

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\b")


@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh: str
    status: str                      # ok | skipped | failed
    reason: str = ""
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_counts: dict | None = None
    memory_analysis: str = ""
    peak_bytes_per_device: float | None = None
    argument_bytes_per_device: float | None = None
    compile_seconds: float = 0.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_step(arch_id: str, shape_id: str, mesh, *, cfg=None, unroll: bool = False,
               perf: PerfOptions | None = None):
    """Returns (fn, abstract_args, in_shardings, out_shardings) or a skip reason.

    ``cfg`` overrides the registered config (the roofline costing pass lowers
    depth-reduced variants); ``unroll`` replaces the layer scan with a python
    unroll so XLA cost analysis counts every layer.
    """
    cfg = cfg or get_config(arch_id)
    shape = get_shape(shape_id)
    reason = skip_reason(cfg, shape)
    if reason:
        return None, reason
    model = build_model(cfg)
    perf = perf or PerfOptions()
    rules = ShardingRules(mesh=mesh, dp=dp_axes(mesh))
    ac = make_activation_constrainer(cfg, shape, rules, perf)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_specs = infer_param_specs(params_shape, cfg, rules)
    param_sh = _named(mesh, param_specs)

    specs = input_specs(cfg, shape, model)
    batch_sp = batch_specs(specs["batch"], cfg, shape, rules)
    batch_sh = _named(mesh, batch_sp)

    if shape.mode == "train":
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        opt_specs = {"m": param_specs, "v": param_specs, "step": P()}
        state_shape = {"params": params_shape, "opt": opt_shape}
        state_sh = {"params": param_sh, "opt": _named(mesh, opt_specs)}
        fn = make_train_step(model, AdamWConfig(), ac, unroll=unroll,
                             cast_params=perf.cast_params_bf16)
        metrics_sh = {"grad_norm": NamedSharding(mesh, P()),
                      "lr": NamedSharding(mesh, P()),
                      "loss": NamedSharding(mesh, P())}
        return (fn, (state_shape, specs["batch"]), (state_sh, batch_sh),
                (state_sh, metrics_sh)), None

    if shape.mode == "prefill":
        def fn(params, batch):
            logits, aux, caches = model.forward(params, batch, ac=ac,
                                                want_cache=True, remat=False,
                                                unroll=unroll)
            return logits, caches

        return (fn, (params_shape, specs["batch"]), (param_sh, batch_sh), None), None

    # decode (serve_step): ONE new token against the full-capacity cache.
    caches_shape = specs["caches"]
    cache_sp = cache_specs(caches_shape, cfg, shape, rules)
    cache_sh = _named(mesh, cache_sp)

    def fn(params, batch, caches):
        return model.decode_step(params, batch, caches, ac=ac, unroll=unroll)

    out_sh = (None, cache_sh)   # logits: let GSPMD choose; caches stay put
    return (fn, (params_shape, specs["batch"], caches_shape),
            (param_sh, batch_sh, cache_sh), out_sh), None


def run_one(arch_id: str, shape_id: str, *, multi_pod: bool = False,
            verbose: bool = True) -> DryrunResult:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    t0 = time.perf_counter()
    try:
        built, reason = build_step(arch_id, shape_id, mesh)
        if built is None:
            return DryrunResult(arch_id, shape_id, mesh_name, "skipped", reason=reason)
        fn, args, in_sh, out_sh = built
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        dt = time.perf_counter() - t0
        ca = cost_analysis_dict(compiled)
        mem = compiled.memory_analysis()
        counts: dict[str, int] = {}
        try:
            text = compiled.as_text()
            for m in COLLECTIVE_RE.finditer(text):
                counts[m.group(1)] = counts.get(m.group(1), 0) + 1
        except Exception:
            counts = {}
        peak = getattr(mem, "temp_size_in_bytes", None)
        argbytes = getattr(mem, "argument_size_in_bytes", None)
        res = DryrunResult(
            arch_id, shape_id, mesh_name, "ok",
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0)),
            collective_counts=counts,
            memory_analysis=str(mem),
            peak_bytes_per_device=float(peak) if peak is not None else None,
            argument_bytes_per_device=float(argbytes) if argbytes is not None else None,
            compile_seconds=dt,
        )
        if verbose:
            print(f"[ok] {arch_id} x {shape_id} x {mesh_name}: "
                  f"flops={res.flops:.3e} bytes={res.bytes_accessed:.3e} "
                  f"collectives={counts} compile={dt:.1f}s")
            print(f"     memory_analysis: {mem}")
        return res
    except Exception as e:
        if verbose:
            traceback.print_exc()
        return DryrunResult(arch_id, shape_id, mesh_name, "failed",
                            reason=f"{type(e).__name__}: {e}",
                            compile_seconds=time.perf_counter() - t0)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    pairs = ([(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
             if args.all else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for mp in meshes:
        for a, s in pairs:
            if a is None or s is None:
                raise SystemExit("need --arch and --shape (or --all)")
            results.append(run_one(a, s, multi_pod=mp))
    n_fail = sum(r.status == "failed" for r in results)
    n_skip = sum(r.status == "skipped" for r in results)
    print(f"\n== dry-run summary: {len(results)} runs, {n_fail} failed, {n_skip} skipped ==")
    for r in results:
        if r.status != "ok":
            print(f"  [{r.status}] {r.arch} x {r.shape} x {r.mesh}: {r.reason}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump([r.to_json() for r in results], f, indent=1)
        print(f"wrote {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
