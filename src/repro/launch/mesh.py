"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Tiny mesh over the actually-available devices (tests/examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...] | str:
    return ("pod", "data") if "pod" in mesh.axis_names else "data"
