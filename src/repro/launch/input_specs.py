"""Abstract input construction for the dry-run: ShapeDtypeStruct stand-ins
for every model input (weak-type-correct, shardable, no device allocation).

* train / prefill: token batch (plus stubbed frontend embeddings for the
  VLM / audio carve-out archs).
* decode: ONE new token per sequence + the full KV cache / SSM state at
  ``seq_len`` capacity.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.transformer import Model


def skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> str | None:
    """Documented (arch, shape) skips — DESIGN.md §6."""
    if shape.name == "long_500k" and shape.mode == "decode":
        if not cfg.supports_long_decode:
            return ("full-attention KV at 524288 tokens is quadratic-cost to fill and "
                    "O(ctx) per step; arch has no sliding-window/SSM path")
    return None


def input_specs(cfg: ArchConfig, shape: ShapeConfig, model: Model | None = None) -> dict[str, Any]:
    """Returns {'batch': pytree of SDS, 'caches': pytree|None}."""
    B, S = shape.global_batch, shape.seq_len
    cd = jnp.dtype(cfg.compute_dtype)

    if shape.mode in ("train", "prefill"):
        batch: dict[str, Any] = {}
        if cfg.family == "vlm":
            # stub ViT frontend: precomputed patch+text embeddings
            batch["embeds"] = SDS((B, S, cfg.d_model), cd)
            batch["labels"] = SDS((B, S), jnp.int32)
            batch["positions"] = SDS((3, B, S), jnp.int32)   # M-RoPE t/h/w
        else:
            batch["tokens"] = SDS((B, S), jnp.int32)
        if cfg.family == "audio":
            # stub mel+conv frontend: precomputed frame embeddings
            batch["encoder_feats"] = SDS((B, cfg.encdec.encoder_seq, cfg.d_model), cd)
        return {"batch": batch, "caches": None}

    # decode: one token, cache at seq_len capacity
    batch = {"tokens": SDS((B, 1), jnp.int32), "pos": SDS((), jnp.int32)}
    caches = None
    if model is not None:
        caches = jax.eval_shape(lambda: model.init_caches(B, S))
    return {"batch": batch, "caches": caches}
