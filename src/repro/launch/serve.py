"""Serving driver: batched requests with host-memory context caching,
comparing KV-fetch backends (the paper's §5.3 workload).

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --batch 4 --ctx 128
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    try:
        eng = ServeEngine(model, params)
    except ValueError as e:
        raise SystemExit(
            f"{args.arch} is not servable by this engine ({e}); "
            "use a decoder-LM arch with uniform layers, e.g. deepseek-7b, "
            "qwen2-0.5b, mixtral-8x7b, olmoe-1b-7b")
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.ctx)).astype(np.int32)
    keys = [f"req-{i}" for i in range(args.batch)]

    print(f"== {cfg.name}: {args.batch} requests x {args.ctx} ctx, {args.new} new tokens ==")
    res_miss = eng.generate(prompts, keys, args.new)
    print(f"[miss/prefill] ttft_wall={res_miss.request_stats[0].ttft_wall_s*1e3:.1f}ms "
          f"tok/s={res_miss.tokens_per_s_wall:.1f}")
    for backend in ("pcpy", "b2b", "opt_b2b", "kernel"):
        res = eng.generate(prompts, keys, args.new, fetch_backend=backend)
        st = res.request_stats[0]
        same = (res.tokens == res_miss.tokens).all()
        print(f"[hit/{backend:6s}] ttft_wall={st.ttft_wall_s*1e3:.1f}ms "
              f"fetch_modeled={st.fetch_modeled_s*1e6:.1f}us transfers={st.n_transfers} "
              f"tok/s={res.tokens_per_s_wall:.1f} tokens_match={same}")
        assert same, f"{backend} produced different tokens"


if __name__ == "__main__":
    main()
