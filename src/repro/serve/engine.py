"""Batched LLM serving engine with host-memory context caching.

Serving flow (mirrors the paper's vLLM + KV-offload setup, §5.3):

1. A request arrives with a context key.  On a HOST CACHE MISS the engine
   runs prefill on device, emits the first token, and SAVES the paged KV to
   the host store.  On a HIT it FETCHES the KV blocks back, rebuilds the
   device cache, and emits the first token with a single decode step — no
   prefill compute.  The fetch backend defaults to the CommBackend's
   ``kv_fetch_plan`` (latte: the optimized ``opt_b2b`` command stream,
   DESIGN.md §7/§8; reference: per-block ``pcpy``); an explicit
   ``fetch_backend`` string overrides the plan.
2. Decode proceeds in batched steps over all active sequences.

TTFT therefore = fetch(+rebuild) time on hits vs prefill time on misses —
exactly the quantity Figures 16/17 study.  Wall-clock numbers on this CPU
container are functional only; the calibrated DMA model supplies the
transfer-side latencies for the paper-scale benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.backend import CommBackend
from repro.models import attention as attn_mod
from repro.models.transformer import Model
from .host_store import HostKVStore
from .kvcache import BLOCK_TOKENS, blocks_to_kv, kv_to_blocks


@dataclasses.dataclass
class RequestStats:
    key: str
    cache_hit: bool
    ttft_wall_s: float
    fetch_modeled_s: float      # 0 on miss
    n_transfers: int
    prompt_tokens: int


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, n_new]
    request_stats: list[RequestStats]
    decode_wall_s: float
    tokens_per_s_wall: float


class ServeEngine:
    def __init__(self, model: Model, params, *, host_store: HostKVStore | None = None,
                 comm: CommBackend | None = None, block_tokens: int = BLOCK_TOKENS):
        cfg = model.cfg
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(f"serving engine supports decoder-LM families, got {cfg.family}")
        if model.scan_info.get("per_unit", 1) != 1:
            raise ValueError("serving engine requires per_unit==1 layer stacking")
        self.model = model
        self.params = params
        self.store = host_store or HostKVStore(block_tokens)
        self.comm = comm or CommBackend("latte")
        self.block_tokens = block_tokens
        self._prefill_jit = jax.jit(
            lambda p, b: model.forward(p, b, want_cache=True, remat=False))
        self._decode_jit = jax.jit(model.decode_step)

    # ----------------------------------------------------------- helpers ----
    def _prefill(self, prompts: jax.Array):
        logits, _, kvs = self._prefill_jit(self.params, {"tokens": prompts})
        (k, v), = kvs      # per_unit == 1
        return logits, np.asarray(k), np.asarray(v)   # [L, B, S, KV, hd]

    def _build_cache(self, k: np.ndarray, v: np.ndarray, capacity: int):
        """k/v [L, B, S, KV, hd] -> stacked decode cache at ``capacity``."""
        L, B, S, KV, hd = k.shape
        cfg = self.model.cfg

        def one_layer(kl, vl):
            return attn_mod.prefill_cache(cfg, jnp.asarray(kl), jnp.asarray(vl), capacity)

        layers = [one_layer(k[i], v[i]) for i in range(L)]
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *layers)
        return (stacked,)   # per_unit tuple

    def _planned_backend(self, keys: Sequence[str]) -> str:
        """Fetch backend from the CommBackend's plan for these contexts
        (latte requests the optimized command stream -> ``opt_b2b``)."""
        n_blocks, block_bytes = self.store.blocks_for(keys[0])
        plan = self.comm.kv_fetch_plan(n_blocks * len(keys), block_bytes)
        mode = plan["mode"]
        return f"opt_{mode}" if plan.get("optimized") else mode

    # ------------------------------------------------------------ public ----
    def first_token(self, prompts: np.ndarray, keys: Sequence[str],
                    *, fetch_backend: str | None = None,
                    capacity: int | None = None):
        """TTFT path for a batch sharing prompt length.  Returns
        (first_tokens [B], cache, stats).  ``fetch_backend=None`` follows
        the CommBackend's ``kv_fetch_plan``."""
        B, S = prompts.shape
        capacity = capacity or S + 64
        all_hit = all(k in self.store for k in keys)
        t0 = time.perf_counter()
        stats = []
        if all_hit:
            if fetch_backend is None:
                fetch_backend = self._planned_backend(keys)
            ks, vs, modeled_total, n_tr = [], [], 0.0, 0
            for key in keys:
                res = self.store.fetch(key, fetch_backend)
                kk, vv = blocks_to_kv(res.k_blocks, res.v_blocks, self.store.tokens_for(key))
                ks.append(kk)
                vs.append(vv)
                modeled_total += res.modeled_seconds
                n_tr += res.n_transfers
            k = np.concatenate(ks, axis=1)   # [L, B, S, KV, hd]
            v = np.concatenate(vs, axis=1)
            cache = self._build_cache(k, v, capacity)
            logits, cache = self._decode_jit(
                self.params,
                {"tokens": jnp.asarray(prompts[:, -1:]), "pos": jnp.int32(S - 1)},
                cache)
            first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            wall = time.perf_counter() - t0
            for key in keys:
                stats.append(RequestStats(key, True, wall / B, modeled_total / B,
                                          n_tr, S))
        else:
            logits, k, v = self._prefill(jnp.asarray(prompts))
            first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            wall = time.perf_counter() - t0
            for b, key in enumerate(keys):
                kb, vb = kv_to_blocks(k[:, b:b + 1], v[:, b:b + 1], self.block_tokens)
                self.store.save(key, kb, vb, S)
                stats.append(RequestStats(key, False, wall / B, 0.0, 0, S))
            cache = self._build_cache(k, v, capacity)
        return first, cache, stats

    def generate(self, prompts: np.ndarray, keys: Sequence[str], n_new: int,
                 *, fetch_backend: str | None = None) -> GenerationResult:
        B, S = prompts.shape
        capacity = S + n_new + 1
        first, cache, stats = self.first_token(prompts, keys,
                                               fetch_backend=fetch_backend,
                                               capacity=capacity)
        toks = [first]
        cur = jnp.asarray(first)[:, None]
        t0 = time.perf_counter()
        for i in range(n_new - 1):
            logits, cache = self._decode_jit(
                self.params, {"tokens": cur, "pos": jnp.int32(S + i)}, cache)
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            toks.append(np.asarray(cur)[:, 0])
        dt = time.perf_counter() - t0
        tokens = np.stack(toks, axis=1)
        return GenerationResult(tokens, stats, dt, B * (n_new - 1) / max(dt, 1e-9))
