"""Batched LLM serving engine with host-memory context caching.

Serving flow (mirrors the paper's vLLM + KV-offload setup, §5.3):

1. A request arrives with a context key.  On a HOST CACHE MISS the engine
   runs prefill on device, emits the first token, and SAVES the paged KV to
   the host store.  On a HIT it FETCHES the KV blocks back, rebuilds the
   device cache, and emits the first token with a single decode step — no
   prefill compute.  The fetch backend defaults to the CommBackend's
   ``kv_fetch_plan`` (latte: the optimized ``opt_b2b`` command stream,
   DESIGN.md §7/§8; reference: per-block ``pcpy``); an explicit
   ``fetch_backend`` string overrides the plan.
2. Decode proceeds in batched steps over all active sequences.

TTFT therefore = fetch(+rebuild) time on hits vs prefill time on misses —
exactly the quantity Figures 16/17 study.  Wall-clock numbers on this CPU
container are functional only; the calibrated DMA model supplies the
transfer-side latencies for the paper-scale benchmarks.

Concurrent-traffic serving (DESIGN.md §12): :class:`ServingSimulator` is
the *modeled* counterpart for load studies — a continuous-batching loop
that maps each in-flight request's KV fetch, the batch's per-layer
all-gathers, and MoE all-to-alls onto schedules composed in ONE resource
world (``run_composed``), with a contention-aware admission policy.  At
load -> 0 it reproduces the single-request Fig. 16/17 numbers exactly
(the K=1 composition is bit-identical to ``simulate``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.backend import CommBackend
from repro.models import attention as attn_mod
from repro.models.transformer import Model
from .host_store import HostKVStore
from .kvcache import BLOCK_TOKENS, blocks_to_kv, kv_to_blocks


@dataclasses.dataclass
class RequestStats:
    key: str
    cache_hit: bool
    ttft_wall_s: float
    fetch_modeled_s: float      # 0 on miss
    n_transfers: int
    prompt_tokens: int


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, n_new]
    request_stats: list[RequestStats]
    decode_wall_s: float
    tokens_per_s_wall: float


class ServeEngine:
    def __init__(self, model: Model, params, *, host_store: HostKVStore | None = None,
                 comm: CommBackend | None = None, block_tokens: int = BLOCK_TOKENS):
        cfg = model.cfg
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(f"serving engine supports decoder-LM families, got {cfg.family}")
        if model.scan_info.get("per_unit", 1) != 1:
            raise ValueError("serving engine requires per_unit==1 layer stacking")
        self.model = model
        self.params = params
        self.store = host_store or HostKVStore(block_tokens)
        self.comm = comm or CommBackend("latte")
        self.block_tokens = block_tokens
        self._prefill_jit = jax.jit(
            lambda p, b: model.forward(p, b, want_cache=True, remat=False))
        self._decode_jit = jax.jit(model.decode_step)

    # ----------------------------------------------------------- helpers ----
    def _prefill(self, prompts: jax.Array):
        logits, _, kvs = self._prefill_jit(self.params, {"tokens": prompts})
        (k, v), = kvs      # per_unit == 1
        return logits, np.asarray(k), np.asarray(v)   # [L, B, S, KV, hd]

    def _build_cache(self, k: np.ndarray, v: np.ndarray, capacity: int):
        """k/v [L, B, S, KV, hd] -> stacked decode cache at ``capacity``."""
        L, B, S, KV, hd = k.shape
        cfg = self.model.cfg

        def one_layer(kl, vl):
            return attn_mod.prefill_cache(cfg, jnp.asarray(kl), jnp.asarray(vl), capacity)

        layers = [one_layer(k[i], v[i]) for i in range(L)]
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *layers)
        return (stacked,)   # per_unit tuple

    def _planned_backend(self, keys: Sequence[str]) -> str:
        """Fetch backend from the CommBackend's plan for these contexts
        (latte requests the optimized command stream -> ``opt_b2b``)."""
        n_blocks, block_bytes = self.store.blocks_for(keys[0])
        plan = self.comm.kv_fetch_plan(n_blocks * len(keys), block_bytes)
        mode = plan["mode"]
        return f"opt_{mode}" if plan.get("optimized") else mode

    # ------------------------------------------------------------ public ----
    def first_token(self, prompts: np.ndarray, keys: Sequence[str],
                    *, fetch_backend: str | None = None,
                    capacity: int | None = None):
        """TTFT path for a batch sharing prompt length.  Returns
        (first_tokens [B], cache, stats).  ``fetch_backend=None`` follows
        the CommBackend's ``kv_fetch_plan``."""
        B, S = prompts.shape
        capacity = capacity or S + 64
        all_hit = all(k in self.store for k in keys)
        t0 = time.perf_counter()
        stats = []
        if all_hit:
            if fetch_backend is None:
                fetch_backend = self._planned_backend(keys)
            ks, vs, modeled_total, n_tr = [], [], 0.0, 0
            for key in keys:
                res = self.store.fetch(key, fetch_backend)
                kk, vv = blocks_to_kv(res.k_blocks, res.v_blocks, self.store.tokens_for(key))
                ks.append(kk)
                vs.append(vv)
                modeled_total += res.modeled_seconds
                n_tr += res.n_transfers
            k = np.concatenate(ks, axis=1)   # [L, B, S, KV, hd]
            v = np.concatenate(vs, axis=1)
            cache = self._build_cache(k, v, capacity)
            logits, cache = self._decode_jit(
                self.params,
                {"tokens": jnp.asarray(prompts[:, -1:]), "pos": jnp.int32(S - 1)},
                cache)
            first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            wall = time.perf_counter() - t0
            for key in keys:
                stats.append(RequestStats(key, True, wall / B, modeled_total / B,
                                          n_tr, S))
        else:
            logits, k, v = self._prefill(jnp.asarray(prompts))
            first = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            wall = time.perf_counter() - t0
            for b, key in enumerate(keys):
                kb, vb = kv_to_blocks(k[:, b:b + 1], v[:, b:b + 1], self.block_tokens)
                self.store.save(key, kb, vb, S)
                stats.append(RequestStats(key, False, wall / B, 0.0, 0, S))
            cache = self._build_cache(k, v, capacity)
        return first, cache, stats

    def generate(self, prompts: np.ndarray, keys: Sequence[str], n_new: int,
                 *, fetch_backend: str | None = None) -> GenerationResult:
        B, S = prompts.shape
        capacity = S + n_new + 1
        first, cache, stats = self.first_token(prompts, keys,
                                               fetch_backend=fetch_backend,
                                               capacity=capacity)
        toks = [first]
        cur = jnp.asarray(first)[:, None]
        t0 = time.perf_counter()
        for i in range(n_new - 1):
            logits, cache = self._decode_jit(
                self.params, {"tokens": cur, "pos": jnp.int32(S + i)}, cache)
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            toks.append(np.asarray(cur)[:, 0])
        dt = time.perf_counter() - t0
        tokens = np.stack(toks, axis=1)
        return GenerationResult(tokens, stats, dt, B * (n_new - 1) / max(dt, 1e-9))


# ===================================================================== #
# Modeled continuous-batching serving under concurrent traffic (§12)    #
# ===================================================================== #

from repro.core.dma import (allgather_schedule, alltoall_schedule,  # noqa: E402
                            kv_fetch_schedule, mi300x_platform,
                            paper_dispatch, run_composed, simulate)
from repro.core.serving_model import (BATCH_API_COST, BLOCK_TOKENS,  # noqa: E402
                                      FRAMEWORK_OVERHEAD, N_BATCH_CALLS,
                                      PAPER_LLMS, LLMSpec, decode_step_time)
from .workload import Request  # noqa: E402


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Knobs of the modeled continuous-batching loop.

    ``admission`` picks the launch policy: ``"fifo"`` admits every waiting
    request up to the free batch slots; ``"defer"`` additionally defers a
    request whose target host link (its home device's PCIe queue) already
    has ``fetch_depth_limit`` fetches in flight — the §12 contention-aware
    policy that protects the decode batch's engines from fetch storms.

    ``ag_bytes_per_token`` is the per-layer tensor-parallel all-gather
    payload one active request contributes per decode step (hidden-dim
    activations, bf16); ``moe_bytes_per_token`` the per-layer all-to-all
    payload of a MoE request.  A decode round aggregates the whole batch's
    per-layer collectives into one schedule of the round's total bytes,
    dispatched via the paper's tables at that size (the layers stream
    back-to-back on the same ring, so the aggregate keeps the contention
    surface while bounding schedule count).

    ``slo_scale`` sets SLOs as multiples of the unloaded numbers: a request
    meets SLO when TTFT <= slo_scale x its isolated TTFT and TPOT <=
    slo_scale x the compute-bound full-batch decode step.  Goodput counts
    only SLO-meeting requests' tokens.
    """

    spec: LLMSpec = PAPER_LLMS[2]         # qwen2.5-7b
    max_batch: int = 16
    admission: str = "fifo"               # "fifo" | "defer"
    fetch_depth_limit: int = 1
    ag_bytes_per_token: int = 7168        # hidden 3584 x bf16
    moe_bytes_per_token: int = 28672
    slo_scale: float = 4.0


@dataclasses.dataclass(frozen=True)
class RequestTiming:
    """Per-request outcome of a :class:`ServingSimulator` run (seconds)."""

    rid: int
    arrival: float
    ttft: float                 # first token latency, arrival -> token
    tpot: float                 # mean inter-token time after the first
    completion: float           # absolute time the last token was emitted
    output_tokens: int
    slo_ttft: float
    slo_tpot: float

    @property
    def meets_slo(self) -> bool:
        return self.ttft <= self.slo_ttft and self.tpot <= self.slo_tpot


@dataclasses.dataclass(frozen=True)
class ServingReport:
    """Aggregate of one workload run: tail latencies and goodput."""

    timings: tuple[RequestTiming, ...]
    makespan: float
    rounds: int
    deferred: int               # admission decisions that pushed a launch back

    def _pct(self, values, q: float) -> float:
        return float(np.percentile(np.asarray(values, dtype=float), q))

    @property
    def ttft_p50(self) -> float:
        return self._pct([t.ttft for t in self.timings], 50)

    @property
    def ttft_p99(self) -> float:
        return self._pct([t.ttft for t in self.timings], 99)

    @property
    def tpot_p50(self) -> float:
        return self._pct([t.tpot for t in self.timings if t.output_tokens > 1], 50)

    @property
    def tpot_p99(self) -> float:
        return self._pct([t.tpot for t in self.timings if t.output_tokens > 1], 99)

    @property
    def throughput(self) -> float:
        """Output tokens per second, SLO-blind."""
        total = sum(t.output_tokens for t in self.timings)
        return total / self.makespan if self.makespan > 0 else 0.0

    @property
    def goodput(self) -> float:
        """Output tokens per second from requests that met both SLOs."""
        good = sum(t.output_tokens for t in self.timings if t.meets_slo)
        return good / self.makespan if self.makespan > 0 else 0.0


class _Fetch:
    """An in-flight KV fetch: the blocks its remainder schedule still owes."""

    __slots__ = ("req", "remaining")

    def __init__(self, req: Request, n_blocks: int) -> None:
        self.req = req
        self.remaining = n_blocks


class _Active:
    __slots__ = ("req", "remaining", "first_token", "ttft", "slo_ttft")

    def __init__(self, req: Request, first_token: float, ttft: float,
                 slo_ttft: float) -> None:
        self.req = req
        self.remaining = req.output_tokens - 1
        self.first_token = first_token
        self.ttft = ttft
        self.slo_ttft = slo_ttft


class ServingSimulator:
    """Round-based continuous batching over the composed DMA simulator.

    Each scheduling round composes, in ONE resource world released at the
    round's start time (DESIGN.md §12):

      * one KV-fetch schedule per newly admitted request, released at the
        request's arrival offset, targeting its home device's host link
        (the dispatch plan's ``opt_prelaunch_b2b`` stream for latte);
      * the decode batch's aggregated per-layer all-gather (plus the MoE
        requests' all-to-all), released at 0 — variants picked from the
        paper's dispatch tables at the round's byte sizes.

    A fetch that outlives its round is *carried over*: the next round
    re-presents it to the composed world as a remainder schedule holding its
    unserved KV blocks (fluid progress, block-granular), so cross-round link
    and engine contention is never lost — a storm of in-flight fetches keeps
    slowing the decode stream and each other until it drains.  The round
    advances wall time by max(modeled comm makespan of the decode stream,
    the batch's compute-bound decode step) — or, with no active batch, to
    the first fetch completion; every active request emits one token per
    round (TPOT is round-granular, like real continuous batching).  A
    request's first token rides its fetch completion plus one decode step —
    at load -> 0 this is exactly the Fig. 16 single-request TTFT, because
    K=1 composition is bit-identical to ``simulate``.

    Degraded-mode serving (DESIGN.md §13.4): ``faults`` threads a
    :class:`~repro.core.dma.faults.FaultPlan` through every composed round.
    Fault windows are expressed in workload-absolute time — each round
    passes ``faults.shifted(now)`` to the composed run so a window means
    the same wall-clock interval in every round.  The ``defer`` admission
    policy additionally consults the plan's live fault state: a request
    whose home device sits in an outage window that will *clear*
    (``FaultPlan.waitable_degraded`` — NIC flap, finite derate window) is
    deferred past the outage instead of fetching at degraded rate.
    Permanent degradation (stragglers) never defers — the KV home is
    pinned, so waiting cannot find healthier hardware and would only starve
    the request.  A starvation guard admits the queue head anyway when
    nothing at all is in flight.  SLO baselines (``unloaded_ttft``) stay
    fault-free: SLOs measure against healthy hardware, so fault runs show
    up as violations, not as a lowered bar.
    """

    def __init__(self, config: ServingConfig | None = None, *,
                 topo=None, comm: CommBackend | None = None,
                 faults=None):
        self.cfg = config or ServingConfig()
        if self.cfg.admission not in ("fifo", "defer"):
            raise ValueError(f"unknown admission policy {self.cfg.admission!r}")
        self.topo = topo or mi300x_platform()
        self.comm = comm or CommBackend("latte")
        # Empty plans normalize away (same contract as simulate(), §13.1).
        self.faults = None if faults is None or faults.is_empty() else faults
        self._fetch_cache: dict = {}
        self._decode_cache: dict = {}
        self._iso_cache: dict = {}
        self.last_recorded = None   # ComposedResult of the record_round round

    # ------------------------------------------------------- schedules ----
    def _home_device(self, req: Request) -> int:
        # Context placement: the device whose host link serves this request's
        # KV blocks.  A paged KV store places contexts by key hash, so
        # collisions are real — a multiplicative hash (not round-robin)
        # reproduces the skew that makes admission policy matter.
        return ((req.rid * 0x9E3779B1) >> 7) % self.topo.n_devices

    def _fetch_shape(self, req: Request) -> tuple[int, int]:
        n_blocks = (req.prompt_tokens + BLOCK_TOKENS - 1) // BLOCK_TOKENS
        block_bytes = self.cfg.spec.kv_bytes_per_token * BLOCK_TOKENS
        return n_blocks, block_bytes

    def _fetch_variant(self, n_blocks: int, block_bytes: int) -> str:
        plan = self.comm.kv_fetch_plan(n_blocks, block_bytes)
        mode = f"prelaunch_{plan['mode']}" if plan["mode"] == "b2b" else plan["mode"]
        return f"opt_{mode}" if plan.get("optimized") else mode

    def _fetch_schedule(self, req: Request):
        n_blocks, block_bytes = self._fetch_shape(req)
        dev = self._home_device(req)
        key = (n_blocks, block_bytes, dev)
        sched = self._fetch_cache.get(key)
        if sched is None:
            variant = self._fetch_variant(n_blocks, block_bytes)
            sched = kv_fetch_schedule(self.topo, n_blocks, block_bytes,
                                      variant, device=dev)
            self._fetch_cache[key] = sched
        return sched

    def _remainder_schedule(self, f: _Fetch):
        """Schedule for a carried-over fetch's unserved blocks."""
        _, block_bytes = self._fetch_shape(f.req)
        dev = self._home_device(f.req)
        key = (f.remaining, block_bytes, dev)
        sched = self._fetch_cache.get(key)
        if sched is None:
            variant = self._fetch_variant(f.remaining, block_bytes)
            sched = kv_fetch_schedule(self.topo, f.remaining, block_bytes,
                                      variant, device=dev)
            self._fetch_cache[key] = sched
        return sched

    def isolated_fetch_seconds(self, req: Request) -> float:
        """Modeled seconds of this request's KV fetch with the PCIe link,
        engines and host to itself — the Fig. 16 fetch component plus the
        batch-API call cost (``serving_model.fetch_time`` equivalent)."""
        n_blocks, block_bytes = self._fetch_shape(req)
        key = (n_blocks, block_bytes, self._home_device(req))
        lat = self._iso_cache.get(key)
        if lat is None:
            lat = simulate(self._fetch_schedule(req), self.topo).latency
            self._iso_cache[key] = lat
        return lat + N_BATCH_CALLS * BATCH_API_COST

    def unloaded_ttft(self, req: Request) -> float:
        """Single-request TTFT (= ``serving_model.ttft(...)["total"]``)."""
        return (self.isolated_fetch_seconds(req)
                + decode_step_time(self.cfg.spec)
                + FRAMEWORK_OVERHEAD)

    def _decode_schedules(self, batch: int, n_moe: int) -> list:
        """The round's decode-comm streams: aggregated per-layer AG (+ AA)."""
        key = (batch, n_moe)
        scheds = self._decode_cache.get(key)
        if scheds is None:
            cfg = self.cfg
            scheds = []
            ag_bytes = cfg.spec.n_layers * batch * cfg.ag_bytes_per_token
            scheds.append(allgather_schedule(
                self.topo, ag_bytes, paper_dispatch("all_gather", ag_bytes)))
            if n_moe:
                aa_bytes = cfg.spec.n_layers * n_moe * cfg.moe_bytes_per_token
                scheds.append(alltoall_schedule(
                    self.topo, aa_bytes, paper_dispatch("all_to_all", aa_bytes)))
            self._decode_cache[key] = scheds
        return scheds

    # -------------------------------------------------------- admission ----
    def _admit(self, waiting: list, slots: int, depth: dict,
               degraded: frozenset = frozenset(),
               starving: bool = False) -> tuple[list, list, int]:
        """Pick this round's launches; returns (admitted, still_waiting,
        n_deferred).  ``depth`` counts in-flight fetches per home device;
        ``degraded`` names devices with live fault state (DESIGN.md §13.4)
        — under ``defer`` a request homed there is pushed back like one
        behind a full fetch queue.  ``starving`` (nothing in flight at all)
        arms the guard that admits the queue head even when every waiter
        would be deferred — a permanently degraded device must degrade
        service, not halt it."""
        if slots <= 0:
            return [], waiting, 0
        admitted, still, deferred = [], [], 0
        depth = dict(depth)
        for req in waiting:
            if len(admitted) >= slots:
                still.append(req)
                continue
            dev = self._home_device(req)
            if (self.cfg.admission == "defer"
                    and (depth.get(dev, 0) >= self.cfg.fetch_depth_limit
                         or dev in degraded)):
                still.append(req)
                deferred += 1
                continue
            depth[dev] = depth.get(dev, 0) + 1
            admitted.append(req)
        if starving and not admitted and still:
            admitted.append(still.pop(0))
            deferred = max(0, deferred - 1)
        return admitted, still, deferred

    # -------------------------------------------------------------- run ----
    def run(self, requests, *, record_round: int | None = None) -> ServingReport:
        """Simulate ``requests`` to completion.

        ``record_round`` records the Nth composed round (0-based) with
        ``record_trace=True`` and keeps its :class:`ComposedResult` on
        ``self.last_recorded`` for Chrome-trace export (DESIGN.md §14);
        timing is unaffected (composed runs always take the full event
        loop).  ``None`` (default) never records.
        """
        cfg = self.cfg
        self.last_recorded = None
        reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        n = len(reqs)
        if n == 0:
            raise ValueError("empty workload")
        slo_tpot = cfg.slo_scale * decode_step_time(cfg.spec, cfg.max_batch)
        api = N_BATCH_CALLS * BATCH_API_COST

        i = 0
        now = 0.0
        waiting: list[Request] = []
        fetching: list[_Fetch] = []          # launch order == service order
        active: list[_Active] = []
        done: list[RequestTiming] = []
        span_est: float | None = None
        rounds = 0
        deferred = 0

        def finish(req: Request, first_token: float, ttft: float,
                   completion: float, slo_ttft: float) -> None:
            out = req.output_tokens
            tpot = ((completion - first_token) / (out - 1)) if out > 1 else 0.0
            done.append(RequestTiming(
                rid=req.rid, arrival=req.arrival, ttft=ttft, tpot=tpot,
                completion=completion, output_tokens=out,
                slo_ttft=slo_ttft, slo_tpot=slo_tpot))

        def land(req: Request, t_f: float) -> None:
            """Fetch fully served at round-relative time ``t_f``.  The delay
            is accumulated as (queue wait) + (service) rather than through
            absolute timestamps, so at load -> 0 (now == arrival) the TTFT
            is bitwise ``serving_model.ttft(...)['total']``."""
            delay = (now - req.arrival) + t_f
            ttft = (delay + api
                    + decode_step_time(cfg.spec) + FRAMEWORK_OVERHEAD)
            slo = cfg.slo_scale * self.unloaded_ttft(req)
            first = req.arrival + ttft
            if req.output_tokens <= 1:
                finish(req, first, ttft, first, slo)
            else:
                active.append(_Active(req, first, ttft, slo))

        while i < n or waiting or fetching or active:
            if not active and not fetching and not waiting:
                now = max(now, reqs[i].arrival)      # idle: jump to arrival
            while i < n and reqs[i].arrival <= now:
                waiting.append(reqs[i])
                i += 1
            # Admission window: arrivals landing before the round would end
            # become candidates, released mid-round at their arrival offset.
            if span_est is None:
                span_est = (self.isolated_fetch_seconds(waiting[0])
                            if waiting else decode_step_time(cfg.spec, cfg.max_batch))
            while i < n and reqs[i].arrival < now + span_est:
                waiting.append(reqs[i])
                i += 1
            depth: dict[int, int] = {}
            for f in fetching:
                d = self._home_device(f.req)
                depth[d] = depth.get(d, 0) + 1
            slots = cfg.max_batch - len(active) - len(fetching)
            degraded = (self.faults.waitable_degraded(now)
                        if self.faults is not None else frozenset())
            starving = not fetching and not active
            admitted, waiting, ndef = self._admit(waiting, slots, depth,
                                                  degraded, starving)
            deferred += ndef

            # One composed world for the round: carried-over fetch remainders
            # (release 0, launch order), the new launches (released at their
            # arrival offsets), then the decode batch's streams.
            schedules, releases = [], []
            for f in fetching:
                schedules.append(self._remainder_schedule(f))
                releases.append(0.0)
            for req in admitted:
                fetching.append(_Fetch(req, self._fetch_shape(req)[0]))
                schedules.append(self._fetch_schedule(req))
                releases.append(max(0.0, req.arrival - now))
            n_fetch = len(fetching)
            batch = len(active)
            n_moe = sum(1 for a in active if a.req.moe)
            if batch:
                for sched in self._decode_schedules(batch, n_moe):
                    schedules.append(sched)
                    releases.append(0.0)
            if not schedules:
                raise AssertionError("round composed nothing")  # unreachable
            comp = run_composed(
                schedules, self.topo, releases,
                faults=self.faults.shifted(now) if self.faults is not None
                else None,
                record_trace=record_round is not None and rounds == record_round)
            if record_round is not None and rounds == record_round:
                self.last_recorded = comp
            rounds += 1

            fin = [comp.outcomes[k].finish for k in range(n_fetch)]
            if batch:
                comm_finish = max(o.finish for o in comp.outcomes[n_fetch:])
                span = max(comm_finish, decode_step_time(cfg.spec, batch))
            else:
                span = min(fin)          # run to the first fetch completion
            end = now + span

            still: list[_Fetch] = []
            for k, f in enumerate(fetching):
                if fin[k] <= span:
                    land(f.req, fin[k])
                else:
                    # Fluid progress over the stream's in-round service
                    # window [release, span); block-granular, so the
                    # remainder is a real (smaller) schedule next round.
                    window = max(0.0, span - releases[k])
                    served = max(0.0, fin[k] - releases[k])
                    done_blocks = int(f.remaining * window / served) if served else 0
                    f.remaining = max(1, f.remaining - done_blocks)
                    still.append(f)
            fetching = still

            if batch:
                remaining = []
                for a in active:
                    a.remaining -= 1
                    if a.remaining == 0:
                        finish(a.req, a.first_token, a.ttft, end, a.slo_ttft)
                    else:
                        remaining.append(a)
                active = remaining
            span_est = span
            now = end

        makespan = max(t.completion for t in done)
        return ServingReport(timings=tuple(sorted(done, key=lambda t: t.rid)),
                             makespan=makespan, rounds=rounds, deferred=deferred)
