"""Host-memory KV store for context caching (paper §5.3).

KV for finished/parked contexts is SAVED to host memory (numpy — the "CPU
DRAM tier") in paged blocks and FETCHED back on a cache hit instead of
re-running prefill.  Three fetch backends mirror the paper's comparison:

* ``pcpy``   — one transfer per block (baseline vLLM: one hipMemcpyAsync
               per dispersed block; here one ``jax.device_put`` each).
* ``b2b``    — ONE batched transfer: blocks are chained into a single
               contiguous staging buffer and moved with one launch + one
               sync (``hipMemcpyBatchAsync`` routed to one engine, §5.3.1);
               fan-out above the 4MB threshold.
* ``opt_b2b``— the b2b data path with the optimized command stream
               (DESIGN.md §7/§8): batched submission + fused write+signal
               over the batch's chunked sDMA commands.  This is what
               ``CommBackend.kv_fetch_plan`` requests for the latte backend.
* ``kernel`` — the whole pool region moves once; a Pallas gather kernel
               (repro/kernels/paged_kv_gather) reassembles dispersed blocks
               on device (the CU/workgroup-per-block alternative).

Each fetch also returns the MODELED DMA latency from the calibrated engine
model (the container has no PCIe to measure), which the TTFT/throughput
benchmarks consume; the data path itself is real and correctness-checked.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.dma import kv_fetch_schedule, mi300x_platform, simulate
from repro.core.dma.rccl_model import kernel_copy_latency
from .kvcache import BLOCK_TOKENS


@dataclasses.dataclass
class FetchResult:
    k_blocks: np.ndarray        # [n_blocks, bt, L, KV, hd]
    v_blocks: np.ndarray
    n_transfers: int
    modeled_seconds: float      # calibrated DMA/kernel model latency


class HostKVStore:
    def __init__(self, block_tokens: int = BLOCK_TOKENS):
        self.block_tokens = block_tokens
        self._store: dict[str, tuple[np.ndarray, np.ndarray, int]] = {}
        self.topo = mi300x_platform()

    # ------------------------------------------------------------- save ----
    def save(self, key: str, k_blocks: np.ndarray, v_blocks: np.ndarray,
             n_tokens: int) -> None:
        self._store[key] = (np.asarray(k_blocks), np.asarray(v_blocks), n_tokens)

    def __contains__(self, key: str) -> bool:
        return key in self._store

    def tokens_for(self, key: str) -> int:
        return self._store[key][2]

    def blocks_for(self, key: str) -> tuple[int, int]:
        """(n_blocks, bytes per K+V block) of a stored context — the inputs
        ``CommBackend.kv_fetch_plan`` needs to plan the fetch."""
        kb, vb, _ = self._store[key]
        return kb.shape[0], kb[0].nbytes + vb[0].nbytes

    # ------------------------------------------------------------ fetch ----
    def fetch(self, key: str, backend: str = "b2b") -> FetchResult:
        kb, vb, n_tokens = self._store[key]
        n_blocks = kb.shape[0]
        block_bytes = kb[0].nbytes + vb[0].nbytes

        if backend == "pcpy":
            # one device_put per dispersed block — per-copy launch + sync
            k_dev = [np.asarray(jax.device_put(kb[i])) for i in range(n_blocks)]
            v_dev = [np.asarray(jax.device_put(vb[i])) for i in range(n_blocks)]
            k_out, v_out = np.stack(k_dev), np.stack(v_dev)
            sched = kv_fetch_schedule(self.topo, n_blocks, block_bytes, "pcpy")
            modeled = simulate(sched, self.topo).latency
            n_transfers = 2 * n_blocks
        elif backend in ("b2b", "opt_b2b"):
            # chain into one staging buffer; ONE transfer, one sync.  The
            # opt_ flavor moves the same bytes but models the optimized
            # command stream (batched submission + fused signal, DESIGN.md
            # §7/§8) for the latency estimate.
            staged = np.concatenate([kb.reshape(n_blocks, -1),
                                     vb.reshape(n_blocks, -1)], axis=1)
            moved = np.asarray(jax.device_put(staged))
            ksz = kb.reshape(n_blocks, -1).shape[1]
            k_out = moved[:, :ksz].reshape(kb.shape)
            v_out = moved[:, ksz:].reshape(vb.shape)
            variant = "prelaunch_b2b" if backend == "b2b" else "opt_prelaunch_b2b"
            sched = kv_fetch_schedule(self.topo, n_blocks, block_bytes, variant)
            modeled = simulate(sched, self.topo).latency
            n_transfers = 1
        elif backend == "kernel":
            # move the pool once; Pallas kernel gathers dispersed blocks
            from repro.kernels.paged_kv_gather.ops import gather_blocks
            pool_k = jax.device_put(kb.reshape(n_blocks, self.block_tokens, -1))
            pool_v = jax.device_put(vb.reshape(n_blocks, self.block_tokens, -1))
            tbl = jnp.arange(n_blocks, dtype=jnp.int32)
            k_out = np.asarray(gather_blocks(pool_k, tbl, interpret=True)).reshape(kb.shape)
            v_out = np.asarray(gather_blocks(pool_v, tbl, interpret=True)).reshape(vb.shape)
            modeled = kernel_copy_latency(self.topo, n_blocks * block_bytes, n_launches=1)
            n_transfers = 1
        else:
            raise ValueError(backend)
        return FetchResult(k_out, v_out, n_transfers, modeled)
