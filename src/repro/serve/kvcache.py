"""Paged KV cache management (PagedAttention layout, vLLM-style).

Device-side pools hold KV in fixed-size blocks (16 tokens by default, the
vLLM default the paper cites); per-sequence block tables map logical block
index -> pool slot.  All model layers of one logical block are stored
contiguously (the [28]-style optimization the paper's baseline assumes), so
one host<->device transfer moves a full layer-stack block.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

BLOCK_TOKENS = 16


@dataclasses.dataclass
class PagedPools:
    """Device-side paged pools: k/v [n_blocks, block_tokens, L, KV, hd]."""

    k: jax.Array
    v: jax.Array
    block_tokens: int

    @property
    def n_blocks(self) -> int:
        return self.k.shape[0]

    @property
    def block_bytes(self) -> int:
        per = int(np.prod(self.k.shape[1:])) * self.k.dtype.itemsize
        return 2 * per  # k + v


def init_pools(cfg: ArchConfig, n_layers: int, n_blocks: int,
               block_tokens: int = BLOCK_TOKENS) -> PagedPools:
    cd = jnp.dtype(cfg.compute_dtype)
    shape = (n_blocks, block_tokens, n_layers, cfg.n_kv_heads, cfg.head_dim)
    return PagedPools(jnp.zeros(shape, cd), jnp.zeros(shape, cd), block_tokens)


class BlockAllocator:
    """Free-list allocator over pool slots."""

    def __init__(self, n_blocks: int):
        self.free = list(range(n_blocks - 1, -1, -1))
        self.n_blocks = n_blocks

    def alloc(self, n: int) -> list[int]:
        if n > len(self.free):
            raise MemoryError(f"paged pool exhausted: want {n}, have {len(self.free)}")
        return [self.free.pop() for _ in range(n)]

    def release(self, blocks: list[int]) -> None:
        self.free.extend(blocks)

    @property
    def n_free(self) -> int:
        return len(self.free)


def blocks_for_tokens(n_tokens: int, block_tokens: int = BLOCK_TOKENS) -> int:
    return (n_tokens + block_tokens - 1) // block_tokens


def kv_to_blocks(k: np.ndarray, v: np.ndarray, block_tokens: int = BLOCK_TOKENS):
    """Layer-stacked prefill KV [L, B=1, S, KV, hd] -> per-block arrays
    [n_blocks, block_tokens, L, KV, hd] (zero-padded tail)."""
    L, B, S, KV, hd = k.shape
    assert B == 1
    nb = blocks_for_tokens(S, block_tokens)
    pad = nb * block_tokens - S
    def conv(a):
        a = np.moveaxis(np.asarray(a)[:, 0], 0, 1)          # [S, L, KV, hd]
        if pad:
            a = np.concatenate([a, np.zeros((pad,) + a.shape[1:], a.dtype)])
        return a.reshape(nb, block_tokens, L, KV, hd)
    return conv(k), conv(v)


def blocks_to_kv(kb: np.ndarray, vb: np.ndarray, n_tokens: int):
    """Inverse of kv_to_blocks -> [L, 1, S, KV, hd]."""
    def conv(a):
        nb, bt, L, KV, hd = a.shape
        a = a.reshape(nb * bt, L, KV, hd)[:n_tokens]
        return np.moveaxis(a, 1, 0)[:, None]
    return conv(kb), conv(vb)
