"""Seeded request-arrival workloads for the concurrent-traffic serving sim.

The paper's serving figures (16/17) time ONE request's KV fetch in
isolation; predicting behavior under load needs *arrival processes*.  Two
generators cover the standard serving regimes:

* :func:`poisson_arrivals` — memoryless open-loop traffic at a fixed
  offered rate (the M/G/k baseline every serving paper sweeps).
* :func:`bursty_arrivals` — a 2-state Markov-modulated Poisson process
  (MMPP): a quiet state and a burst state whose rate is ``burst_factor``
  higher, with geometric dwell times.  The mixture is normalized so the
  *mean* rate equals ``rate`` — a bursty trace stresses tail latency at the
  same offered load.

Everything is driven by ``numpy.random.default_rng`` (PCG64), so a fixed
seed reproduces the exact same trace across processes and platforms —
`tests/test_compose.py` pins this plus a golden end-to-end trace.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: arrival time plus its traffic shape.

    ``prompt_tokens`` sizes the KV fetch (the context is assumed cached on
    the host, the paper's 100%-hit regime); ``output_tokens`` is the decode
    length; ``moe`` marks requests whose decode steps add MoE all-to-all
    traffic on top of the per-layer all-gathers.
    """

    rid: int
    arrival: float              # seconds since workload start
    prompt_tokens: int
    output_tokens: int
    moe: bool = False


def poisson_arrivals(rate: float, n: int, seed: int) -> tuple[float, ...]:
    """``n`` Poisson arrival times at ``rate`` requests/second."""
    if rate <= 0.0:
        raise ValueError("rate must be > 0")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return tuple(float(t) for t in np.cumsum(gaps))


def bursty_arrivals(rate: float, n: int, seed: int, *,
                    burst_factor: float = 4.0,
                    p_enter: float = 0.15,
                    p_exit: float = 0.35) -> tuple[float, ...]:
    """``n`` MMPP arrival times with mean rate ``rate``.

    After each arrival the modulating chain flips quiet->burst with
    probability ``p_enter`` and burst->quiet with ``p_exit`` (geometric
    dwell in units of arrivals).  The quiet-state rate is solved so the
    stationary mixture's mean rate equals ``rate``: with burst fraction
    ``pi = p_enter / (p_enter + p_exit)``, quiet rate
    ``rate / (1 - pi + pi * burst_factor)``.
    """
    if rate <= 0.0:
        raise ValueError("rate must be > 0")
    if burst_factor < 1.0:
        raise ValueError("burst_factor must be >= 1")
    pi = p_enter / (p_enter + p_exit)
    quiet = rate / ((1.0 - pi) + pi * burst_factor)
    rng = np.random.default_rng(seed)
    t = 0.0
    burst = False
    out = []
    for _ in range(n):
        r = quiet * (burst_factor if burst else 1.0)
        t += float(rng.exponential(1.0 / r))
        out.append(t)
        u = float(rng.random())
        burst = (u < p_enter) if not burst else (u >= p_exit)
    return tuple(out)


def synthetic_workload(n: int, rate: float, seed: int, *,
                       kind: str = "poisson",
                       prompt_tokens: int = 2048,
                       output_tokens: int = 8,
                       prompt_jitter: float = 0.25,
                       moe_fraction: float = 0.0,
                       **kwargs) -> tuple[Request, ...]:
    """``n`` seeded requests with ``kind`` arrivals ("poisson"/"bursty").

    Prompt lengths jitter uniformly within ``±prompt_jitter`` of
    ``prompt_tokens`` (KV fetches of varied size contend differently than a
    uniform fleet); a ``moe_fraction`` of requests carry MoE all-to-all
    decode traffic.  Request shapes draw from an rng stream separate from
    the arrival process (seed sequence ``[seed, 1]``), so the same trace
    shape can be replayed against either arrival generator.
    """
    if kind == "poisson":
        arrivals = poisson_arrivals(rate, n, seed)
    elif kind == "bursty":
        arrivals = bursty_arrivals(rate, n, seed, **kwargs)
    else:
        raise ValueError(f"unknown arrival kind {kind!r}")
    rng = np.random.default_rng([seed, 1])
    lo = max(1, int(prompt_tokens * (1.0 - prompt_jitter)))
    hi = max(lo + 1, int(prompt_tokens * (1.0 + prompt_jitter)) + 1)
    prompts = rng.integers(lo, hi, size=n)
    moe_draw = rng.random(size=n)
    return tuple(
        Request(rid=i, arrival=arrivals[i], prompt_tokens=int(prompts[i]),
                output_tokens=output_tokens, moe=bool(moe_draw[i] < moe_fraction))
        for i in range(n))
