"""Compatibility shims for the range of JAX versions this repo runs under.

The repo targets the modern public API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, dict-valued ``cost_analysis``).
Older releases (e.g. 0.4.x, which the container ships) expose the same
functionality under different names/signatures:

* ``shard_map`` lives in ``jax.experimental.shard_map`` and spells the
  replication check ``check_rep`` instead of ``check_vma``.
* ``jax.make_mesh`` has no ``axis_types`` parameter (and
  ``jax.sharding.AxisType`` does not exist).
* ``Compiled.cost_analysis()`` returns a one-element *list* of dicts
  rather than a dict.

Import from here instead of sprinkling try/excepts at every call site.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: public top-level export
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the new keyword spelling on every version."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})


def make_mesh(axis_shapes, axis_names, *, auto_axes: bool = True):
    """``jax.make_mesh`` requesting Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=(axis_type.Auto,) * len(axis_names)
                                 if auto_axes else None)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (older versions: ``psum(1, axis)`` constant-folds
    to a concrete int inside shard_map)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def pltpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (renamed from ``TPUCompilerParams``)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def pltpu_interpret_mode():
    """Value for ``pallas_call(interpret=...)`` requesting TPU interpret mode:
    ``pltpu.InterpretParams()`` where it exists, plain ``True`` before that."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "InterpretParams", None)
    return cls() if cls is not None else True


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to a flat dict on every version."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
