"""Qwen2-VL-72B language backbone: 80L d_model=8192 64H (GQA kv=8)
d_ff=29568 vocab=152064, M-RoPE (t/h/w sections), dynamic-resolution vision
encoder is a STUB (input_specs provides patch embeddings).
[arXiv:2409.12191]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    norm="rmsnorm",
    act="silu",
    rope_kind="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # t,h,w split of head_dim/2=64
    stub_frontend=True,
)
