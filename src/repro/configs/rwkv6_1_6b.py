"""RWKV-6 (Finch) 1.6B: 24L d_model=2048, attention-free (data-dependent
decay linear attention), channel-mix d_ff=7168, vocab=65536, head_size=64.
[arXiv:2404.05892]"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    source="arXiv:2404.05892",
    n_layers=24,
    d_model=2048,
    n_heads=0,          # attention-free
    n_kv_heads=0,
    d_ff=7168,
    vocab=65536,
    norm="layernorm",
    act="silu",
    rope_kind="none",
    ssm=SSMConfig(kind="rwkv6", state_size=64, head_size=64),
)
