"""Mixtral-8x7B: 32L d_model=4096 32H (GQA kv=8) expert d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088]"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    source="arXiv:2401.04088",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    norm="rmsnorm",
    act="silu",
    rope_kind="rope",
    rope_theta=1_000_000.0,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
)
