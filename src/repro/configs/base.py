"""Architecture + input-shape config system.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` defining an
:class:`ArchConfig` with the exact public numbers (cited).  ``reduced()``
returns the smoke-test variant of the same family (<=2 layers, d_model<=512,
<=4 experts) used by CPU tests; the full config is only ever *lowered*
(ShapeDtypeStruct, no allocation) by the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: Literal["rwkv6", "mamba2"]
    state_size: int          # recurrent state per channel-head
    head_size: int = 64
    expand: int = 2          # mamba2 d_inner = expand * d_model
    conv_kernel: int = 4     # mamba2 depthwise conv


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    attn_every: int          # one shared attention block every N ssm layers
    shared_attn: bool = True # zamba2: ONE weight-shared attention block


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int
    encoder_seq: int         # encoder frames after the (stubbed) conv frontend


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    source: str              # citation: arXiv id or model card

    n_layers: int
    d_model: int
    n_heads: int             # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int | None = None           # defaults to d_model // n_heads
    qkv_bias: bool = False
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["silu", "gelu"] = "silu"
    tie_embeddings: bool = False
    rope_kind: Literal["rope", "mrope", "none", "sinusoidal"] = "rope"
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = ()   # M-RoPE dims split (t, h, w)
    attn_softcap: float | None = None      # gemma2 logit soft-capping
    final_softcap: float | None = None
    sliding_window: int | None = None      # SWA window (mixtral, gemma2 local)
    layer_pattern: tuple[str, ...] | None = None  # e.g. ("local","global") cycled

    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    encdec: EncDecConfig | None = None

    # embeddings provided directly (VLM patch embeds / audio frames) — the
    # allowed frontend-stub carve-out.
    stub_frontend: bool = False

    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic / O(1)-state archs run the 524288-token decode shape.

        Dense full-attention archs skip it (DESIGN.md §6); SWA archs
        (mixtral) qualify via the rolling-window KV cache; gemma2 does NOT
        (its alternating pattern keeps full-attention global layers).
        """
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None and self.layer_pattern is None

    @property
    def n_params(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6*N*D)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.head_dim or 0
        total = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm" and self.ssm and self.ssm.kind == "rwkv6":
            per = 4 * D * D + D * D + 2 * D * F  # r,k,v,g,o + channel-mix
            return total + L * per
        per = 0
        if self.n_heads:
            per += D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd + self.n_heads * hd * D
        if self.moe:
            per_expert = 3 * D * self.moe.d_ff_expert
            per += D * self.moe.n_experts + self.moe.n_experts * per_expert
        else:
            per += 3 * D * F if self.act == "silu" else 2 * D * F
        if self.hybrid and self.ssm:
            d_in = self.ssm.expand * D
            N = self.ssm.state_size
            nh = d_in // self.ssm.head_size
            # mamba2 per layer: in_proj (z,x,B,C,dt) + out_proj + conv
            per = D * (2 * d_in + 2 * N + nh) + d_in * D + 4 * (d_in + 2 * N)
            # ONE weight-shared attention block (+ its MLP), stored once
            total += 4 * D * self.n_heads * hd + 3 * D * F
        if self.encdec:
            total += self.encdec.n_encoder_layers * (4 * D * self.n_heads * hd + 2 * D * F)
            per = 4 * D * self.n_heads * hd + 2 * D * F + 4 * D * self.n_heads * hd
        return total + L * per

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE uses top_k experts only)."""
        if not self.moe:
            return self.n_params
        D, L = self.d_model, self.n_layers
        inactive = L * (self.moe.n_experts - self.moe.top_k) * 3 * D * self.moe.d_ff_expert
        return self.n_params - inactive

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/features, tiny dims."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        head_dim = max(1, d_model // n_heads) if n_heads else None
        kv = min(self.n_kv_heads, n_heads) if n_heads else 0
        kv = max(1, kv) if n_heads else 0
        changes: dict = dict(
            n_layers=min(self.n_layers, 2),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            mrope_sections=(head_dim // 2 - 2 * (head_dim // 8), head_dim // 8, head_dim // 8)
            if self.mrope_sections and head_dim else (),
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2), d_ff_expert=min(self.moe.d_ff_expert, 256))
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_size=min(self.ssm.state_size, 16),
                head_size=min(self.ssm.head_size, 32))
        if self.hybrid:
            changes["hybrid"] = dataclasses.replace(self.hybrid, attn_every=1)
        if self.encdec:
            changes["encdec"] = dataclasses.replace(
                self.encdec, n_encoder_layers=min(self.encdec.n_encoder_layers, 2),
                encoder_seq=min(self.encdec.encoder_seq, 32))
        if self.layer_pattern:
            changes["n_layers"] = len(self.layer_pattern)
        return dataclasses.replace(self, name=self.name + "-smoke", **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
