"""Zamba2-2.7B: 54 Mamba2 layers d_model=2560, shared attention block
(32H, GQA kv=32) every 6 layers, d_ff=10240, vocab=32000, ssm_state=64.
[arXiv:2411.15242]"""
from .base import ArchConfig, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    norm="rmsnorm",
    act="silu",
    rope_kind="rope",
    ssm=SSMConfig(kind="mamba2", state_size=64, head_size=64, expand=2),
    hybrid=HybridConfig(attn_every=6, shared_attn=True),
)
