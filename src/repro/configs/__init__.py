"""Registry of assigned architectures (public-literature pool) + input shapes."""
from __future__ import annotations

import importlib

from .base import ArchConfig, INPUT_SHAPES, ShapeConfig  # noqa: F401

_MODULES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "zamba2-2.7b": "zamba2_2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "deepseek-7b": "deepseek_7b",
    "stablelm-12b": "stablelm_12b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "qwen2-0.5b": "qwen2_0_5b",
    "mixtral-8x7b": "mixtral_8x7b",
    "whisper-tiny": "whisper_tiny",
    "gemma2-27b": "gemma2_27b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def get_shape(shape_id: str) -> ShapeConfig:
    return INPUT_SHAPES[shape_id]


def all_configs() -> dict[str, ArchConfig]:
    return {k: get_config(k) for k in ARCH_IDS}


def assigned_pairs() -> list[tuple[str, str]]:
    """All (arch, shape) pairs the dry-run must cover (skips handled there)."""
    return [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
