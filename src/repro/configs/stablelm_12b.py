"""StableLM-2-12B: 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352,
LayerNorm (stablelm-2 family).  [hf:stabilityai/stablelm-2-1_6b]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    qkv_bias=False,
    norm="layernorm",
    act="silu",
    rope_kind="rope",
)
