"""Whisper-tiny: encoder-decoder, 4L each, d_model=384 6H d_ff=1536
vocab=51865.  The mel-spectrogram + conv frontend is a STUB — input_specs
provides precomputed frame embeddings (1500 frames).  [arXiv:2212.04356]"""
from .base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=4,                 # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    rope_kind="sinusoidal",
    encdec=EncDecConfig(n_encoder_layers=4, encoder_seq=1500),
    stub_frontend=True,
)
