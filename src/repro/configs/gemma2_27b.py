"""Gemma2-27B: 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000,
alternating local (sliding window 4096) + global attention, attention logit
softcap 50, final logit softcap 30, head_dim=128.  [arXiv:2408.00118]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    source="arXiv:2408.00118",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    norm="rmsnorm",
    act="gelu",
    rope_kind="rope",
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    layer_pattern=("local", "global"),
    tie_embeddings=True,
)
