"""DeepSeek-7B (base): llama-arch, 30L d_model=4096 32H (GQA kv=32)
d_ff=11008 vocab=102400.  [arXiv:2401.02954]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    source="arXiv:2401.02954",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    norm="rmsnorm",
    act="silu",
    rope_kind="rope",
)
