"""OLMoE-1B-7B: 16L d_model=2048 16H (GQA kv=16) expert d_ff=1024 vocab=50304,
MoE 64 experts top-8.  [arXiv:2409.02060]"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    norm="rmsnorm",
    act="silu",
    rope_kind="rope",
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
)
