"""Logical sharding rules: param/activation PartitionSpecs per mesh.

Strategy (DESIGN.md §7):
* FSDP: the non-TP dimension of every large matrix is sharded over the
  data-parallel axes (('pod','data') on the multi-pod mesh) — ZeRO-style
  fully-sharded storage; GSPMD inserts the layer-wise all-gathers.
* TP:   head/ffn/vocab output dims shard over 'model'.
* Every rule is divisibility-guarded: a dim that doesn't divide the axis
  size falls back to replicated on that axis (e.g. qwen2-0.5b's 14 heads).
* Activations: hidden states are sharded batch-over-DP and sequence-over-
  'model' between blocks (Megatron-style sequence parallelism); attention
  and MLP internals reshard as GSPMD requires.
* batch==1 decode (long_500k): batch is unshardable — KV-cache capacity is
  sharded over the DP axes instead (context parallelism).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@dataclasses.dataclass(frozen=True)
class PerfOptions:
    """Beyond-baseline performance knobs (§Perf in EXPERIMENTS.md).

    Defaults are the OPTIMIZED configuration; the recorded baseline used
    ``PerfOptions.baseline()``.
    """

    expert_sharding: bool = True      # shard MoE capacity buffers over DP
    cast_params_bf16: bool = True     # gather bf16 weights, fp32 master copy
    light_resharding: bool = True     # one seq-reshard point per block, not two

    @classmethod
    def baseline(cls) -> "PerfOptions":
        return cls(expert_sharding=False, cast_params_bf16=False,
                   light_resharding=False)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    dp: Any          # data-parallel axes: ('pod','data') or 'data'
    tp: Any = "model"

    def _ok(self, dim: int, axes) -> Any:
        return axes if axes is not None and dim % _axsize(self.mesh, axes) == 0 else None

    def matrix(self, shape: tuple[int, ...], tp_dim: int, *, stacked: int = 0) -> P:
        """Spec for a (possibly layer-stacked) weight matrix: FSDP on the
        first non-stacked non-TP dim, TP on ``tp_dim``."""
        spec: list = [None] * len(shape)
        spec[tp_dim] = self._ok(shape[tp_dim], self.tp)
        for i in range(stacked, len(shape)):
            if i != tp_dim:
                spec[i] = self._ok(shape[i], self.dp)
                break
        return P(*spec)

    def replicated(self, shape) -> P:
        return P(*([None] * len(shape)))


def infer_param_specs(params_shape: Any, cfg: ArchConfig, rules: ShardingRules) -> Any:
    """Walk the (abstract) param tree and assign PartitionSpecs by leaf path."""

    def spec_for(path: tuple, leaf) -> P:
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        shape = leaf.shape
        nd = len(shape)
        joined = "/".join(str(n) for n in names)
        stacked = 1 if any(n in ("blocks", "supers", "enc_blocks", "dec_blocks") for n in names) else 0
        # extra stacking inside zamba superblocks: params under supers are
        # [trip, ...] only (inner layers are tuple-indexed, not stacked).
        if nd - stacked == 0:
            return rules.replicated(shape)
        last = names[-1]
        if last in ("embed", "dec_pos"):               # [V, D]
            return P(rules._ok(shape[0], rules.tp), rules._ok(shape[1], rules.dp))
        if last == "unembed":                          # [D, V]
            return P(rules._ok(shape[0], rules.dp), rules._ok(shape[1], rules.tp))
        if last == "router":                           # [.., D, E]
            spec = [None] * nd
            spec[-2] = rules._ok(shape[-2], rules.dp)
            return P(*spec)
        if last in ("wg", "wu", "wd") and "moe" in joined:  # [.., E, D|F, F|D]
            # Expert-parallel when E divides the TP axis; otherwise TP the
            # expert-FFN hidden dim.  (Padded EP for E < axis is rejected by
            # jit argument shardings; hierarchical shard_map dispatch is the
            # identified fix — EXPERIMENTS.md §Perf mixtral iterations.)
            spec = [None] * nd
            e = nd - 3
            d_dim = nd - 2 if last in ("wg", "wu") else nd - 1   # d_model dim
            f_dim = nd - 1 if last in ("wg", "wu") else nd - 2   # expert-FFN dim
            if shape[e] % _axsize(rules.mesh, rules.tp) == 0:
                spec[e] = rules.tp                                # expert parallel
                spec[d_dim] = rules._ok(shape[d_dim], rules.dp)
            else:
                spec[f_dim] = rules._ok(shape[f_dim], rules.tp)   # TP inside experts
                spec[d_dim] = rules._ok(shape[d_dim], rules.dp)
            return P(*spec)
        in_dim_names = {"wo", "wd", "w_out", "wB"}
        out_dim_names = {"wq", "wk", "wv", "wg", "wu", "wA", "w_in", "wr"}
        if "cm" in names and last == "wv":              # rwkv channel-mix [F, D]
            return _in_dim_tp(rules, shape, stacked)
        if last in ("wq", "wk", "wv") and ("attn" in joined or "self_attn" in joined
                                           or "cross_attn" in joined or "shared_attn" in joined):
            # TP on the head axis only when WHOLE heads divide the axis:
            # splitting inside a head (e.g. qwen2-0.5b's 2 KV heads over 16
            # chips) forces a full KV-cache re-gather every decode step
            # (§Perf iteration 2 — measured 9.7 GB/step/device).
            heads = cfg.n_heads if last == "wq" else cfg.n_kv_heads
            if heads % _axsize(rules.mesh, rules.tp) != 0:
                spec = [None] * nd
                spec[nd - 2] = rules._ok(shape[nd - 2], rules.dp)
                return P(*spec)
            return rules.matrix(shape, nd - 1, stacked=stacked)
        if last == "wo" and ("attn" in joined or "self_attn" in joined
                             or "cross_attn" in joined or "shared_attn" in joined):
            if cfg.n_heads % _axsize(rules.mesh, rules.tp) != 0:
                spec = [None] * nd
                spec[nd - 1] = rules._ok(shape[nd - 1], rules.dp)
                return P(*spec)
            return _in_dim_tp(rules, shape, stacked)
        if nd >= 2 and last in out_dim_names:
            return rules.matrix(shape, nd - 1, stacked=stacked)  # out-dim TP
        if nd >= 2 and last in in_dim_names:
            return _in_dim_tp(rules, shape, stacked)
        if nd >= 2 and last == "conv_w":
            spec = [None] * nd
            spec[-1] = rules._ok(shape[-1], rules.tp)
            return P(*spec)
        return rules.replicated(shape)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def _in_dim_tp(rules: ShardingRules, shape, stacked: int) -> P:
    """TP on the input (second-to-last) dim, FSDP on the output dim."""
    nd = len(shape)
    spec: list = [None] * nd
    spec[nd - 2] = rules._ok(shape[nd - 2], rules.tp)
    spec[nd - 1] = rules._ok(shape[nd - 1], rules.dp)
    return P(*spec)


def make_activation_constrainer(cfg: ArchConfig, shape: ShapeConfig, rules: ShardingRules,
                                perf: "PerfOptions | None" = None):
    """Returns ac(x, kind) applying with_sharding_constraint inside models."""
    perf = perf or PerfOptions()
    mesh = rules.mesh
    batch_shardable = shape.global_batch % _axsize(mesh, rules.dp) == 0
    dp_size = _axsize(mesh, rules.dp)
    tp_size = _axsize(mesh, rules.tp)

    def ac(x, kind):
        if kind == "hidden_mid" and perf.light_resharding:
            return x    # §Perf: one reshard point per block suffices
        if kind in ("hidden", "hidden_mid", "partial"):
            # "partial": a sub-layer output whose TP contraction just
            # finished — constraining it (rather than the residual sum)
            # lets the partitioner emit reduce-scatter instead of
            # all-reduce + re-slice (§Perf iteration 3).
            if x.ndim != 3:
                return x
            b, s, d = x.shape
            bspec = rules.dp if batch_shardable else None
            sspec = rules.tp if (s % tp_size == 0 and s > 1) else None
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(bspec, sspec, None)))
        if kind == "logits":
            b, s, v = x.shape
            bspec = rules.dp if batch_shardable else None
            vspec = rules.tp if v % tp_size == 0 else None
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(bspec, None, vspec)))
        if kind == "expert":
            # DISABLED after measurement: every forced sharding of the MoE
            # capacity buffer (C over DP, C over TP, E over TP) REGRESSED
            # 3-12x — GSPMD cannot see locality through the global-argsort
            # scatter and falls back to involuntary full rematerialization
            # (replicate + re-partition).  The identified fix is a
            # hierarchical shard_map dispatch (local sort per DP shard +
            # explicit expert all-to-all, exactly the collective the paper
            # optimizes).  Full log: EXPERIMENTS.md §Perf / mixtral+olmoe.
            return x
        return x

    return ac


def cache_specs(cache_tree: Any, cfg: ArchConfig, shape: ShapeConfig, rules: ShardingRules) -> Any:
    """PartitionSpecs for KV caches / SSM states (stacked [L, B, ...])."""
    mesh = rules.mesh
    batch_ok = shape.global_batch % _axsize(mesh, rules.dp) == 0

    def spec_for(path: tuple, leaf) -> P:
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        shape_ = leaf.shape
        nd = len(shape_)
        last = names[-1]
        if last == "kpos" or nd <= 2:
            return P(*([None] * nd))
        spec: list = [None] * nd
        # find the batch dim: first dim equal to global_batch after stacking dims
        bdim = None
        for i, s in enumerate(shape_):
            if s == shape.global_batch and i <= 2:
                bdim = i
                break
        if bdim is not None and batch_ok and shape.global_batch > 1:
            spec[bdim] = rules.dp
        elif last in ("k", "v") and nd >= 3:
            # batch==1: context parallelism — shard capacity over DP axes
            cap_dim = (bdim + 1) if bdim is not None else nd - 3
            if shape_[cap_dim] % _axsize(mesh, rules.dp) == 0:
                spec[cap_dim] = rules.dp
        if last in ("k", "v"):
            kv_dim = nd - 2
            cap_dim = nd - 3
            if shape_[kv_dim] % _axsize(mesh, rules.tp) == 0:
                spec[kv_dim] = rules.tp
            elif spec[cap_dim] is None and shape_[cap_dim] % _axsize(mesh, rules.tp) == 0:
                # KV heads can't shard the TP axis (e.g. 8 heads / 16 chips):
                # shard cache CAPACITY over TP instead — without this, a
                # 32k-context cache replicates 16x and blows the 16GB HBM
                # budget (measured 43 GB/device on qwen2-vl decode_32k).
                spec[cap_dim] = rules.tp
        if last == "h" and nd >= 2:  # mamba state [.., B, nh, hs, N]
            if shape_[nd - 3] % _axsize(mesh, rules.tp) == 0:
                spec[nd - 3] = rules.tp
        if last == "S" and nd >= 2:  # rwkv state [.., B, nh, hs, hs]
            if shape_[nd - 3] % _axsize(mesh, rules.tp) == 0:
                spec[nd - 3] = rules.tp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def batch_specs(batch_tree: Any, cfg: ArchConfig, shape: ShapeConfig, rules: ShardingRules) -> Any:
    mesh = rules.mesh
    batch_ok = shape.global_batch % _axsize(mesh, rules.dp) == 0 and shape.global_batch > 1

    def spec_for(path: tuple, leaf) -> P:
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        spec: list = [None] * nd
        # positions for mrope are [3, B, S]
        bdim = 1 if (nd >= 2 and leaf.shape[0] == 3 and cfg.rope_kind == "mrope"
                     and leaf.shape[1] == shape.global_batch) else 0
        if batch_ok and leaf.shape[bdim] == shape.global_batch:
            spec[bdim] = rules.dp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, batch_tree)
