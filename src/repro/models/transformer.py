"""Model assembly: builds init/forward/decode functions for every assigned
architecture family (dense / moe / vlm / ssm / hybrid / audio).

Conventions
-----------
* Params are nested dicts; repeated layers are STACKED along a leading scan
  axis and executed with ``jax.lax.scan`` (keeps HLO size and compile time
  independent of depth — required for 80-layer configs on this container).
* ``forward``  : full-sequence (train / prefill).  Returns (logits, aux, caches)
  where caches is None unless ``want_cache`` (prefill).
* ``decode_step``: ONE new token against per-layer caches/states.
* ``ac(x, kind)`` is an optional activation-sharding hook threaded from the
  launcher (identity by default) — models stay mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import attention as attn
from . import layers as L
from . import mamba2, moe as moe_mod, rwkv6


def _identity_ac(x, kind):  # default activation-sharding hook
    return x


def scan_blocks(body, carry, xs, unroll: bool = False):
    """lax.scan over stacked layer params, or a python unroll.

    The unrolled form exists for the roofline costing pass: XLA's
    ``cost_analysis`` counts a while body ONCE regardless of trip count
    (verified empirically — DESIGN.md §7), so exact per-layer costs are
    measured by lowering small UNROLLED variants and differencing.
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    leaves = jax.tree.leaves(xs)
    n = leaves[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = ys[0] if ys else None
    return carry, stacked


# --------------------------------------------------------------------------
# Standard transformer block (dense / moe / vlm)
# --------------------------------------------------------------------------
def init_tf_block(cfg: ArchConfig, rng: jax.Array) -> dict:
    k1, k2 = jax.random.split(rng)
    p = {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": attn.init_attention(cfg, k1),
        "ln2": L.init_norm(cfg, cfg.d_model),
    }
    if cfg.moe:
        p["moe"] = moe_mod.init_moe(cfg, k2)
    else:
        p["mlp"] = L.init_mlp(cfg, k2, cfg.d_model, cfg.d_ff)
    return p


def apply_tf_block(cfg, p, x, *, rope, window, ac, expert_sharding=None):
    h = L.apply_norm(cfg, p["ln1"], x)
    out = attn.attention_ctx(cfg, p["attn"], h, rope=rope, causal=True, window=window)
    x = x + ac(out, "partial")
    x = ac(x, "hidden_mid")
    h = L.apply_norm(cfg, p["ln2"], x)
    if cfg.moe:
        es = expert_sharding or (lambda t: ac(t, "expert"))
        y, aux = moe_mod.apply_moe(cfg, p["moe"], h, expert_sharding=es)
    else:
        y, aux = L.apply_mlp(cfg, p["mlp"], h), jnp.float32(0)
    x = ac(x + ac(y, "partial"), "hidden")
    return x, aux


def apply_tf_block_prefill(cfg, p, x, *, rope, window, ac, expert_sharding=None):
    """Like apply_tf_block but also returns this layer's K/V for the cache."""
    h = L.apply_norm(cfg, p["ln1"], x)
    out, (k, v) = attn.attention_ctx(cfg, p["attn"], h, rope=rope, causal=True,
                                     window=window, return_kv=True)
    x = ac(x + out, "hidden_mid")
    h = L.apply_norm(cfg, p["ln2"], x)
    if cfg.moe:
        es = expert_sharding or (lambda t: ac(t, "expert"))
        y, aux = moe_mod.apply_moe(cfg, p["moe"], h, expert_sharding=es)
    else:
        y, aux = L.apply_mlp(cfg, p["mlp"], h), jnp.float32(0)
    return ac(x + y, "hidden"), aux, (k, v)


def apply_tf_block_decode(cfg, p, x, cache, pos, *, rope_fn, window, ac,
                          expert_sharding=None):
    h = L.apply_norm(cfg, p["ln1"], x)
    out, cache = attn.attention_decode(cfg, p["attn"], h, cache, pos,
                                       rope_fn=rope_fn, window=window)
    x = x + out
    h = L.apply_norm(cfg, p["ln2"], x)
    if cfg.moe:
        es = expert_sharding or (lambda t: ac(t, "expert"))
        y, _ = moe_mod.apply_moe(cfg, p["moe"], h, expert_sharding=es)
    else:
        y = L.apply_mlp(cfg, p["mlp"], h)
    return x + y, cache


# --------------------------------------------------------------------------
# Rope helpers
# --------------------------------------------------------------------------
def make_rope(cfg: ArchConfig, positions: jax.Array):
    """positions: [B,S] (rope) or [3,B,S] (mrope).  Returns (cos, sin) or None."""
    if cfg.rope_kind == "rope":
        return L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    if cfg.rope_kind == "mrope":
        if positions.ndim == 2:  # text-only: t=h=w
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return L.mrope_angles(positions, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
    return None


def make_rope_fn(cfg: ArchConfig):
    if cfg.rope_kind in ("rope", "mrope"):
        return lambda pos_b: make_rope(cfg, pos_b)
    return None


def _layer_windows(cfg: ArchConfig) -> list[int | None]:
    """Per-scan-unit attention windows (gemma2 alternates local/global)."""
    if cfg.layer_pattern:
        return [cfg.sliding_window if kind == "local" else None
                for kind in cfg.layer_pattern]
    return [cfg.sliding_window]


# --------------------------------------------------------------------------
# Model bundle
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[..., Any]          # (params, batch, ac=..., want_cache=False)
    decode_step: Callable[..., Any]      # (params, batch, caches, ac=...)
    init_caches: Callable[..., Any]      # (batch_size, capacity)
    scan_info: dict                      # cost scopes: {"layer_trip": L, ...}

    def loss(self, params, batch, ac=_identity_ac, unroll=False):
        logits, aux, _ = self.forward(params, batch, ac=ac, unroll=unroll)
        labels = batch.get("labels")
        if labels is None:
            labels = batch["tokens"][:, 1:]
            logits = logits[:, :-1]
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll) + aux


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return _build_decoder_lm(cfg)
    if cfg.family == "ssm":
        return _build_rwkv(cfg)
    if cfg.family == "hybrid":
        return _build_zamba(cfg)
    if cfg.family == "audio":
        return _build_whisper(cfg)
    raise ValueError(cfg.family)


def _stack_init(init_one: Callable, rng: jax.Array, n: int):
    keys = jax.random.split(rng, n)
    return jax.vmap(init_one)(keys)


# ======================================================================
# Dense / MoE / VLM decoder-only LM
# ======================================================================
def _build_decoder_lm(cfg: ArchConfig) -> Model:
    pattern = cfg.layer_pattern or ("layer",)
    per_unit = len(pattern)
    assert cfg.n_layers % per_unit == 0
    trip = cfg.n_layers // per_unit
    windows = _layer_windows(cfg)

    def init(rng):
        k_e, k_b, k_u = jax.random.split(rng, 3)

        def init_unit(k):
            ks = jax.random.split(k, per_unit)
            return tuple(init_tf_block(cfg, ks[i]) for i in range(per_unit))

        p = {
            "embed": L.init_embedding(cfg, k_e),
            "blocks": _stack_init(init_unit, k_b, trip),
            "final_norm": L.init_norm(cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = (jax.random.normal(k_u, (cfg.d_model, cfg.vocab),
                                              jnp.dtype(cfg.param_dtype)) * 0.02)
        return p

    def _embed_in(params, batch):
        if "embeds" in batch:
            return batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
        return L.embed_tokens(cfg, params["embed"], batch["tokens"])

    def _unembed_out(params, x):
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        return L.unembed(cfg, w, x)

    def forward(params, batch, ac=_identity_ac, want_cache=False, remat=True,
                unroll=False):
        x = ac(_embed_in(params, batch), "hidden")
        B, S, _ = x.shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        rope = make_rope(cfg, positions)

        def unit(x, unit_params):
            aux = jnp.float32(0)
            kvs = []
            for i in range(per_unit):
                if want_cache:
                    x, a, kv = apply_tf_block_prefill(
                        cfg, unit_params[i], x, rope=rope, window=windows[i], ac=ac)
                    kvs.append(kv)
                else:
                    x, a = apply_tf_block(cfg, unit_params[i], x,
                                          rope=rope, window=windows[i], ac=ac)
                aux = aux + a
            return x, aux, tuple(kvs)

        unit_fn = jax.checkpoint(unit) if (remat and not want_cache) else unit

        def body(carry, unit_params):
            x, aux = carry
            x, a, kvs = unit_fn(x, unit_params)
            return (x, aux + a), kvs

        (x, aux), kvs = scan_blocks(body, (x, jnp.float32(0)), params["blocks"], unroll)
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = ac(_unembed_out(params, x), "logits")
        caches = None
        if want_cache:
            caches = kvs  # tuple(per_unit) of (k,v) stacked [trip, B, S, KV, hd]
        return logits, aux / trip, caches

    def init_caches(batch_size, capacity):
        caps = [min(w, capacity) if w else capacity for w in windows]
        one = tuple(attn.init_attn_cache(cfg, batch_size, c) for c in caps)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (trip,) + a.shape), one)

    rope_fn = make_rope_fn(cfg)

    def decode_step(params, batch, caches, ac=_identity_ac, unroll=False):
        x = L.embed_tokens(cfg, params["embed"], batch["tokens"])  # [B,1,D]
        pos = batch["pos"]

        def body(x, scanned):
            unit_params, unit_cache = scanned
            new_caches = []
            for i in range(per_unit):
                x, c = apply_tf_block_decode(cfg, unit_params[i], x, unit_cache[i],
                                             pos, rope_fn=rope_fn, window=windows[i], ac=ac)
                new_caches.append(c)
            return x, tuple(new_caches)

        x, caches = scan_blocks(body, x, (params["blocks"], caches), unroll)
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = ac(_unembed_out(params, x), "logits")
        return logits, caches

    return Model(cfg, init, forward, decode_step, init_caches,
                 scan_info={"layer_trip": trip, "per_unit": per_unit})


# ======================================================================
# RWKV6 (attention-free SSM)
# ======================================================================
def _build_rwkv(cfg: ArchConfig) -> Model:
    trip = cfg.n_layers

    def init(rng):
        k_e, k_b, k_u = jax.random.split(rng, 3)

        def init_block(k):
            return {
                "ln1": L.init_norm(cfg, cfg.d_model),
                "ln2": L.init_norm(cfg, cfg.d_model),
                "body": rwkv6.init_rwkv_block(cfg, k),
            }

        return {
            "embed": L.init_embedding(cfg, k_e),
            "ln_in": L.init_norm(cfg, cfg.d_model),
            "blocks": _stack_init(init_block, k_b, trip),
            "final_norm": L.init_norm(cfg, cfg.d_model),
            "unembed": (jax.random.normal(k_u, (cfg.d_model, cfg.vocab),
                                          jnp.dtype(cfg.param_dtype)) * 0.02),
        }

    def _block(x, bp, state, ac):
        norms = (partial(L.apply_norm, cfg, bp["ln1"]), partial(L.apply_norm, cfg, bp["ln2"]))

        def apply_norm_i(norm, h):
            return norm(h)

        x, new_state = rwkv6.apply_rwkv_block(
            cfg, bp["body"], x,
            norms=(bp["ln1"], bp["ln2"]),
            apply_norm=lambda np_, h: L.apply_norm(cfg, np_, h),
            state=state)
        return ac(x, "hidden"), new_state

    def forward(params, batch, ac=_identity_ac, want_cache=False, remat=True,
                unroll=False):
        x = L.embed_tokens(cfg, params["embed"], batch["tokens"])
        x = ac(L.apply_norm(cfg, params["ln_in"], x), "hidden")

        blk = jax.checkpoint(_block, static_argnums=(3,)) if remat and not want_cache else _block

        def body(x, bp):
            x, st = blk(x, bp, None, ac)
            return x, st if want_cache else None

        x, states = scan_blocks(body, x, params["blocks"], unroll)
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = ac(x @ params["unembed"].astype(x.dtype), "logits")
        return logits, jnp.float32(0), states

    def init_caches(batch_size, capacity):
        one = rwkv6.rwkv_state_init(cfg, batch_size)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (trip,) + a.shape), one)

    def decode_step(params, batch, states, ac=_identity_ac, unroll=False):
        x = L.embed_tokens(cfg, params["embed"], batch["tokens"])
        x = L.apply_norm(cfg, params["ln_in"], x)

        def body(x, scanned):
            bp, st = scanned
            x, new_st = _block(x, bp, st, ac)
            return x, new_st

        x, states = scan_blocks(body, x, (params["blocks"], states), unroll)
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = x @ params["unembed"].astype(x.dtype)
        return logits, states

    return Model(cfg, init, forward, decode_step, init_caches,
                 scan_info={"layer_trip": trip, "per_unit": 1, "time_scan": True})


# ======================================================================
# Zamba2 hybrid: mamba2 backbone + ONE weight-shared attention block
# ======================================================================
def _build_zamba(cfg: ArchConfig) -> Model:
    every = cfg.hybrid.attn_every
    assert cfg.n_layers % every == 0
    trip = cfg.n_layers // every     # superblocks: `every` mamba layers + shared attn

    def init(rng):
        k_e, k_b, k_a, k_m, k_u = jax.random.split(rng, 5)

        def init_super(k):
            ks = jax.random.split(k, every)
            blocks = tuple({"ln": L.init_norm(cfg, cfg.d_model),
                            "body": mamba2.init_mamba_block(cfg, ks[i])}
                           for i in range(every))
            return blocks

        return {
            "embed": L.init_embedding(cfg, k_e),
            "supers": _stack_init(init_super, k_b, trip),
            "shared_attn": {
                "ln1": L.init_norm(cfg, cfg.d_model),
                "attn": attn.init_attention(cfg, k_a),
                "ln2": L.init_norm(cfg, cfg.d_model),
                "mlp": L.init_mlp(cfg, k_m, cfg.d_model, cfg.d_ff),
            },
            "final_norm": L.init_norm(cfg, cfg.d_model),
            "unembed": (jax.random.normal(k_u, (cfg.d_model, cfg.vocab),
                                          jnp.dtype(cfg.param_dtype)) * 0.02),
        }

    def _super_fwd(x, sp, shared, rope, ac, want_cache, states):
        new_states = []
        for i in range(every):
            st = None if states is None else jax.tree.map(lambda a: a[i], states["mamba"])
            h = L.apply_norm(cfg, sp[i]["ln"], x)
            y, ns = mamba2.apply_mamba_block(cfg, sp[i]["body"], h, st)
            x = ac(x + y, "hidden")
            new_states.append(ns)
        # shared attention block (weights shared across all superblocks)
        h = L.apply_norm(cfg, shared["ln1"], x)
        if want_cache:
            out, kv = attn.attention_ctx(cfg, shared["attn"], h, rope=rope,
                                         causal=True, return_kv=True)
        else:
            out = attn.attention_ctx(cfg, shared["attn"], h, rope=rope, causal=True)
            kv = None
        x = ac(x + out, "hidden")
        h = L.apply_norm(cfg, shared["ln2"], x)
        x = ac(x + L.apply_mlp(cfg, shared["mlp"], h), "hidden")
        mamba_stack = jax.tree.map(lambda *a: jnp.stack(a), *new_states)
        return x, mamba_stack, kv

    def forward(params, batch, ac=_identity_ac, want_cache=False, remat=True,
                unroll=False):
        x = ac(L.embed_tokens(cfg, params["embed"], batch["tokens"]), "hidden")
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        rope = make_rope(cfg, positions)
        shared = params["shared_attn"]

        fwd = _super_fwd
        if remat and not want_cache:
            fwd = jax.checkpoint(_super_fwd, static_argnums=(4, 5))

        def body(x, sp):
            x, mstack, kv = fwd(x, sp, shared, rope, ac, want_cache, None)
            return x, (mstack, kv) if want_cache else None

        x, collected = scan_blocks(body, x, params["supers"], unroll)
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = ac(x @ params["unembed"].astype(x.dtype), "logits")
        return logits, jnp.float32(0), collected

    def init_caches(batch_size, capacity):
        m_one = mamba2.mamba_state_init(cfg, batch_size)
        mamba = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (trip, every) + a.shape), m_one)
        a_one = attn.init_attn_cache(cfg, batch_size, capacity)
        attn_c = jax.tree.map(lambda a: jnp.broadcast_to(a, (trip,) + a.shape), a_one)
        return {"mamba": mamba, "attn": attn_c}

    rope_fn = make_rope_fn(cfg)

    def decode_step(params, batch, caches, ac=_identity_ac, unroll=False):
        x = L.embed_tokens(cfg, params["embed"], batch["tokens"])
        pos = batch["pos"]
        shared = params["shared_attn"]

        def body(x, scanned):
            sp, mamba_st, attn_c = scanned
            new_m = []
            for i in range(every):
                st = jax.tree.map(lambda a: a[i], mamba_st)
                h = L.apply_norm(cfg, sp[i]["ln"], x)
                y, ns = mamba2.apply_mamba_block(cfg, sp[i]["body"], h, st)
                x = x + y
                new_m.append(ns)
            h = L.apply_norm(cfg, shared["ln1"], x)
            out, attn_c = attn.attention_decode(cfg, shared["attn"], h, attn_c, pos,
                                                rope_fn=rope_fn)
            x = x + out
            h = L.apply_norm(cfg, shared["ln2"], x)
            x = x + L.apply_mlp(cfg, shared["mlp"], h)
            m_stack = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
            return x, (m_stack, attn_c)

        x, (mamba_new, attn_new) = scan_blocks(
            body, x, (params["supers"], caches["mamba"], caches["attn"]), unroll)
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = x @ params["unembed"].astype(x.dtype)
        return logits, {"mamba": mamba_new, "attn": attn_new}

    return Model(cfg, init, forward, decode_step, init_caches,
                 scan_info={"layer_trip": trip, "per_unit": every, "time_scan": True})


# ======================================================================
# Whisper (audio encoder-decoder, stubbed conv frontend)
# ======================================================================
def _build_whisper(cfg: ArchConfig) -> Model:
    enc_trip = cfg.encdec.n_encoder_layers
    dec_trip = cfg.n_layers
    # Learned decoder positions.  Whisper's real decoder caps at 448; the
    # assigned input shapes exercise the decoder structurally at up to 32k,
    # so the table is sized to cover them (noted in DESIGN.md §6).
    MAX_DEC_POS = 32768

    def init(rng):
        ks = jax.random.split(rng, 6)

        def init_enc_block(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": L.init_norm(cfg, cfg.d_model),
                "attn": attn.init_attention(cfg, k1),
                "ln2": L.init_norm(cfg, cfg.d_model),
                "mlp": L.init_mlp(cfg, k2, cfg.d_model, cfg.d_ff),
            }

        def init_dec_block(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "ln1": L.init_norm(cfg, cfg.d_model),
                "self_attn": attn.init_attention(cfg, k1),
                "ln_x": L.init_norm(cfg, cfg.d_model),
                "cross_attn": attn.init_attention(cfg, k2, cross=True),
                "ln2": L.init_norm(cfg, cfg.d_model),
                "mlp": L.init_mlp(cfg, k3, cfg.d_model, cfg.d_ff),
            }

        return {
            "enc_blocks": _stack_init(init_enc_block, ks[0], enc_trip),
            "enc_norm": L.init_norm(cfg, cfg.d_model),
            "embed": L.init_embedding(cfg, ks[1]),
            "dec_pos": (jax.random.normal(ks[2], (MAX_DEC_POS, cfg.d_model),
                                          jnp.dtype(cfg.param_dtype)) * 0.01),
            "dec_blocks": _stack_init(init_dec_block, ks[3], dec_trip),
            "dec_norm": L.init_norm(cfg, cfg.d_model),
        }

    def encode(params, feats, ac, unroll=False):
        cd = jnp.dtype(cfg.compute_dtype)
        x = feats.astype(cd)
        x = x + L.sinusoidal_embedding(x.shape[1], cfg.d_model).astype(cd)
        x = ac(x, "hidden")

        def body(x, bp):
            h = L.apply_norm(cfg, bp["ln1"], x)
            x = x + attn.attention_ctx(cfg, bp["attn"], h, rope=None, causal=False)
            h = L.apply_norm(cfg, bp["ln2"], x)
            return ac(x + L.apply_mlp(cfg, bp["mlp"], h), "hidden"), None

        x, _ = scan_blocks(body, x, params["enc_blocks"], unroll)
        return L.apply_norm(cfg, params["enc_norm"], x)

    def _dec_block(x, bp, enc_out, ac, want_cache):
        h = L.apply_norm(cfg, bp["ln1"], x)
        if want_cache:
            out, kv = attn.attention_ctx(cfg, bp["self_attn"], h, rope=None,
                                         causal=True, return_kv=True)
        else:
            out = attn.attention_ctx(cfg, bp["self_attn"], h, rope=None, causal=True)
            kv = None
        x = x + out
        h = L.apply_norm(cfg, bp["ln_x"], x)
        if want_cache:
            out, cross_kv = attn.attention_ctx(cfg, bp["cross_attn"], h, rope=None,
                                               causal=False, kv_x=enc_out, return_kv=True)
        else:
            out = attn.attention_ctx(cfg, bp["cross_attn"], h, rope=None,
                                     causal=False, kv_x=enc_out)
            cross_kv = None
        x = ac(x + out, "hidden")
        h = L.apply_norm(cfg, bp["ln2"], x)
        x = ac(x + L.apply_mlp(cfg, bp["mlp"], h), "hidden")
        return x, kv, cross_kv

    def forward(params, batch, ac=_identity_ac, want_cache=False, remat=True,
                unroll=False):
        enc_out = encode(params, batch["encoder_feats"], ac, unroll)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens)
        x = x + params["dec_pos"][:S].astype(x.dtype)
        x = ac(x, "hidden")

        blk = _dec_block
        if remat and not want_cache:
            blk = jax.checkpoint(_dec_block, static_argnums=(3, 4))

        def body(x, bp):
            x, kv, cross_kv = blk(x, bp, enc_out, ac, want_cache)
            return x, (kv, cross_kv) if want_cache else None

        x, caches = scan_blocks(body, x, params["dec_blocks"], unroll)
        x = L.apply_norm(cfg, params["dec_norm"], x)
        logits = ac(x @ params["embed"].T.astype(x.dtype), "logits")
        return logits, jnp.float32(0), caches

    def init_caches(batch_size, capacity):
        self_c = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (dec_trip,) + a.shape),
            attn.init_attn_cache(cfg, batch_size, capacity))
        cd = jnp.dtype(cfg.compute_dtype)
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        enc_s = cfg.encdec.encoder_seq
        cross = {
            "k": jnp.zeros((dec_trip, batch_size, enc_s, KV, hd), cd),
            "v": jnp.zeros((dec_trip, batch_size, enc_s, KV, hd), cd),
        }
        return {"self": self_c, "cross": cross}

    def decode_step(params, batch, caches, ac=_identity_ac, unroll=False):
        tokens = batch["tokens"]
        pos = batch["pos"]
        B = tokens.shape[0]
        x = L.embed_tokens(cfg, params["embed"], tokens)
        x = x + jnp.take(params["dec_pos"],
                         jnp.minimum(pos, MAX_DEC_POS - 1)[None], axis=0)[None].astype(x.dtype)

        def body(x, scanned):
            bp, self_c, cross_k, cross_v = scanned
            h = L.apply_norm(cfg, bp["ln1"], x)
            out, self_c = attn.attention_decode(cfg, bp["self_attn"], h, self_c, pos)
            x = x + out
            # cross attention against precomputed encoder K/V
            h = L.apply_norm(cfg, bp["ln_x"], x)
            H, KVh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            cd = x.dtype
            q = (h @ bp["cross_attn"]["wq"].astype(cd))
            if cfg.qkv_bias:
                q = q + bp["cross_attn"]["bq"].astype(cd)
            q = q.reshape(B, 1, KVh, H // KVh, hd)
            import numpy as _np
            scores = jnp.einsum("bckgd,bskd->bkgcs", q, cross_k) / _np.sqrt(hd)
            w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(cd)
            out = jnp.einsum("bkgcs,bskd->bckgd", w, cross_v)
            out = out.reshape(B, 1, H * hd) @ bp["cross_attn"]["wo"].astype(cd)
            x = x + out
            h = L.apply_norm(cfg, bp["ln2"], x)
            x = x + L.apply_mlp(cfg, bp["mlp"], h)
            return x, self_c

        x, self_new = scan_blocks(
            body, x, (params["dec_blocks"], caches["self"],
                      caches["cross"]["k"], caches["cross"]["v"]), unroll)
        x = L.apply_norm(cfg, params["dec_norm"], x)
        logits = x @ params["embed"].T.astype(x.dtype)
        return logits, {"self": self_new, "cross": caches["cross"]}

    return Model(cfg, init, forward, decode_step, init_caches,
                 scan_info={"layer_trip": dec_trip, "per_unit": 1,
                            "enc_trip": enc_trip})
