"""RWKV-6 "Finch" block: data-dependent-decay linear attention (time-mix)
plus channel-mix, per arXiv:2404.05892.

Faithfulness notes (recorded in DESIGN.md):
* The recurrence, data-dependent decay ``w = exp(-exp(w0 + lora(x)))``,
  per-head bonus ``u``, and squared-ReLU channel-mix match the paper.
* Token-shift uses static interpolation weights (the paper's ddlerp LoRA on
  the shift mix is omitted — a parameter-count detail, not a systems one).

State per layer: S [B, n_heads, head, head] — O(1) in sequence length, which
is why rwkv6 runs the 524288-token decode shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

LORA_RANK = 64


def init_rwkv_block(cfg: ArchConfig, rng: jax.Array) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    D, F = cfg.d_model, cfg.d_ff
    hs = cfg.ssm.head_size
    nh = D // hs
    ks = jax.random.split(rng, 10)
    s = 1.0 / np.sqrt(D)
    return {
        "tm": {  # time-mix
            "mu": jnp.full((5, D), 0.5, pd),  # r,k,v,g,w shift mixes
            "w0": jnp.full((D,), -6.0, pd),
            "wA": jax.random.normal(ks[0], (D, LORA_RANK), pd) * s,
            "wB": jax.random.normal(ks[1], (LORA_RANK, D), pd) * (1.0 / np.sqrt(LORA_RANK)),
            "wr": jax.random.normal(ks[2], (D, D), pd) * s,
            "wk": jax.random.normal(ks[3], (D, D), pd) * s,
            "wv": jax.random.normal(ks[4], (D, D), pd) * s,
            "wg": jax.random.normal(ks[5], (D, D), pd) * s,
            "wo": jax.random.normal(ks[6], (D, D), pd) * s,
            "u": jnp.zeros((nh, hs), pd),
            "ln_scale": jnp.ones((D,), pd),
        },
        "cm": {  # channel-mix
            "mu": jnp.full((2, D), 0.5, pd),  # k, r
            "wk": jax.random.normal(ks[7], (D, F), pd) * s,
            "wv": jax.random.normal(ks[8], (F, D), pd) * (1.0 / np.sqrt(F)),
            "wr": jax.random.normal(ks[9], (D, D), pd) * s,
        },
    }


def _shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """Token shift: x[t-1] (zeros / carried state at t=0).  x: [B,T,D]."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv_state_init(cfg: ArchConfig, batch: int) -> dict:
    D = cfg.d_model
    hs = cfg.ssm.head_size
    nh = D // hs
    f32 = jnp.float32
    return {
        "S": jnp.zeros((batch, nh, hs, hs), f32),
        "x_tm": jnp.zeros((batch, 1, D), jnp.dtype(cfg.compute_dtype)),
        "x_cm": jnp.zeros((batch, 1, D), jnp.dtype(cfg.compute_dtype)),
    }


def _wkv_step(S, r_t, k_t, v_t, w_t, u):
    """One recurrence step.  S [B,nh,hs,hs]; r/k/v/w [B,nh,hs]; u [nh,hs].

    y_t = r · (S + u ⊙ kᵀv);  S' = diag(w) S + kᵀ v
    """
    kv = k_t[..., :, None] * v_t[..., None, :]           # [B,nh,hs,hs]
    y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., :, None] * kv)
    S = w_t[..., :, None] * S + kv
    return S, y


def time_mix(cfg: ArchConfig, p: dict, x: jax.Array, state: dict | None = None):
    """x [B,T,D] -> (y [B,T,D], new_state).  state=None => zero init (train)."""
    B, T, D = x.shape
    hs = cfg.ssm.head_size
    nh = D // hs
    cd = x.dtype
    prev_x = None if state is None else state["x_tm"]
    xs = _shift(x, prev_x)
    mu = p["mu"].astype(cd)
    xr, xk, xv, xg, xw = (x + mu[i] * (xs - x) for i in range(5))
    r = (xr @ p["wr"].astype(cd)).reshape(B, T, nh, hs)
    k = (xk @ p["wk"].astype(cd)).reshape(B, T, nh, hs)
    v = (xv @ p["wv"].astype(cd)).reshape(B, T, nh, hs)
    g = jax.nn.silu(xg @ p["wg"].astype(cd))
    # data-dependent decay (the Finch contribution)
    lora = jnp.tanh(xw @ p["wA"].astype(cd)) @ p["wB"].astype(cd)
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))))
    w = w.reshape(B, T, nh, hs)

    S0 = (jnp.zeros((B, nh, hs, hs), jnp.float32) if state is None else state["S"])
    u = p["u"].astype(jnp.float32)

    def body(S, inp):
        r_t, k_t, v_t, w_t = inp
        S, y = _wkv_step(S, r_t.astype(jnp.float32), k_t.astype(jnp.float32),
                         v_t.astype(jnp.float32), w_t, u)
        return S, y

    from .mamba2 import chunked_time_scan
    seq = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    S, ys = chunked_time_scan(body, S0, seq)
    y = jnp.moveaxis(ys, 0, 1).astype(cd)                 # [B,T,nh,hs]

    # per-head group norm
    yf = y.astype(jnp.float32)
    mean = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = ((yf - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(B, T, D)
    yn = (yn * p["ln_scale"].astype(jnp.float32)).astype(cd)
    out = (yn * g) @ p["wo"].astype(cd)
    new_state = {"S": S, "x_tm": x[:, -1:], "x_cm": None}
    return out, new_state


def channel_mix(cfg: ArchConfig, p: dict, x: jax.Array, state: dict | None = None):
    cd = x.dtype
    prev = None if state is None else state["x_cm"]
    xs = _shift(x, prev)
    mu = p["mu"].astype(cd)
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(cd)))
    r = jax.nn.sigmoid(xr @ p["wr"].astype(cd))
    return r * (k @ p["wv"].astype(cd)), x[:, -1:]


def apply_rwkv_block(cfg: ArchConfig, p: dict, x: jax.Array, norms: tuple,
                     apply_norm, state: dict | None = None):
    """Pre-norm residual block: time-mix + channel-mix."""
    n1, n2 = norms
    tm_out, new_state = time_mix(cfg, p["tm"], apply_norm(n1, x), state)
    x = x + tm_out
    cm_out, x_cm_last = channel_mix(cfg, p["cm"], apply_norm(n2, x), state)
    x = x + cm_out
    new_state["x_cm"] = x_cm_last
    return x, new_state
