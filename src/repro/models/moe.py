"""Mixture-of-Experts with sort-based capacity dispatch.

Design notes (DESIGN.md §6/§7):
* Dispatch is gather/scatter-based (argsort by expert id + per-expert
  capacity buffer), NOT a dense [T, E, C] one-hot einsum — so the compiled
  FLOPs equal the *active* expert FLOPs, keeping the roofline honest.
* The expert buffer [E, C, D] carries a sharding constraint that places the
  expert axis on the 'model' mesh axis when divisible (expert parallelism):
  GSPMD then materializes the token exchange as all-to-all — exactly the
  collective the paper optimizes (swap/b2b for latency-bound sizes, §4.3).
  When E < mesh width (mixtral: 8 experts on 16 chips), the expert FFN
  hidden dim is sharded instead (tensor-parallel experts).
* Every token keeps its top-k weights; tokens over capacity are dropped
  (capacity_factor 1.25), as in Switch/GShard-style systems.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def init_moe(cfg: ArchConfig, rng: jax.Array) -> dict:
    assert cfg.moe is not None
    pd = jnp.dtype(cfg.param_dtype)
    D, E, F = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_ff_expert
    ks = jax.random.split(rng, 4)
    s_in, s_out = 1.0 / np.sqrt(D), 1.0 / np.sqrt(F)
    return {
        "router": jax.random.normal(ks[0], (D, E), pd) * s_in,
        "wg": jax.random.normal(ks[1], (E, D, F), pd) * s_in,
        "wu": jax.random.normal(ks[2], (E, D, F), pd) * s_in,
        "wd": jax.random.normal(ks[3], (E, F, D), pd) * s_out,
    }


def capacity_for(cfg: ArchConfig, n_tokens: int) -> int:
    m = cfg.moe
    cap = int(np.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    # large capacities round to 128 so the capacity axis is shardable over
    # the DP mesh axes (16 or 32) — see sharding.rules 'expert' kind.
    mult = 128 if cap >= 128 else 8
    return max(8, int(np.ceil(cap / mult)) * mult)


def apply_moe(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,                      # [B, S, D]
    *,
    expert_sharding=None,              # optional fn: buffer [E,C,D/F] -> constrained
) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,D], router aux loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    C = capacity_for(cfg, T)
    cd = x.dtype

    xf = x.reshape(T, D)
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, K)                              # [T, K]
    topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch-style) ----
    me = jnp.mean(probs, axis=0)                                          # [E]
    assign = jnp.zeros((E,), jnp.float32).at[topk_e.reshape(-1)].add(1.0)
    ce = assign / (T * K)
    aux = E * jnp.sum(me * ce) * m.router_aux_weight

    # ---- sort-based dispatch ----
    flat_e = topk_e.reshape(-1)                                           # [T*K]
    order = jnp.argsort(flat_e)                                           # [T*K]
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    pos = jnp.arange(T * K, dtype=jnp.int32) - group_start[sorted_e].astype(jnp.int32)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)                                       # C == drop slot
    token_of = (order // K).astype(jnp.int32)

    buf = jnp.zeros((E, C, D), cd)
    buf = buf.at[sorted_e, pos_c].set(xf[token_of], mode="drop")
    if expert_sharding is not None:
        buf = expert_sharding(buf)

    # ---- expert FFNs: active-FLOP einsum over the capacity buffer ----
    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(cd))
    h = jax.nn.silu(h) * u
    if expert_sharding is not None:
        h = expert_sharding(h)
    y = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(cd))
    if expert_sharding is not None:
        y = expert_sharding(y)

    # ---- combine: gather back + weighted sum over k ----
    contrib = y[sorted_e, pos_c] * keep[:, None].astype(cd)               # [T*K, D]
    weights = topk_p.reshape(-1)[order].astype(cd)
    out = jnp.zeros((T, D), cd).at[token_of].add(contrib * weights[:, None])
    return out.reshape(B, S, D), aux
