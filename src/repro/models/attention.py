"""GQA attention: full (train/prefill, query-chunked for long sequences),
decode (one token against a — possibly rolling/sliding-window — KV cache),
and cross-attention (whisper).  Pure JAX; the Pallas decode kernel in
``repro/kernels/decode_attention`` implements the same math for the paged
serving path and is validated against this reference."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from .layers import apply_rotary, softcap

# Sequences longer than this use the query-chunked path (bounds the
# materialized [*, chunk, S] score block instead of [*, S, S]).
CHUNK_THRESHOLD = 2048
Q_CHUNK = 512


class dense_attention_for_costing:
    """Context manager: disable query chunking so the roofline costing pass
    sees attention FLOPs/bytes exactly once (a chunk scan body is counted
    once by XLA cost analysis regardless of trip count)."""

    def __enter__(self):
        global CHUNK_THRESHOLD
        self._old = CHUNK_THRESHOLD
        CHUNK_THRESHOLD = 1 << 62
        return self

    def __exit__(self, *exc):
        global CHUNK_THRESHOLD
        CHUNK_THRESHOLD = self._old
        return False


def init_attention(cfg: ArchConfig, rng: jax.Array, *, cross: bool = False) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    s = 1.0 / np.sqrt(D)
    p = {
        "wq": jax.random.normal(ks[0], (D, H * hd), pd) * s,
        "wk": jax.random.normal(ks[1], (D, KV * hd), pd) * s,
        "wv": jax.random.normal(ks[2], (D, KV * hd), pd) * s,
        "wo": jax.random.normal(ks[3], (H * hd, D), pd) * (1.0 / np.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), pd)
        p["bk"] = jnp.zeros((KV * hd,), pd)
        p["bv"] = jnp.zeros((KV * hd,), pd)
    return p


def _project_qkv(cfg: ArchConfig, p: dict, x: jax.Array, kv_x: jax.Array | None = None):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = x.dtype
    src = x if kv_x is None else kv_x
    Skv = src.shape[1]
    q = x @ p["wq"].astype(cd)
    k = src @ p["wk"].astype(cd)
    v = src @ p["wv"].astype(cd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return (
        q.reshape(B, S, KV, H // KV, hd),
        k.reshape(B, Skv, KV, hd),
        v.reshape(B, Skv, KV, hd),
    )


def _attn_scores_block(
    q: jax.Array,        # [B, C, KV, G, hd]
    k: jax.Array,        # [B, S, KV, hd]
    v: jax.Array,        # [B, S, KV, hd]
    q_pos: jax.Array,    # [C] int32 (query absolute positions)
    k_pos: jax.Array,    # [S] int32
    *,
    head_dim: int,
    causal: bool,
    window: int | None,
    cap: float | None,
) -> jax.Array:
    """Dense attention of one query block against the full K/V. [B,C,KV,G,hd]."""
    scores = jnp.einsum("bckgd,bskd->bkgcs", q, k) / np.sqrt(head_dim)
    scores = softcap(scores, cap)
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask = mask & (q_pos[:, None] >= k_pos[None, :])
    if window is not None:
        mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgcs,bskd->bckgd", w, v)


def attention_ctx(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,
    *,
    rope: tuple[jax.Array, jax.Array] | None,
    causal: bool = True,
    window: int | None = None,
    kv_x: jax.Array | None = None,
    q_chunk: int = Q_CHUNK,
    return_kv: bool = False,
):
    """Full-context attention (train / prefill / encoder / cross).

    Long sequences are processed in query chunks via ``lax.scan`` so the
    materialized score block is [*, chunk, S] (see DESIGN.md §7 for the
    cost-scope implication).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(cfg, p, x, kv_x)
    Skv = k.shape[1]
    if rope is not None:
        cos, sin = rope
        qf = q.reshape(B, S, H, hd)
        qf = apply_rotary(qf, cos, sin)
        q = qf.reshape(B, S, KV, H // KV, hd)
        k = apply_rotary(k, cos, sin)
    q_pos = jnp.arange(S, dtype=jnp.int32)
    k_pos = jnp.arange(Skv, dtype=jnp.int32)
    block = partial(_attn_scores_block, head_dim=hd, causal=causal, window=window,
                    cap=cfg.attn_softcap)

    if S <= CHUNK_THRESHOLD or S % q_chunk != 0:
        out = block(q, k, v, q_pos, k_pos)
    else:
        n_chunks = S // q_chunk
        qc = jnp.moveaxis(q.reshape(B, n_chunks, q_chunk, KV, H // KV, hd), 1, 0)
        pc = q_pos.reshape(n_chunks, q_chunk)

        def body(_, inp):
            qb, pb = inp
            return None, block(qb, k, v, pb, k_pos)

        _, outc = jax.lax.scan(body, None, (qc, pc))
        out = jnp.moveaxis(outc, 0, 1).reshape(B, S, KV, H // KV, hd)

    out = out.reshape(B, S, H * hd) @ p["wo"].astype(x.dtype)
    if return_kv:
        return out, (k, v)
    return out


# ------------------------------------------------------------- KV cache ----
@dataclasses.dataclass(frozen=True)
class CacheSpec:
    capacity: int


def init_attn_cache(cfg: ArchConfig, batch: int, capacity: int) -> dict:
    cd = jnp.dtype(cfg.compute_dtype)
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, capacity, KV, hd), cd),
        "v": jnp.zeros((batch, capacity, KV, hd), cd),
        "kpos": jnp.full((capacity,), -1, jnp.int32),
    }


def prefill_cache(cfg: ArchConfig, k: jax.Array, v: jax.Array, capacity: int) -> dict:
    """Build a cache from prefill K/V [B, S, KV, hd] (S <= capacity; for a
    sliding-window cache, capacity = window and the tail of the sequence is
    kept)."""
    B, S = k.shape[:2]
    if S > capacity:          # rolling window: keep last `capacity` tokens
        k = k[:, S - capacity:]
        v = v[:, S - capacity:]
        kpos = jnp.arange(S - capacity, S, dtype=jnp.int32)
        # slot layout must match pos % capacity
        slots = kpos % capacity
        order = jnp.argsort(slots)
        k, v, kpos = k[:, order], v[:, order], kpos[order]
    else:
        pad = capacity - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                                jnp.full((pad,), -1, jnp.int32)])
    return {"k": k, "v": v, "kpos": kpos}


def attention_decode(
    cfg: ArchConfig,
    p: dict,
    x: jax.Array,          # [B, 1, D] — ONE new token
    cache: dict,
    pos: jax.Array,        # scalar int32: absolute position of the new token
    *,
    rope_fn=None,          # positions -> (cos, sin) for a [B,1] position
    window: int | None = None,
) -> tuple[jax.Array, dict]:
    """Single-token decode against a (rolling) KV cache."""
    B, _, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cap = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(cfg, p, x)
    if rope_fn is not None:
        pos_b = jnp.broadcast_to(pos, (B, 1))
        cos_q, sin_q = rope_fn(pos_b)
        qf = apply_rotary(q.reshape(B, 1, H, hd), cos_q, sin_q)
        q = qf.reshape(B, 1, KV, H // KV, hd)
        k_new = apply_rotary(k_new, cos_q, sin_q)

    slot = (pos % cap).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    kpos = jax.lax.dynamic_update_slice(cache["kpos"], pos[None].astype(jnp.int32), (slot,))

    scores = jnp.einsum("bckgd,bskd->bkgcs", q, k) / np.sqrt(hd)
    scores = softcap(scores, cfg.attn_softcap)
    valid = (kpos >= 0) & (kpos <= pos)
    if window is not None:
        valid = valid & (pos - kpos < window)
    scores = jnp.where(valid[None, None, None, None, :], scores.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgcs,bskd->bckgd", w, v)
    out = out.reshape(B, 1, H * hd) @ p["wo"].astype(x.dtype)
    return out, {"k": k, "v": v, "kpos": kpos}
