"""Shared neural-net layers: norms, rotary embeddings (RoPE / M-RoPE /
sinusoidal), gated MLPs, embeddings.  Pure-functional: params are nested
dicts of arrays, every ``apply`` is jit-safe."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------- norms ----
def init_norm(cfg: ArchConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), _dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), _dtype(cfg.param_dtype))
    return p


def apply_norm(cfg: ArchConfig, p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ------------------------------------------------------------- rotaries ----
def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions [..., S] -> cos/sin [..., S, head_dim/2] (float32)."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions: jax.Array, head_dim: int, theta: float,
                 sections: tuple[int, ...]) -> tuple[jax.Array, jax.Array]:
    """M-RoPE (Qwen2-VL): positions [3, B, S] (t/h/w); frequency bands are
    split into ``sections`` (sum = head_dim/2), each band rotated by its own
    position stream.  For text tokens the three streams coincide with the
    1-D position, recovering vanilla RoPE."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # per-band position selector
    band = np.concatenate([np.full((s,), i) for i, s in enumerate(sections)])
    band = jnp.asarray(band)  # [half] in {0,1,2}
    pos = positions.astype(jnp.float32)            # [3, B, S]
    pos_per_freq = jnp.take(pos, band, axis=0)     # [half, B, S]
    ang = jnp.moveaxis(pos_per_freq, 0, -1) * inv_freq  # [B, S, half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [B, S, H, hd]; cos/sin [B, S, hd/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def sinusoidal_embedding(seq: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal positional embedding [seq, d]."""
    half = d // 2
    inv = np.exp(-np.log(10000.0) / (half - 1) * np.arange(half))
    ang = np.arange(seq)[:, None] * inv[None, :]
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=1)
    return jnp.asarray(emb, dtype=jnp.float32)


# ----------------------------------------------------------------- MLPs ----
def init_mlp(cfg: ArchConfig, rng: jax.Array, d: int, f: int) -> dict:
    pd = _dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(rng, 3)
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(f)
    if cfg.act == "silu":
        return {
            "wg": jax.random.normal(k1, (d, f), pd) * scale_in,
            "wu": jax.random.normal(k2, (d, f), pd) * scale_in,
            "wd": jax.random.normal(k3, (f, d), pd) * scale_out,
        }
    return {
        "wu": jax.random.normal(k1, (d, f), pd) * scale_in,
        "bu": jnp.zeros((f,), pd),
        "wd": jax.random.normal(k2, (f, d), pd) * scale_out,
        "bd": jnp.zeros((d,), pd),
    }


def apply_mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    cd = x.dtype
    if cfg.act == "silu":
        g = x @ p["wg"].astype(cd)
        u = x @ p["wu"].astype(cd)
        return (jax.nn.silu(g) * u) @ p["wd"].astype(cd)
    h = jax.nn.gelu(x @ p["wu"].astype(cd) + p["bu"].astype(cd))
    return h @ p["wd"].astype(cd) + p["bd"].astype(cd)


# ----------------------------------------------------------- embeddings ----
def init_embedding(cfg: ArchConfig, rng: jax.Array) -> jax.Array:
    pd = _dtype(cfg.param_dtype)
    return jax.random.normal(rng, (cfg.vocab, cfg.d_model), pd) * 0.02


def embed_tokens(cfg: ArchConfig, table: jax.Array, tokens: jax.Array) -> jax.Array:
    x = jnp.take(table, tokens, axis=0).astype(_dtype(cfg.compute_dtype))
    if cfg.family == "dense" and cfg.tie_embeddings and cfg.name.startswith("gemma2"):
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    return x


def unembed(cfg: ArchConfig, table_or_w: jax.Array, x: jax.Array) -> jax.Array:
    """Project to vocab; applies gemma2 final logit soft-capping."""
    logits = x @ table_or_w.astype(x.dtype)
    if cfg.final_softcap:
        c = jnp.asarray(cfg.final_softcap, logits.dtype)
        logits = c * jnp.tanh(logits / c)
    return logits


def softcap(scores: jax.Array, cap: float | None) -> jax.Array:
    if not cap:
        return scores
    c = jnp.asarray(cap, scores.dtype)
    return c * jnp.tanh(scores / c)
