from .transformer import Model, build_model, make_rope, make_rope_fn  # noqa: F401
