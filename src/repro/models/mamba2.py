"""Mamba2 (SSD) block per arXiv:2405.21060, as used by Zamba2's backbone.

Multi-head selective state space: per head h of size P=head_size with shared
state dimension N=state_size (ngroups=1):

    h_t = exp(dt_t · A_h) · h_{t-1} + dt_t · x_t ⊗ B_t
    y_t = h_t · C_t + D_h · x_t

with data-dependent (dt, B, C) projected from the input and a causal
depthwise conv on the x/B/C stream.  State is O(1) in sequence length, so
Zamba2 runs the 524288-token decode shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


TIME_CHUNK = 256


def chunked_time_scan(step_body, carry0, seq):
    """Time scan with gradient checkpointing every TIME_CHUNK steps.

    A flat scan stores its carry (the f32 SSM state) at EVERY step for AD —
    measured ~1 TB/device peak temp on zamba2 train_4k (§Perf iteration 6).
    Chunking stores one carry per chunk and recomputes inside the chunk on
    the backward pass — the Mamba2 paper's chunked-SSD memory discipline.
    ``seq`` leaves are time-major [T, ...].
    """
    T = jax.tree.leaves(seq)[0].shape[0]
    if T % TIME_CHUNK != 0 or T <= TIME_CHUNK:
        return jax.lax.scan(step_body, carry0, seq)
    n_chunks = T // TIME_CHUNK

    @jax.checkpoint
    def chunk_body(carry, chunk_seq):
        return jax.lax.scan(step_body, carry, chunk_seq)

    seq_c = jax.tree.map(
        lambda a: a.reshape((n_chunks, TIME_CHUNK) + a.shape[1:]), seq)
    carry, ys_c = jax.lax.scan(chunk_body, carry0, seq_c)
    ys = jax.tree.map(
        lambda a: a.reshape((T,) + a.shape[2:]), ys_c)
    return carry, ys


def dims(cfg: ArchConfig) -> tuple[int, int, int, int]:
    d_in = cfg.ssm.expand * cfg.d_model
    hs = cfg.ssm.head_size
    nh = d_in // hs
    return d_in, hs, nh, cfg.ssm.state_size


def init_mamba_block(cfg: ArchConfig, rng: jax.Array) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    D = cfg.d_model
    d_in, hs, nh, N = dims(cfg)
    K = cfg.ssm.conv_kernel
    ks = jax.random.split(rng, 3)
    s = 1.0 / np.sqrt(D)
    conv_dim = d_in + 2 * N
    return {
        # z (gate), x, B, C, dt
        "w_in": jax.random.normal(ks[0], (D, 2 * d_in + 2 * N + nh), pd) * s,
        "conv_w": jax.random.normal(ks[1], (K, conv_dim), pd) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(pd),
        "D_skip": jnp.ones((nh,), pd),
        "dt_bias": jnp.full((nh,), -4.0, pd),
        "norm_scale": jnp.ones((d_in,), pd),
        "w_out": jax.random.normal(ks[2], (d_in, D), pd) * (1.0 / np.sqrt(d_in)),
    }


def mamba_state_init(cfg: ArchConfig, batch: int) -> dict:
    d_in, hs, nh, N = dims(cfg)
    K = cfg.ssm.conv_kernel
    return {
        "h": jnp.zeros((batch, nh, hs, N), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, d_in + 2 * N), jnp.dtype(cfg.compute_dtype)),
    }


def _split_proj(cfg: ArchConfig, proj: jax.Array):
    d_in, hs, nh, N = dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(p: dict, xbc: jax.Array, conv_state: jax.Array | None):
    """Depthwise causal conv over time.  xbc [B,T,Cdim]."""
    K = p["conv_w"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[-1]), xbc.dtype)
    padded = jnp.concatenate([conv_state, xbc], axis=1)
    w = p["conv_w"].astype(xbc.dtype)                    # [K, Cdim]
    out = sum(padded[:, i:i + xbc.shape[1]] * w[i] for i in range(K))
    out = jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))
    return out, padded[:, -(K - 1):] if K > 1 else conv_state


def apply_mamba_block(cfg: ArchConfig, p: dict, x: jax.Array,
                      state: dict | None = None):
    """x [B,T,D] -> (y [B,T,D], new_state)."""
    B, T, D = x.shape
    d_in, hs, nh, N = dims(cfg)
    cd = x.dtype
    proj = x @ p["w_in"].astype(cd)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(p, xbc, None if state is None else state["conv"])
    xs, Bmat, Cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(B, T, nh, hs)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # [B,T,nh]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # [nh]
    decay = jnp.exp(dt * A)                               # [B,T,nh]

    h0 = (jnp.zeros((B, nh, hs, N), jnp.float32) if state is None else state["h"])

    def body(h, inp):
        x_t, B_t, C_t, dt_t, a_t = inp                    # [B,nh,hs],[B,N],[B,N],[B,nh],[B,nh]
        xb = (dt_t[..., None] * x_t.astype(jnp.float32))[..., None] * B_t.astype(jnp.float32)[:, None, None, :]
        h = a_t[..., None, None] * h + xb                 # [B,nh,hs,N]
        y = jnp.einsum("bhpn,bn->bhp", h, C_t.astype(jnp.float32))
        return h, y

    seq = (
        jnp.moveaxis(xs, 1, 0),
        jnp.moveaxis(Bmat, 1, 0),
        jnp.moveaxis(Cmat, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(decay, 1, 0),
    )
    h, ys = chunked_time_scan(body, h0, seq)
    y = jnp.moveaxis(ys, 0, 1)                            # [B,T,nh,hs] f32
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, T, d_in).astype(cd)

    # gated RMS norm + out projection
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    out = yf.astype(cd) @ p["w_out"].astype(cd)
    return out, {"h": h, "conv": conv_state}
