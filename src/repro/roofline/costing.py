"""Exact cost accounting for scanned programs.

XLA ``cost_analysis`` counts a while/scan body ONCE regardless of trip
count (measured: a 10-step scan of matmuls reports 1 matmul's flops), so
full-depth lowerings undercount by ~L.  We therefore lower depth-reduced
UNROLLED variants of each model (1 scan-unit and 2 scan-units per scan
stack) with dense (unchunked) attention and difference them:

    C(k units) = C_base + k * C_body    =>    C_body = C(2) - C(1)
    Total      = C_base + trip * C_body (per scan stack)

The SSM time scans (rwkv/mamba recurrence over seq_len steps) cannot be
unrolled at 32k steps; their per-step cost is tiny and closed-form, so an
analytic correction term ``(T-1) * step_cost * n_layers`` is added
(documented in EXPERIMENTS.md §Roofline methodology).

All metrics (flops, bytes, per-collective wire bytes) are PER-DEVICE (the
partitioned module's shapes are per-device).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

from repro.compat import cost_analysis_dict
from repro.configs import get_config, get_shape
from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.roofline.hlo_parse import wire_bytes_by_kind


@dataclasses.dataclass
class CostVector:
    flops: float = 0.0
    bytes: float = 0.0
    wire: dict | None = None

    def __post_init__(self):
        self.wire = dict(self.wire or {})

    @property
    def wire_total(self) -> float:
        return sum(self.wire.values())

    def __sub__(self, o: "CostVector") -> "CostVector":
        keys = set(self.wire) | set(o.wire)
        return CostVector(self.flops - o.flops, self.bytes - o.bytes,
                          {k: self.wire.get(k, 0) - o.wire.get(k, 0) for k in keys})

    def __add__(self, o: "CostVector") -> "CostVector":
        keys = set(self.wire) | set(o.wire)
        return CostVector(self.flops + o.flops, self.bytes + o.bytes,
                          {k: self.wire.get(k, 0) + o.wire.get(k, 0) for k in keys})

    def scaled(self, f: float) -> "CostVector":
        return CostVector(self.flops * f, self.bytes * f,
                          {k: v * f for k, v in self.wire.items()})

    def clamped(self) -> "CostVector":
        return CostVector(max(self.flops, 0.0), max(self.bytes, 0.0),
                          {k: max(v, 0.0) for k, v in self.wire.items()})


def _scan_axes(cfg: ArchConfig) -> list[tuple[str, int, Callable[[ArchConfig, int], ArchConfig]]]:
    """(name, full_trip, cfg_builder(k_units)) for every scan stack."""
    axes = []
    per_unit = len(cfg.layer_pattern) if cfg.layer_pattern else 1
    if cfg.hybrid:
        per_unit = cfg.hybrid.attn_every
    trip = cfg.n_layers // per_unit

    def set_layers(c: ArchConfig, k: int) -> ArchConfig:
        return dataclasses.replace(c, n_layers=k * per_unit)

    axes.append(("layers", trip, set_layers))
    if cfg.encdec:
        def set_enc(c: ArchConfig, k: int) -> ArchConfig:
            return dataclasses.replace(
                c, encdec=dataclasses.replace(c.encdec, n_encoder_layers=k))
        axes.append(("enc", cfg.encdec.n_encoder_layers, set_enc))
    return axes


def _measure(arch_id: str, shape_id: str, mesh, cfg: ArchConfig, perf=None) -> CostVector:
    from repro.launch.dryrun import build_step
    with attn_mod.dense_attention_for_costing():
        built, reason = build_step(arch_id, shape_id, mesh, cfg=cfg, unroll=True, perf=perf)
        if built is None:
            raise RuntimeError(f"skipped: {reason}")
        fn, args, in_sh, out_sh = built
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
    ca = cost_analysis_dict(compiled)
    wire = wire_bytes_by_kind(compiled.as_text())
    return CostVector(float(ca.get("flops", 0.0)),
                      float(ca.get("bytes accessed", 0.0)), wire)


def _ssm_correction(cfg: ArchConfig, shape, dp_size: int) -> CostVector:
    """Analytic (T-1)-step correction for time-scan recurrences (per device)."""
    if shape.mode == "decode" or not cfg.ssm:
        return CostVector()
    T = shape.seq_len
    b_loc = max(shape.global_batch // dp_size, 1)
    if cfg.ssm.kind == "rwkv6":
        hs = cfg.ssm.head_size
        step_flops = 6.0 * b_loc * cfg.d_model * hs
        state_bytes = 4.0 * b_loc * cfg.d_model * hs      # f32 S matrix
        n_scans = cfg.n_layers
    else:  # mamba2
        d_in = cfg.ssm.expand * cfg.d_model
        N = cfg.ssm.state_size
        step_flops = 7.0 * b_loc * d_in * N
        state_bytes = 4.0 * b_loc * d_in * N
        n_scans = cfg.n_layers
    per_layer = CostVector(step_flops, 3.0 * state_bytes, {})
    return per_layer.scaled((T - 1) * n_scans)


def total_cost(arch_id: str, shape_id: str, mesh, *, dp_size: int, perf=None) -> dict:
    """Per-device totals with exact scan scaling.  Returns dict with
    CostVector 'total' plus the measured points for the record."""
    cfg = get_config(arch_id)
    shape = get_shape(shape_id)
    axes = _scan_axes(cfg)

    base_cfg = cfg
    for name, trip, build in axes:
        base_cfg = build(base_cfg, 1)
    c0 = _measure(arch_id, shape_id, mesh, base_cfg, perf)

    total = c0
    bodies = {}
    for i, (name, trip, build) in enumerate(axes):
        cfg_i = base_cfg
        for j, (n2, t2, b2) in enumerate(axes):
            cfg_i = b2(cfg_i, 2 if j == i else 1)
        ci = _measure(arch_id, shape_id, mesh, cfg_i, perf)
        body = (ci - c0).clamped()
        bodies[name] = body
        total = total + body.scaled(trip - 1)

    corr = _ssm_correction(cfg, shape, dp_size)
    total = total + corr
    return {
        "total": total,
        "base": c0,
        "bodies": bodies,
        "ssm_correction": corr,
        "trips": {name: trip for name, trip, _ in axes},
    }
