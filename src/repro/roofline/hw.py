"""TPU v5e hardware constants for the roofline (system targets)."""

PEAK_BF16_FLOPS = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link per direction
ICI_LINKS_PER_CHIP = 4          # 2D torus

SINGLE_POD_CHIPS = 256
MULTI_POD_CHIPS = 512
