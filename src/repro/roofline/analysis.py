"""Three-term roofline per (arch x shape x mesh) from the dry-run artifacts.

    compute    = HLO_FLOPs / (chips x 197 TF/s bf16)
    memory     = HLO_bytes / (chips x 819 GB/s)
    collective = collective_bytes / (chips x 50 GB/s per link)

HLO terms come from the costing pass (per-device, scan-exact); the reported
seconds are per-device = global/chips for a balanced program.  MODEL_FLOPS
uses 6*N*D for training (2*N*D prefill; 2*N_active*B + KV-read term for
decode) — the utilization ratio MODEL/HLO catches remat & redundancy waste.
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config, get_shape
from repro.configs.base import ArchConfig, ShapeConfig
from . import hw
from .costing import CostVector


def _flops_params(cfg: ArchConfig) -> float:
    """Active parameters per token for FLOP purposes.  Zamba2's ONE shared
    attention block is stored once but EXECUTES n_layers/attn_every times."""
    n = cfg.n_active_params
    if cfg.hybrid:
        shared = (4 * cfg.d_model * cfg.n_heads * cfg.head_dim
                  + 3 * cfg.d_model * cfg.d_ff)
        n += (cfg.n_layers // cfg.hybrid.attn_every - 1) * shared
    return n


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Global useful FLOPs for this step (6ND train / 2ND prefill / decode)."""
    tokens = shape.global_batch * shape.seq_len
    n = _flops_params(cfg)
    if shape.mode == "train":
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        return 2.0 * n * tokens
    # decode: one token per sequence + KV-cache read math
    b = shape.global_batch
    flops = 2.0 * n * b
    if cfg.n_heads:
        ctx = shape.seq_len
        per_unit = len(cfg.layer_pattern) if cfg.layer_pattern else 1
        windows = ([cfg.sliding_window if k == "local" else None
                    for k in cfg.layer_pattern] if cfg.layer_pattern
                   else [cfg.sliding_window])
        n_attn_layers = (cfg.n_layers // cfg.hybrid.attn_every if cfg.hybrid
                         else cfg.n_layers)
        qk_dim = cfg.n_heads * cfg.head_dim
        per_layer = 0.0
        for w in windows:
            eff = min(w, ctx) if w else ctx
            per_layer += 4.0 * b * qk_dim * eff
        flops += n_attn_layers / len(windows) * per_layer
    if cfg.ssm:
        d_state = (cfg.d_model * cfg.ssm.head_size if cfg.ssm.kind == "rwkv6"
                   else cfg.ssm.expand * cfg.d_model * cfg.ssm.state_size)
        flops += 6.0 * b * d_state * cfg.n_layers
    return flops


_SUGGESTIONS = {
    "compute": ("compute-bound: raise MFU via better MXU tiling "
                "(128-aligned matmul dims), fewer recompute passes (remat "
                "policy), or lower-precision matmuls"),
    "memory": ("HBM-bound: fuse elementwise chains, keep activations in "
               "bf16, avoid materialized score/logit temporaries, increase "
               "arithmetic intensity per byte (larger per-chip tiles)"),
    "collective": ("ICI-bound: reshard to cut gather volume (move TP axis), "
                   "overlap collectives with compute (latte issue-ahead), or "
                   "use bidirectional/ring schedules across more links"),
}


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    wire_by_kind: dict
    suggestion: str

    def to_json(self):
        return dataclasses.asdict(self)


def make_row(arch_id: str, shape_id: str, mesh_name: str, chips: int,
             total: CostVector) -> RooflineRow:
    cfg = get_config(arch_id)
    shape = get_shape(shape_id)
    compute_s = total.flops / hw.PEAK_BF16_FLOPS
    memory_s = total.bytes / hw.HBM_BW
    collective_s = total.wire_total / hw.ICI_LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = total.flops * chips
    return RooflineRow(
        arch=arch_id, shape=shape_id, mesh=mesh_name, chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, hlo_flops_global=hlo_global,
        useful_ratio=mf / hlo_global if hlo_global else 0.0,
        wire_by_kind=dict(total.wire),
        suggestion=_SUGGESTIONS[dominant],
    )


def format_table(rows: list[RooflineRow]) -> str:
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "dominant | MODEL/HLO | note |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.compute_s*1e3:.3f} | "
            f"{r.memory_s*1e3:.3f} | {r.collective_s*1e3:.3f} | "
            f"**{r.dominant}** | {r.useful_ratio:.2f} | {r.suggestion.split(':')[0]} |")
    return "\n".join(lines)
