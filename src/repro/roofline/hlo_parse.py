"""Parse collective traffic out of compiled (SPMD-partitioned) HLO text.

Shapes in the partitioned module are PER-DEVICE, so the wire-byte estimates
below are per-device too.  Wire bytes per op (ring-algorithm accounting):

  all-gather        : out - in            (receives (n-1)/n of the result)
  reduce-scatter    : in - out
  all-reduce        : 2 * out             (ring RS + AG, upper bound)
  all-to-all        : out * (n-1)/n ~ out
  collective-permute: out
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    out_bytes: int
    in_bytes: int

    @property
    def wire_bytes(self) -> float:
        if self.kind == "all-gather":
            return max(self.out_bytes - self.in_bytes, 0)
        if self.kind == "reduce-scatter":
            return max(self.in_bytes - self.out_bytes, 0)
        if self.kind == "all-reduce":
            return 2.0 * self.out_bytes
        if self.kind == "all-to-all":
            return float(self.out_bytes)
        return float(self.out_bytes)   # collective-permute


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for m in _OP_RE.finditer(hlo_text):
        out_s, kind, operands = m.group(1), m.group(2), m.group(3)
        ops.append(CollectiveOp(kind, _shape_bytes(out_s), _shape_bytes(operands)))
    return ops


def wire_bytes_by_kind(hlo_text: str) -> dict[str, float]:
    acc: dict[str, float] = {}
    for op in parse_collectives(hlo_text):
        acc[op.kind] = acc.get(op.kind, 0.0) + op.wire_bytes
    return acc


def total_wire_bytes(hlo_text: str) -> float:
    return sum(wire_bytes_by_kind(hlo_text).values())
