"""Workload-level (LLM inference) performance composition for §5.3.

TTFT at 100% CPU-cache hit = KV fetch (host->device over PCIe, via the
calibrated DMA engine model) + one decode step (HBM-bound on MI300X) +
framework overhead.  Throughput overlaps fetch with model execution for the
optimized DMA path (free CUs) but serializes under CU contention for the
kernel path — the paper's §2.4 argument.

LLM specs are the public models the paper evaluates (Qwen2.5, Llama 3.x,
DeepSeek-R1-Distill-32B).
"""
from __future__ import annotations

import dataclasses

from .dma import kv_fetch_schedule, mi300x_platform, simulate
from .dma.rccl_model import kernel_copy_latency

MI300X_HBM_BW = 5.3e12          # bytes/s
BLOCK_TOKENS = 16
FRAMEWORK_OVERHEAD = 1.6e-3     # python/vLLM scheduler, per request
API_CALL_COST = 3.0e-6          # one hipMemcpyAsync call on the CPU
BATCH_API_COST = 100.0e-6        # one hipMemcpyBatchAsync call (setup+teardown)
N_BATCH_CALLS = 6               # b2b path issues a few batch calls per fetch
KERNEL_LAUNCH = 10.0e-6
KERNEL_WIRE_EFF = 0.90          # CU gather kernel PCIe efficiency
KERNEL_CONTENTION = 1.35        # CU fetch slows overlapped model compute (§2.4)


@dataclasses.dataclass(frozen=True)
class LLMSpec:
    name: str
    params_b: float          # billions
    n_layers: int
    n_kv_heads: int
    head_dim: int

    @property
    def kv_bytes_per_token(self) -> int:
        return self.n_layers * self.n_kv_heads * self.head_dim * 2 * 2  # K+V bf16


PAPER_LLMS = (
    LLMSpec("qwen2.5-0.5b", 0.5, 24, 2, 64),
    LLMSpec("llama3.2-1b", 1.2, 16, 8, 64),
    LLMSpec("qwen2.5-7b", 7.6, 28, 4, 128),
    LLMSpec("llama3.1-8b", 8.0, 32, 8, 128),
    LLMSpec("r1-distill-qwen-32b", 32.8, 64, 8, 128),
)


def fetch_time(spec: LLMSpec, prompt: int, backend: str) -> float:
    """Host->device KV fetch for `prompt` cached tokens.

    ``opt_b2b`` is the batched path with the optimized command stream
    (DESIGN.md §7/§8) — what the serving engine's ``kv_fetch_plan`` requests
    for the latte backend.
    """
    topo = mi300x_platform()
    n_blocks = (prompt + BLOCK_TOKENS - 1) // BLOCK_TOKENS
    block_bytes = spec.kv_bytes_per_token * BLOCK_TOKENS
    if backend == "kernel":
        wire = n_blocks * block_bytes / (topo.host_link_bw * KERNEL_WIRE_EFF)
        return KERNEL_LAUNCH + wire
    if backend == "pcpy":
        sched = kv_fetch_schedule(topo, n_blocks, block_bytes, "pcpy")
        # one hipMemcpyAsync per block, serialized on the host
        return simulate(sched, topo).latency + n_blocks * API_CALL_COST
    variant = "opt_prelaunch_b2b" if backend == "opt_b2b" else "prelaunch_b2b"
    sched = kv_fetch_schedule(topo, n_blocks, block_bytes, variant)
    return simulate(sched, topo).latency + N_BATCH_CALLS * BATCH_API_COST


def decode_step_time(spec: LLMSpec, batch: int = 1) -> float:
    """One decode step: weight-read bound (bf16 params over HBM)."""
    weight = spec.params_b * 1e9 * 2 / MI300X_HBM_BW
    return weight * max(1.0, 0.15 * batch)   # mild batch scaling


def ttft(spec: LLMSpec, prompt: int, backend: str) -> dict:
    """Returns gpu-side and total TTFT at 100% KV cache hit."""
    f = fetch_time(spec, prompt, backend)
    d = decode_step_time(spec)
    gpu = f + d
    total = gpu + FRAMEWORK_OVERHEAD
    return {"fetch": f, "decode": d, "gpu": gpu, "total": total}


def throughput(spec: LLMSpec, prompt: int, backend: str, *,
               hit_rate: float = 1.0, requests: int = 2000) -> float:
    """Steady-state tokens/s with many concurrent requests.

    Optimized DMA fetch overlaps with model execution (free CUs) ->
    pipeline is max(fetch, compute).  Baseline pcpy serializes most of its
    launch/sync overhead with execution; kernel fetch overlaps but slows
    compute via CU/cache contention.
    """
    f = fetch_time(spec, prompt, backend)
    batch = 32
    step = decode_step_time(spec, batch)
    exec_per_req = step * 24 / batch            # amortized decode of ~24 tokens
    miss_prefill = 2 * spec.params_b * 1e9 * prompt / 1.3e15 * (1 - hit_rate)
    if backend in ("b2b", "opt_b2b"):
        per_req = max(f, exec_per_req) + miss_prefill
    elif backend == "kernel":
        per_req = max(f, exec_per_req * KERNEL_CONTENTION) + miss_prefill
    else:  # pcpy: launch/sync storms serialize with execution
        per_req = 0.70 * f + exec_per_req + miss_prefill
    return 24.0 / per_req
