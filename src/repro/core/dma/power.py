"""GPU power model for collectives (paper §5.2.9, Fig. 15).

Total GPU power = idle + XCD (compute dies) + IOD (cache/links/DMA) + HBM
+ host (command scheduling/sync wakeups).

* CU (RCCL) collectives keep CUs spinning on packet loops -> high XCD power,
  scaled down at latency-bound sizes where CUs are mostly waiting.
* DMA collectives leave CUs idle (paper: ~3.7x less XCD power) and draw IOD
  power per engaged engine, so fewer engines (b2b) -> lower power, and bcst's
  single source read lowers HBM traffic -> additional HBM power savings.
* Optimized command streams (DESIGN.md §7/§8.4) price lower still: batched
  submission collapses host scheduling events (each a CPU-core wakeup,
  ``host_wakeup_j``) and fused write+signal skips the engine's atomic
  signal round-trip over the fabric (``atomic_j``) — the paper's 3-10%
  *additional* power saving at latency-bound sizes.  Both counts come from
  the event simulator (``SimResult.host_events``/``engine_atomics``), so
  ``dma_collective_power`` prices baseline and ``opt_`` schedules from the
  same formula.
"""
from __future__ import annotations

import dataclasses

from .engine import SimResult
from .topology import PowerCalibration, Topology


@dataclasses.dataclass(frozen=True)
class PowerReport:
    xcd: float
    iod: float
    hbm: float
    idle: float
    host: float = 0.0    # command scheduling + sync observation wakeups (§8.4)

    @property
    def total(self) -> float:
        return self.xcd + self.iod + self.hbm + self.idle + self.host


def _utilization(size: int, knee: float = 8e6) -> float:
    """How busy the mover is vs waiting on launch/sync (ramps with size)."""
    return size / (size + knee)


def dma_collective_power(
    topo: Topology,
    size: int,
    sim: SimResult,
    calib: PowerCalibration | None = None,
) -> PowerReport:
    c = calib or PowerCalibration()
    dev = max(sim.per_device, key=lambda d: sim.per_device[d].total)
    engines = sim.engines_used[dev]
    lat = max(sim.latency, 1e-9)
    # HBM traffic: local reads (tracked) + symmetric incoming writes.
    gbps = 2 * sim.hbm_bytes[dev] / lat / 1e9
    u = _utilization(size)
    # Link/SerDes power tracks the ACTUAL wire-busy intervals recorded by the
    # event simulator, not the nominal message size: an idle link waiting on
    # control/sync draws (almost) nothing.
    link_gbps = sim.link_busy_seconds(dev) / lat * topo.link_bw / 1e9
    # Host scheduling/sync wakeups and engine atomic round-trips (§8.4):
    # energy per event over the collective's duration.  Batched submission
    # (§7.1) collapses scheduling events, fused signals (§7.3) drop the
    # atomics — this term is where the optimized streams' 3-10% additional
    # saving comes from; baseline schedules pay one event per command.
    host_w = c.host_wakeup_j * sim.host_events.get(dev, 0) / lat
    atomic_w = c.atomic_j * sim.engine_atomics.get(dev, 0) / lat
    return PowerReport(
        xcd=c.xcd_dma_collective * (0.5 + 0.5 * u),
        iod=c.iod_per_engine * engines + c.link_per_busy_gbps * link_gbps + atomic_w,
        hbm=c.hbm_static + c.hbm_per_gbps * gbps,
        idle=c.idle,
        host=host_w,
    )


def cu_collective_power(
    topo: Topology,
    size: int,
    latency: float,
    calib: PowerCalibration | None = None,
    *,
    collective: str = "all_gather",
) -> PowerReport:
    c = calib or PowerCalibration()
    n = topo.n_devices
    shard = size / n
    # Per-device HBM payload of the CU packet loop, per collective: the
    # gather-style collectives read each outgoing shard once and write each
    # arrival once (2x per delivery — all_to_all moves n-1 *distinct*
    # per-peer shards but the same total bytes, so it prices identically);
    # the reduce collectives additionally read the local accumulator per
    # arrived chunk (2 reads + 1 write = 3x per delivery), and all_reduce
    # composes reduce-scatter + all-gather (3x + 2x).
    deliveries = n - 1
    if collective in ("all_gather", "all_to_all"):
        payload = 2 * shard * deliveries
    elif collective == "reduce_scatter":
        payload = 3 * shard * deliveries
    elif collective == "all_reduce":
        payload = 5 * shard * deliveries
    else:
        raise ValueError(
            f"unknown collective {collective!r} for the CU power model")
    gbps = c.cu_traffic_multiplier * payload / max(latency, 1e-9) / 1e9
    u = _utilization(size)
    xcd = c.xcd_cu_collective * (c.xcd_latency_scale + (1 - c.xcd_latency_scale) * u)
    return PowerReport(
        xcd=xcd,
        iod=c.iod_cu * (0.6 + 0.4 * u),
        hbm=c.hbm_static + c.hbm_per_gbps * gbps,
        idle=c.idle,
        # One kernel launch + one completion poll: the CU path schedules on
        # the GPU, not per-transfer on the host.
        host=2 * c.host_wakeup_j / max(latency, 1e-9),
    )
