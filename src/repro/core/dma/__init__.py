"""DMA-Latte core: command set, event-driven engine simulator, collective
schedules, optimized command-stream transforms, dispatch policy, RCCL
baseline and power models (the paper's contribution)."""
from . import commands
from .commands import (
    CmdKind,
    Command,
    EngineQueue,
    Schedule,
    chunk_command,
    chunk_schedule,
    chunk_sizes,
    chunk_tag,
    chunked_copies,
    chunked_reduces,
    link_traffic,
    reduce_work,
    tag_chunk,
    tag_name,
)
from .collectives import (
    PIPE_DEPTH,
    RS_VARIANTS,
    allgather_schedule,
    allreduce_schedule,
    alltoall_schedule,
    kv_fetch_schedule,
    reduce_scatter_schedule,
)
from .dispatch import (
    COLLECTIVE_BUILDERS,
    PAPER_AA_DISPATCH,
    PAPER_AG_DISPATCH,
    PERTURB_SCENARIOS,
    FragileEntry,
    RobustnessReport,
    best_variant_for,
    candidate_variants,
    derive_dispatch,
    dispatch_robustness,
    optimized_variants,
    paper_dispatch,
    perturbed_topology,
    pick_variant,
    pipelined_variants,
    reduce_variants,
    variant_latency,
)
from .faults import (
    BlockedWaiter,
    FaultPlan,
    FaultReport,
    LinkDerate,
    NicFlap,
    RetryRecord,
    SimFault,
    Straggler,
    straggler_plan,
)
from .engine import (
    ComposedResult,
    PhaseBreakdown,
    ScheduleOutcome,
    SimResult,
    run_composed,
    simulate,
    single_copy_breakdown,
)
from .optimizations import (
    OptimizationConfig,
    batch_commands,
    fuse_signals,
    optimize,
    parse_optimized,
    split_queues,
)
from .power import cu_collective_power, dma_collective_power
from .trace import (
    SimTrace,
    TraceFlow,
    TraceInstant,
    TraceRecorder,
    TraceSpan,
    chrome_trace,
    write_chrome_trace,
)
from .rccl_model import kernel_copy_latency, rccl_collective_latency
from .topology import (
    Calibration,
    PowerCalibration,
    RcclCalibration,
    Topology,
    mi300x_platform,
    rccl_aa_calibration,
    rccl_ag_calibration,
    tpu_v5e_pod,
)

__all__ = [
    "commands", "CmdKind", "Command", "EngineQueue", "Schedule",
    "chunk_command", "chunk_schedule", "chunk_sizes", "chunk_tag",
    "chunked_copies", "chunked_reduces", "link_traffic", "reduce_work",
    "tag_chunk", "tag_name",
    "PIPE_DEPTH", "RS_VARIANTS", "allgather_schedule", "allreduce_schedule",
    "alltoall_schedule", "kv_fetch_schedule", "reduce_scatter_schedule",
    "COLLECTIVE_BUILDERS", "PAPER_AA_DISPATCH", "PAPER_AG_DISPATCH",
    "PERTURB_SCENARIOS", "FragileEntry", "RobustnessReport",
    "best_variant_for",
    "candidate_variants", "derive_dispatch", "dispatch_robustness",
    "optimized_variants",
    "paper_dispatch", "perturbed_topology", "pick_variant",
    "pipelined_variants",
    "reduce_variants", "variant_latency",
    "BlockedWaiter", "FaultPlan", "FaultReport", "LinkDerate", "NicFlap",
    "RetryRecord", "SimFault", "Straggler", "straggler_plan",
    "ComposedResult", "PhaseBreakdown", "ScheduleOutcome", "SimResult",
    "run_composed", "simulate", "single_copy_breakdown",
    "OptimizationConfig", "batch_commands", "fuse_signals", "optimize",
    "parse_optimized", "split_queues",
    "cu_collective_power", "dma_collective_power",
    "SimTrace", "TraceFlow", "TraceInstant", "TraceRecorder", "TraceSpan",
    "chrome_trace", "write_chrome_trace",
    "kernel_copy_latency", "rccl_collective_latency",
    "Calibration", "PowerCalibration", "RcclCalibration", "Topology",
    "mi300x_platform", "tpu_v5e_pod", "rccl_ag_calibration", "rccl_aa_calibration",
]
