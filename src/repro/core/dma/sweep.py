"""Vectorized dispatch-sweep fast path (DESIGN.md §11.3).

``derive_dispatch`` historically evaluated one ``simulate()`` call per
(variant, size, chunk) point, and every call built the *full* schedule —
one command stream per device — only for the symmetric fast path to then
simulate a single representative.  On a 16-device pod the build dominates
the point cost ~12×, and it grows linearly with device count: the 64- and
256-device multislice sweeps (DESIGN.md §11) are unreachable in CI budgets
that way.

This module is the batched replacement the multi-node tables run on:

* :func:`sweep_variant_latencies` evaluates one (variant, chunk) candidate
  over the *whole size grid* using representative-only builds — every
  collective builder accepts ``device=`` and emits just that device's
  queues (O(1) in device count, DESIGN.md §11.3) — and the same
  single-device event loop the symmetric fast path runs (``_Sim(topo,
  rep)``), so the returned latencies are **bit-identical** to the per-point
  ``simulate()`` loop by construction: the identical float operations run
  in the identical order, only the dead per-device rebuild work is gone.
  Candidates whose schedule is not symmetric on this topology return
  ``None`` and the caller falls back to the per-point loop — correctness
  never rests on the fast path applying.
* :func:`argmin_grid` replays the sweep's strict-improvement argmin as a
  numpy pass per candidate over the full size axis instead of a Python
  comparison per point.  Same comparisons, same tie-breaking (earlier
  candidate wins within the 1e-9 tolerance), one vectorized sweep.

An affine closed form over the size grid (latency = a + b·size per
structural regime) was considered and rejected: re-deriving coefficients
and evaluating ``a + b·size`` reassociates float additions, so the result
is only *approximately* equal to the event loop — and approximately-equal
latencies flip argmin winners near crossover points, which is exactly
where dispatch thresholds live.  Bit-identity is the contract
(tests/test_hier.py asserts it on every bundled table entry), so the fast
path keeps the scalar op sequence and deletes only redundant work.
"""
from __future__ import annotations

import numpy as np

from .collectives import (allgather_schedule, allreduce_schedule,
                          alltoall_schedule, fused_ag_gemm_schedule,
                          fused_gemm_rs_schedule, reduce_scatter_schedule)
from .sim import _Sim, _breakdown, _finish_device, _run
from .topology import Topology

_BUILDERS = {
    "all_gather": allgather_schedule,
    "all_to_all": alltoall_schedule,
    "reduce_scatter": reduce_scatter_schedule,
    "all_reduce": allreduce_schedule,
    "fused_gemm_rs": fused_gemm_rs_schedule,
    "fused_ag_gemm": fused_ag_gemm_schedule,
}

#: Representative device of a symmetric schedule — the builders emit devices
#: in ascending order, so ``Schedule.devices[0]`` is always device 0 and the
#: rep-only build can target it directly (matches simulate()'s choice).
_REP = 0


def rep_latency(topo: Topology, collective: str, size: int, variant: str,
                chunk_bytes: int | None = None) -> float | None:
    """One point of the fast path: representative-only build + single-device
    event loop.  Returns ``None`` when the schedule is not symmetric on this
    topology (the caller must use the full ``simulate()`` there)."""
    builder = _BUILDERS[collective]
    sched = builder(topo, size, variant, max_chunk_bytes=chunk_bytes,
                    device=_REP)
    if not sched.symmetric or topo.n_devices < 2:
        return None
    sim = _Sim(topo, _REP)
    key = (0, _REP)
    started = _run(sim, [(key, _REP, sched.queues_for(_REP), 0.0)])
    t0, _, cend, states = started[key]
    return _breakdown(t0, cend, *_finish_device(sim, _REP, cend, states, key)).total


def sweep_variant_latencies(
        topo: Topology, collective: str, sizes: tuple[int, ...], variant: str,
        chunk_bytes: int | None = None) -> list[float] | None:
    """Latency of one (variant, chunk) candidate over the whole size grid.

    Bit-identical to ``[simulate(build(size)).latency for size in sizes]``
    when the variant is symmetric on ``topo`` (asserted in
    tests/test_hier.py); ``None`` when it is not — symmetry is a property
    of (variant, topology), not of the message size, so one probe build
    decides for the whole grid.
    """
    if not sizes:
        return []
    first = rep_latency(topo, collective, sizes[0], variant, chunk_bytes)
    if first is None:
        return None
    out = [first]
    for size in sizes[1:]:
        t = rep_latency(topo, collective, size, variant, chunk_bytes)
        assert t is not None  # symmetry cannot vary across the grid
        out.append(t)
    return out


def argmin_grid(lat: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Strict-improvement argmin over a (candidates, sizes) latency matrix.

    Vectorized replay of the per-point sweep loop: candidate ``c`` displaces
    the incumbent at a size only when ``lat[c] < best * (1 - 1e-9)`` —
    earlier candidates win ties within the tolerance, exactly like the
    scalar loop (the calibrated-default chunk is ordered first so chunk-flat
    prelaunched variants don't churn on float noise).  Returns
    ``(winner_index, winner_latency)`` arrays over the size axis.
    """
    lat = np.asarray(lat, dtype=float)
    n_cand, n_sizes = lat.shape
    best_t = np.full(n_sizes, np.inf)
    best_i = np.zeros(n_sizes, dtype=int)
    for c in range(n_cand):
        better = lat[c] < best_t * (1.0 - 1e-9)
        best_i[better] = c
        best_t[better] = lat[c][better]
    return best_i, best_t


def winner_flips(base, alt) -> np.ndarray:
    """Size-grid indices where the argmin winner differs between two
    (candidates, sizes) latency matrices over the *same* candidate axis —
    the dispatch-robustness primitive (DESIGN.md §13.5): a flip means the
    bundled table's winner at that size is fragile under the perturbation
    that produced ``alt``."""
    base_i, _ = argmin_grid(base)
    alt_i, _ = argmin_grid(alt)
    return np.flatnonzero(base_i != alt_i)
