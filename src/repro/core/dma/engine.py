"""Compatibility façade over the event-driven simulator core.

The timing model used to live here as a closed-form per-device formula; it is
now the discrete-event simulator in :mod:`repro.core.dma.sim` (contended
links/engines/host, multi-hop routing, cross-device waits, symmetric fast
path — see DESIGN.md §2).  This module keeps the historical import surface:

    from repro.core.dma.engine import PhaseBreakdown, SimResult, simulate
"""
from __future__ import annotations

from .faults import FaultPlan, FaultReport, SimFault
from .sim import (
    ComposedResult,
    PhaseBreakdown,
    ScheduleOutcome,
    SimResult,
    run_composed,
    simulate,
    single_copy_breakdown,
)

__all__ = ["ComposedResult", "FaultPlan", "FaultReport", "PhaseBreakdown",
           "ScheduleOutcome", "SimFault", "SimResult",
           "run_composed", "simulate", "single_copy_breakdown"]
