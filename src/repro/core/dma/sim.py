"""Event-driven simulator of DMA offload execution (paper §3, Fig. 6/7).

:func:`simulate` executes a :class:`~repro.core.dma.commands.Schedule` on a
:class:`~repro.core.dma.topology.Topology` and returns a :class:`SimResult`.
Unlike the original closed-form per-device model, every shared piece of
hardware is a *contended resource* with an explicit busy timeline
(DESIGN.md §2):

  host CPU     — serial: command-packet creation, doorbell MMIO writes,
                 completion-signal observation.
  engine       — per-(device, engine) streaming capacity: data commands
                 stream through it back-to-back at ``engine_bw``; all SDMA
                 queue slots of an engine share this one resource.
  link         — per *directed* peer link: wire time serializes on each link;
                 transfers on distinct links overlap.  Multi-hop routes
                 (non-fully-connected topologies) occupy every link on the
                 path, staggered by the per-hop router latency (cut-through).
  host link    — the PCIe link, one directed resource per device/direction,
                 shared by all of that device's engines.

Cross-device dependencies: a ``wait`` command blocks its engine until the
named tagged signal was raised by its producer (plus ``poll_trigger`` remote
observation latency), so ring/torus schedules are timed from real signal
arrival rather than assumed overlap.

The four reported phases keep the paper's meaning (``PhaseBreakdown`` is the
stable reporting surface):

  control  — CPU creates + enqueues command packets (serial on the host)
  schedule — doorbell rings (serial on the host) + engine wake/fetch
  copy     — decode, address translation, reads/writes over the fabric
             (wait-for-neighbor time lands here)
  sync     — completion signals (engine atomic + host observation; the host
             drains its signal set serially once the last signal landed)

Back-to-back overlap (§4.4): data commands queued on a single engine pipeline
their issue (``b2b_issue`` per extra command) and their wire time overlaps
across distinct links, bounded by the engine's streaming bandwidth.

Prelaunch (§4.5): queues that begin with a ``poll`` are armed ahead of time;
control+schedule leave the critical path and are replaced by the poll-trigger
observation latency.

Optimized command streams (DESIGN.md §7): queues built by
:mod:`repro.core.dma.optimizations` may carry a host submission batch size
(``EngineQueue.batch`` — packet creation and doorbells amortize inside one
scheduling event, §7.1), occupy extra SDMA queue slots of one engine
(``EngineQueue.slot`` — decode/issue overlaps across slots; fetch and
streaming bandwidth still contend on the engine, §7.2), and fuse completion
signals into the final write packet of a
data command (``Command.fused_tag``/``fused_signal`` — the engine scheduling
round-trip ``sync_engine`` is replaced by the posted-write delay
``fused_sync``; the host-side observation cost is unchanged, §7.3).  Baseline
schedules set none of these and time identically to the unoptimized model.

Chunked transfers and the simulator hot path (DESIGN.md §8): GB-scale copies
arrive split into bounded-size chunk commands
(:func:`repro.core.dma.commands.chunk_schedule`), multiplying event counts by
10-100x.  Three data structures keep the event loop fast:

  * the worklist is a **heap-based event queue** ordered by each queue's
    ready time, and a queue blocked on a tagged signal parks on a
    tag -> waiters map and is re-queued exactly when the producer raises the
    tag — no repeated scans over blocked queues (§8.2);
  * busy timelines are **append-only** and coalesce adjacent intervals, so a
    thousand back-to-back chunks cost one interval, not a thousand (§8.2);
  * a run of identical chunk commands (they share one ``Command`` instance)
    is scheduled in **closed form** — per-chunk issue still overlaps the
    previous chunk's streaming, but the whole run commits with O(1) timeline
    updates instead of one event per chunk (§8.3).  Runs whose issue rate or
    engine bandwidth would leave wire gaps fall back to the per-chunk loop.

Per-chunk signaling (DESIGN.md §9): pipelined schedules tag each chunk of a
transfer with its own semaphore (``fused_tag`` carrying a chunk index,
:func:`repro.core.dma.commands.chunked_copies`) and ``wait`` at chunk
granularity, so a consumer starts on the first *arrived* chunk instead of
the whole transfer.  The tag -> waiters map handles chunk tags like any
other tag — a queue parked on chunk *i* wakes exactly when chunk *i*'s
fused semaphore is raised — and a run of equivalent-modulo-tag chunk
commands still schedules in closed form (§9.2): the run commits with O(1)
timeline updates while each chunk's tag is raised at its closed-form
completion time.

Per-chunk reduction (DESIGN.md §10): reduce-scatter schedules interleave
``reduce_tag`` commands with their forwarded copies — a ``reduce_tag``
blocks like a ``wait`` on the named chunk tag, then charges
``Calibration.reduce_setup + size / reduce_bytes_per_s`` on the consumer's
engine timeline (the engine reads the arrived chunk and the local
accumulator and writes the partial back) before the queue may forward the
reduced result.  Reduction time lands in the copy phase, exactly like
wait-for-neighbor time; an optional ``fused_tag`` on the reduce raises a
semaphore at reduction completion (all-reduce chaining).  The §9.2
closed-form chunk run is unaffected — reductions sit on the *consumer*,
so a producer's chunk run still commits closed-form and each chunk's
semaphore wakes its parked reduction exactly as the per-chunk loop would.

Compute-collective overlap (DESIGN.md §15): each device additionally owns a
*CU timeline* (``cu:{dev}``) modeling its compute units as one aggregate
serial resource.  A ``compute`` command occupies it for one GEMM tile
(``Calibration.cu_tile_setup + size / cu_flops``, ``size`` in FLOPs),
optionally blocking on a tagged chunk first (all-gather+GEMM: tile *k*
launches when shard *k* lands) and optionally raising a semaphore at tile
completion (GEMM+reduce-scatter: tile *i*'s partial releases the RS chunk
pipeline).  A ``reduce_tag`` with ``on_cu=True`` charges its §10 reduction
on the CU timeline instead of the consumer's engine — the reduce-placement
axis.  Schedules without compute/on_cu commands never create a CU timeline
and time bit-identically to the pre-§15 simulator.

Symmetric fast path (DESIGN.md §6): schedules whose builder marked them
``symmetric`` simulate ONE representative device — waits on a neighbor's
tagged signal resolve, by translation invariance, to the representative's own
signal of the same (name, step) — and replicate the breakdown.  This is
bit-identical to the full simulation because symmetric schedules never put
two devices on the same directed link.

Worked example — two devices, one copy each way, chained by a tagged signal::

    from repro.core.dma import commands as cmd, mi300x_platform, simulate
    from repro.core.dma.commands import EngineQueue, Schedule

    topo = mi300x_platform()
    MB = 1 << 20
    q0 = EngineQueue(device=0, engine=0, commands=(
        cmd.copy(0, 1, 4 * MB),          # dev0 pushes 4MB to dev1
        cmd.signal(("done", 0, 0)),      # engine-scope semaphore, step 0
        cmd.signal(),                    # host-observed completion
    ))
    q1 = EngineQueue(device=1, engine=0, commands=(
        cmd.wait(("done", 0, 0)),        # block until dev0's data arrived
        cmd.copy(1, 0, 4 * MB),          # then push 4MB back
        cmd.signal(),
    ))
    res = simulate(Schedule(name="pingpong", queues=(q0, q1)), topo)
    res.latency                  # end-to-end seconds (max over devices)
    res.per_device[1].copy       # dev1's copy phase INCLUDES its wait time
    res.breakdown.as_dict()      # critical-path device's 4-phase split
    res.utilization("link:0>1")  # busy fraction of the 0->1 wire

Device 1's queue makes no progress until device 0's tagged signal is raised;
:func:`_run` parks it on the ``("done", 0, 0)`` waiter list and re-queues it
the moment device 0's signal lands (a drained heap with parked waiters left
over raises ``RuntimeError`` naming the blocked tags).

Multi-schedule composition (DESIGN.md §12): :func:`run_composed` executes K
independent schedules in ONE resource world — every host, engine, link and
NIC timeline is shared, so concurrent collectives contend exactly as they
would on real hardware.  Each schedule is released at its arrival time
(host control may not begin earlier), its tags are namespaced by schedule
index so streams never satisfy each other's waits, and the per-schedule
``ScheduleOutcome`` reports release/start/finish plus a phase breakdown
relative to the release.  Composed runs always take the full event loop:
the symmetric fast path reasons about ONE schedule's translation symmetry
and is meaningless under cross-schedule contention.

Fault injection and timeout/retry (DESIGN.md §13): ``simulate(...,
faults=FaultPlan(...))`` threads a seeded, deterministic fault plan
(:mod:`repro.core.dma.faults`) through the event loop — straggler engines
stream slower, derated/flapping wires grant slower or later, and tagged
raises may land late or be *dropped*.  A queue parked on a dropped tag is
recovered by watchdog/retry: once the heap drains with waiters left, the
producing command is re-issued from the watchdog deadline with exponential
backoff, its costs charged on the real host/engine/link timelines, up to
``max_attempts`` total attempts.  Exhaustion — and any fault-free deadlock
— raises :class:`~repro.core.dma.faults.SimFault`, a ``RuntimeError``
subclass carrying a deterministic sorted diagnosis of every parked waiter
(device, blocked tag, producing command, nearest raised sibling tag) plus
the retry history.  An *empty* plan is normalized to ``None`` so the
fault-free path runs untouched and bit-identical.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict

from .commands import DATA_KINDS, CmdKind, EngineQueue, Schedule, tag_chunk
from .faults import (BlockedWaiter, FaultPlan, FaultReport, RetryRecord,
                     SimFault)
from .topology import Topology
from .trace import SimTrace, TraceRecorder


@dataclasses.dataclass(frozen=True)
class PhaseBreakdown:
    """One device's latency split into the paper's four phases (Fig. 6/7).

    The fields are durations in seconds and partition the device's total:
    ``control`` (host packet creation), ``schedule`` (doorbells + engine
    wake), ``copy`` (data movement, including time spent waiting on a
    neighbor's signal) and ``sync`` (completion signaling + host
    observation).  ``total`` is their sum; ``noncopy_fraction`` is the
    paper's headline "how much of a small transfer is overhead" metric.
    """

    control: float
    schedule: float
    copy: float
    sync: float

    @property
    def total(self) -> float:
        """End-to-end seconds for this device (sum of the four phases)."""
        return self.control + self.schedule + self.copy + self.sync

    @property
    def noncopy_fraction(self) -> float:
        """Fraction of ``total`` spent outside the copy phase (Fig. 7)."""
        t = self.total
        return 0.0 if t == 0 else (t - self.copy) / t

    def as_dict(self) -> dict[str, float]:
        return {
            "control": self.control,
            "schedule": self.schedule,
            "copy": self.copy,
            "sync": self.sync,
            "total": self.total,
        }


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Everything :func:`simulate` learned about one schedule execution.

    ``latency`` is the collective's completion time (max over devices);
    ``per_device`` maps device id to its :class:`PhaseBreakdown`;
    ``timelines``/``busy`` expose the per-resource busy intervals recorded by
    the event loop (resource keys are ``host:<dev>``, ``engine:<dev>.<e>``,
    ``link:<a>><b>`` and ``hostlink:<dev>:<dir>``), which the power model and
    the utilization reports consume.  ``host_events`` counts each device's
    host scheduling events (command-creation passes, full-cost doorbells,
    completion observations) and ``engine_atomics`` its standalone engine
    signal round-trips — the quantities the power model prices for the
    optimized-stream saving (DESIGN.md §8.4).
    """

    latency: float                       # collective completion (max over devices)
    per_device: dict[int, PhaseBreakdown]
    engines_used: dict[int, int]
    hbm_bytes: dict[int, int]            # local HBM traffic per device (power model)
    # Per-resource busy timelines: resource name -> ((start, end), ...).
    # In symmetric mode only the representative device's resources appear.
    timelines: dict[str, tuple] = dataclasses.field(default_factory=dict)
    busy: dict[str, float] = dataclasses.field(default_factory=dict)
    host_events: dict[int, int] = dataclasses.field(default_factory=dict)
    engine_atomics: dict[int, int] = dataclasses.field(default_factory=dict)
    # Chunk reductions executed per device (DESIGN.md §10) — the event-loop
    # side of the reduction-work conservation invariant.
    reduce_chunks: dict[int, int] = dataclasses.field(default_factory=dict)
    representative: int | None = None    # set when the symmetric fast path ran
    # What the fault layer did (DESIGN.md §13) — None on fault-free runs
    # (an empty FaultPlan is normalized away before the event loop).
    fault_report: FaultReport | None = None
    # Per-command span record (DESIGN.md §14) — None unless the run was
    # started with record_trace=True; render with trace.chrome_trace().
    trace: SimTrace | None = None

    @property
    def breakdown(self) -> PhaseBreakdown:
        """Breakdown of the critical-path device."""
        return max(self.per_device.values(), key=lambda b: b.total)

    def utilization(self, resource: str) -> float:
        """Busy fraction of one resource over the collective's latency."""
        if self.latency <= 0:
            return 0.0
        return min(1.0, self.busy.get(resource, 0.0) / self.latency)

    def link_busy_seconds(self, device: int) -> float:
        """Total wire-busy seconds on links sourced at ``device`` (falls back
        to the representative device under the symmetric fast path)."""
        dev = device
        if self.representative is not None and not any(
                k.startswith(f"link:{device}>") or k.startswith(f"hostlink:{device}:")
                for k in self.busy):
            dev = self.representative
        pfx_l, pfx_h = f"link:{dev}>", f"hostlink:{dev}:"
        return sum(v for k, v in self.busy.items()
                   if k.startswith(pfx_l) or k.startswith(pfx_h))


class _Timeline:
    """A serial resource: requests are granted FIFO at max(request, free).

    Intervals are append-only and adjacent back-to-back grants coalesce into
    one interval (DESIGN.md §8.2) — a chunked GB transfer records one busy
    span, not hundreds.
    """

    __slots__ = ("free", "busy", "intervals")

    def __init__(self) -> None:
        self.free = 0.0
        self.busy = 0.0
        self.intervals: list[tuple[float, float]] = []

    def _record(self, start: float, end: float) -> None:
        iv = self.intervals
        if iv and iv[-1][1] == start:
            iv[-1] = (iv[-1][0], end)
        else:
            iv.append((start, end))

    def acquire(self, t: float, dur: float) -> tuple[float, float]:
        start = t if t > self.free else self.free
        end = start + dur
        self.free = end
        if dur > 0.0:
            self.busy += dur
            self._record(start, end)
        return start, end

    def occupy(self, start: float, end: float) -> None:
        """Commit a contiguous busy run computed in closed form (§8.3).

        Callers guarantee ``start >= free``; kept separate from ``acquire``
        so the run's exact closed-form ``end`` lands in ``free`` (re-adding
        the duration would reassociate the floats).
        """
        self.free = end
        if end > start:
            self.busy += end - start
            self._record(start, end)


class _QueueState:
    __slots__ = ("q", "idx", "issue", "seen_data", "last_end", "copy_end",
                 "start", "engine_tl", "blocked", "key")

    def __init__(self, q: EngineQueue, start: float, engine_tl: _Timeline,
                 key: tuple) -> None:
        self.q = q
        self.idx = 0
        self.start = start
        self.issue = start          # engine front-end clock
        self.seen_data = False
        self.last_end = start       # completion of the latest data command
        self.copy_end = start       # max data completion (device copy phase)
        self.engine_tl = engine_tl  # the engine's streaming timeline (cached)
        self.blocked = None         # resolved tag this queue is parked on
        self.key = key              # (schedule index, device) stats key (§12)


class _DroppedSignal:
    """Watchdog state of a tag whose raise was dropped (DESIGN.md §13.2).

    ``time`` is the would-have-raised time of the latest lost attempt;
    ``attempts`` counts total raise attempts so far (the original drop is
    attempt 1); ``deadline`` is lazily set to the watchdog expiry once a
    waiter is known to be parked on the tag (the watchdog arms from the
    later of the drop and the earliest parked wait)."""

    __slots__ = ("time", "device", "engine", "cmd", "attempts", "deadline")

    def __init__(self, time: float, device: int, engine: int, cmd) -> None:
        self.time = time
        self.device = device
        self.engine = engine
        self.cmd = cmd
        self.attempts = 1
        self.deadline: float | None = None


class _Sim:
    def __init__(self, topo: Topology, rep: int | None,
                 faults: FaultPlan | None = None,
                 trace: TraceRecorder | None = None) -> None:
        self.topo = topo
        self.calib = topo.calib
        self.rep = rep                      # symmetric-mode representative
        self.faults = faults                # FaultPlan or None (§13)
        self.trace = trace                  # TraceRecorder or None (§14)
        self.dropped: dict[tuple, _DroppedSignal] = {}
        self.drop_log: list[tuple] = []
        self.delay_log: list[tuple] = []
        self.retry_log: list[RetryRecord] = []
        self.retry_seconds = 0.0
        self.timelines: dict[str, _Timeline] = {}
        self.tags: dict[tuple, float] = {}  # tagged signal -> raise time
        self.raised: list[tuple] = []       # tags raised since last drain (§8.2)
        # Signal/event stats are keyed by (schedule index, device) so
        # composed runs (§12) keep per-schedule provenance; a plain
        # simulate() uses schedule index 0 throughout.
        self.host_signals: dict[tuple, list[float]] = defaultdict(list)
        # Fused completions (§7.3) write adjacent slots of one completion
        # record per device: the host drains them in a single sweep, paying
        # sync_obs once and sync_obs_batched for each further entry.
        self.fused_signals: dict[tuple, list[float]] = defaultdict(list)
        self.host_events: dict[tuple, int] = defaultdict(int)
        self.engine_atomics: dict[int, int] = defaultdict(int)
        self.reduce_chunks: dict[int, int] = defaultdict(int)
        # (src, dst) -> ((timeline, added latency) per hop, wire bandwidth);
        # resolving the route and the timeline dict once per endpoint pair
        # keeps the per-command cost flat under chunking.
        self._routes: dict[tuple, tuple[tuple[tuple[_Timeline, float], ...], float]] = {}

    def timeline(self, key: str) -> _Timeline:
        tl = self.timelines.get(key)
        if tl is None:
            tl = self.timelines[key] = _Timeline()
        return tl

    def resolve(self, tag: tuple) -> tuple:
        if self.rep is not None and len(tag) >= 2:
            return (tag[0], self.rep) + tuple(tag[2:])
        return tag

    # ------------------------------------------------------------ wire ----
    def route_tls(self, src, dst) -> tuple[tuple[tuple[_Timeline, float, str], ...], float]:
        """Per-hop (timeline, added latency, resource key) along src->dst +
        wire bandwidth.

        The hop structure comes from ``Topology.wire_path`` (DESIGN.md §11):
        intra-node hops are directed DMA links (first hop latency 0, further
        hops ``hop_latency``); a cross-node transfer is one hop through the
        sender's NIC at NIC bandwidth with ``nic_latency`` up front.  The
        resource key rides along so the fault layer (§13) can target derate
        windows and NIC flaps at specific wires.
        """
        key = (src, dst)
        ent = self._routes.get(key)
        if ent is None:
            if src == "host" or dst == "host":
                dev = dst if src == "host" else src
                dirn = "h2d" if src == "host" else "d2h"
                hkey = f"hostlink:{dev}:{dirn}"
                tls = ((self.timeline(hkey), 0.0, hkey),)
                bw = self.topo.host_link_bw * self.calib.dma_link_efficiency
            else:
                hops, bw = self.topo.wire_path(src, dst)
                tls = tuple((self.timeline(k), lat, k) for k, lat in hops)
            ent = self._routes[key] = (tls, bw)
        return ent

    def transfer(self, src, dst, size: int, start: float) -> float:
        """Occupy every hop on the src->dst route; returns completion time."""
        tls, bw = self.route_tls(src, dst)
        wire = size / bw
        t = start
        end = start
        fp = self.faults
        tr = self.trace
        if fp is None and tr is None:       # hot path: no per-hop branches
            for tl, lat, key in tls:
                s, end = tl.acquire(t + lat, wire)
                t = s                # cut-through: next hop staggers off start
        elif fp is None:
            for tl, lat, key in tls:
                s, end = tl.acquire(t + lat, wire)
                tr.wire(key, s, end)
                t = s
        else:
            for tl, lat, key in tls:
                # A flapping NIC holds the request until the outage clears;
                # a derate window stretches the wire occupancy (§13).
                req = fp.outage_release(key, t + lat)
                s, end = tl.acquire(req, wire / fp.derate_factor(key, req))
                if tr is not None:
                    tr.wire(key, s, end)
                t = s
        return end

    # ------------------------------------------------- chunk runs (§8.3) ----
    def _chunk_run(self, st: _QueueState, cmd, m: int, ts: float,
                   tagged: tuple | None = None) -> bool:
        """Closed-form schedule of ``m`` identical chunk commands.

        The per-chunk recurrence (issue clock advances ``b2b_issue``, the
        engine streams chunks FIFO, each wire grants FIFO) telescopes when
        every chunk streams back-to-back on the engine AND lands back-to-back
        on each wire; both conditions reduce to endpoint checks, so the whole
        run commits with one ``occupy`` per resource.  Returns False (no
        state touched) when the run is issue-bound, engine-bound relative to
        a wire, multi-hop, or carries fused flags — the caller then executes
        it per-chunk, which is always correct.

        ``tagged`` extends the closed form to *per-chunk-signaled* runs
        (DESIGN.md §9.2): ``m`` commands equivalent modulo their
        ``fused_tag`` (chunk-indexed semaphores, ``commands.chunked_copies``).
        The timeline commits are identical to the untagged run — a fused tag
        never gates the engine front end — and each chunk's semaphore is
        raised at its closed-form completion time, waking chunk-granularity
        waiters exactly as the per-chunk loop would.
        """
        if self.faults is not None or self.trace is not None:
            # Fault runs take the per-chunk loop (always correct): stragglers,
            # derate windows, flaps and per-tag signal draws all break the
            # back-to-back affine structure the closed form relies on (§13).
            # Traced runs do too: the closed form commits O(1) timeline
            # updates and would skip the per-chunk spans (§14) — the loop
            # reproduces its latency bit-for-bit, and its timelines to the
            # same ulp tolerance the §8.3/§9.2 equivalence tests pin.
            return False
        if tagged is None and (cmd.fused_tag is not None or cmd.fused_signal):
            return False
        size = cmd.size
        wires: list[tuple[_Timeline, float, float]] = []
        for dst in cmd.dsts:
            tls, bw = self.route_tls(cmd.src, dst)
            if len(tls) != 1:
                return False
            wires.append((tls[0][0], size / bw, tls[0][1]))
        if cmd.kind is CmdKind.SWAP:
            tls, bw = self.route_tls(cmd.dsts[0], cmd.src)
            if len(tls) != 1:
                return False
            wires.append((tls[0][0], size / bw, tls[0][1]))
        b = self.calib.b2b_issue
        engine = st.engine_tl
        issue0 = st.issue
        s1 = issue0 + b
        if engine.free > s1:
            s1 = engine.free
        sm = s1 + (m - 1) * ts
        tail = issue0 + m * b
        if tail > sm:                       # issue-bound: chunks gap on the engine
            return False
        end = sm + ts
        commits: list[tuple[_Timeline, float, float]] = []
        for tl, tw, lat in wires:
            # Each chunk's wire request lags its engine stream start by the
            # hop latency (0 intra-node, nic_latency across nodes).
            req1 = s1 + lat
            w1 = req1 if req1 > tl.free else tl.free
            wm = w1 + (m - 1) * tw
            if sm + lat > wm:               # engine-bound: chunks gap on this wire
                return False
            commits.append((tl, w1, wm + tw))
            if wm + tw > end:
                end = wm + tw
        engine.occupy(s1, sm + ts)
        for tl, a, z in commits:
            tl.occupy(a, z)
        if tagged is not None:
            # Raise each chunk's semaphore at its completion time (§9.2):
            # engine-stream end and every wire's landing end are affine in
            # the chunk index under the back-to-back conditions above.
            w1s = [(w1, tw) for (tl, tw, _), (_, w1, _) in zip(wires, commits)]
            fs = self.calib.fused_sync
            tags = self.tags
            for i, tc in enumerate(tagged):
                e_i = s1 + (i + 1) * ts
                for w1, tw in w1s:
                    we = w1 + (i + 1) * tw
                    if we > e_i:
                        e_i = we
                rt = self.resolve(tc.fused_tag)
                tags[rt] = e_i + fs
                self.raised.append(rt)
        st.issue = tail
        if end > st.last_end:
            st.last_end = end
        if end > st.copy_end:
            st.copy_end = end
        return True

    # --------------------------------------------------------- queue run ----
    def advance(self, st: _QueueState) -> bool:
        """Run one queue until finished (True) or blocked on a wait (False)."""
        c = self.calib
        q = st.q
        cmds = q.commands
        n = len(cmds)
        tags = self.tags
        idx = st.idx
        fp = self.faults
        tr = self.trace
        while idx < n:
            cmd = cmds[idx]
            kind = cmd.kind
            if kind in DATA_KINDS:
                st.issue += c.b2b_issue if st.seen_data else c.copy_setup
                st.seen_data = True
                # Identical chunk commands share one object (chunk_command):
                # detect the run by identity and try the closed form (§8.3).
                j = idx + 1
                while j < n and cmds[j] is cmd:
                    j += 1
                size = cmd.size
                tagged = None
                if j == idx + 1 and cmd.fused_tag is not None \
                        and not cmd.fused_signal:
                    # Per-chunk-signaled chunks (chunked_copies) are distinct
                    # instances equivalent modulo their chunk tag: detect the
                    # run by field equality and try the tagged closed form
                    # (§9.2).
                    while j < n:
                        c2 = cmds[j]
                        if (c2.kind is kind and c2.src == cmd.src
                                and c2.dsts == cmd.dsts and c2.size == size
                                and c2.fused_tag is not None
                                and not c2.fused_signal):
                            j += 1
                        else:
                            break
                    if j > idx + 1:
                        tagged = cmds[idx + 1:j]
                stream_bytes = size if kind is CmdKind.COPY else 2 * size
                ts = stream_bytes / c.engine_bw
                if fp is not None:
                    ts *= fp.engine_slowdown(q.device, q.engine)
                engine = st.engine_tl
                start = st.issue if st.issue > engine.free else engine.free
                _, end = engine.acquire(start, ts)
                if tr is not None:
                    span_tag = cmd.fused_tag if cmd.fused_tag is not None \
                        else cmd.tag
                    ch = None if span_tag is None else tag_chunk(span_tag)
                    tr.set_ctx(q.device, st.key[0], size, ch, False)
                    tr.span(f"engine:{q.device}.{q.engine}", q.device,
                            st.key[0], kind.name.lower(), start, end,
                            tag=span_tag, size=size, chunk=ch,
                            args={"src": cmd.src, "dsts": list(cmd.dsts)})
                for dst in cmd.dsts:
                    e = self.transfer(cmd.src, dst, size, start)
                    if e > end:
                        end = e
                if kind is CmdKind.SWAP:    # reverse direction, concurrently
                    e = self.transfer(cmd.dsts[0], cmd.src, size, start)
                    if e > end:
                        end = e
                if end > st.last_end:
                    st.last_end = end
                if end > st.copy_end:
                    st.copy_end = end
                # Fused write+signal (§7.3): the signal payload rides the
                # final write packet — no engine scheduling round-trip, so
                # the queue front end (st.issue) is NOT gated.
                if cmd.fused_tag is not None:
                    rt = self.resolve(cmd.fused_tag)
                    if fp is None:
                        tags[rt] = end + c.fused_sync
                        self.raised.append(rt)
                        if tr is not None:
                            tr.raise_tag(rt, end + c.fused_sync,
                                         f"engine:{q.device}.{q.engine}")
                    else:
                        self._faulty_raise(rt, end + c.fused_sync, q, cmd)
                if cmd.fused_signal:
                    self.fused_signals[st.key].append(end + c.fused_sync)
                idx += 1
                m = j - idx
                if m > 0 and self._chunk_run(st, cmd, m, ts, tagged):
                    idx = j
            elif kind is CmdKind.WAIT:
                rt = self.resolve(cmd.tag)
                t = tags.get(rt)
                if t is None:
                    st.idx = idx
                    st.blocked = rt
                    return False
                arrival = t + c.poll_trigger
                if tr is not None:
                    # Wait span: engine reached the wait (st.issue — parking
                    # does not advance it) until signal arrival; an
                    # already-arrived tag yields an instant event (§14).
                    tr.wait(f"engine:{q.device}.{q.engine}", q.device,
                            st.key[0], st.issue,
                            arrival if arrival > st.issue else st.issue, rt)
                if arrival > st.issue:
                    st.issue = arrival
                idx += 1
            elif kind is CmdKind.REDUCE:
                # Per-chunk reduction (DESIGN.md §10): block like a wait,
                # then stream the accumulate through the consumer's engine.
                rt = self.resolve(cmd.tag)
                t = tags.get(rt)
                if t is None:
                    st.idx = idx
                    st.blocked = rt
                    return False
                arrival = t + c.poll_trigger
                start = st.issue if st.issue > arrival else arrival
                # Placement axis (§15): an on_cu reduction contends with
                # GEMM tiles on the CU timeline instead of with the
                # engine's forwarding copies, and skips the per-chunk
                # descriptor dispatch (reduce_setup) — the accumulate rides
                # the resident kernel's epilogue.
                if cmd.on_cu:
                    dur = cmd.size / c.reduce_bytes_per_s
                    red_tl = self.timeline(f"cu:{q.device}")
                else:
                    dur = c.reduce_setup + cmd.size / c.reduce_bytes_per_s
                    red_tl = st.engine_tl
                if fp is not None:
                    dur *= fp.engine_slowdown(q.device, q.engine)
                rstart, end = red_tl.acquire(start, dur)
                if tr is not None:
                    res = f"cu:{q.device}" if cmd.on_cu \
                        else f"engine:{q.device}.{q.engine}"
                    tr.wait(res, q.device, st.key[0], st.issue,
                            arrival if arrival > st.issue else st.issue, rt)
                    tr.span(res, q.device, st.key[0], "reduce", rstart, end,
                            tag=rt, size=cmd.size, chunk=tag_chunk(rt))
                st.issue = end
                if end > st.last_end:
                    st.last_end = end
                if end > st.copy_end:
                    st.copy_end = end
                self.reduce_chunks[q.device] += 1
                if cmd.fused_tag is not None:
                    rt2 = self.resolve(cmd.fused_tag)
                    if fp is None:
                        tags[rt2] = end + c.fused_sync
                        self.raised.append(rt2)
                        if tr is not None:
                            tr.raise_tag(rt2, end + c.fused_sync,
                                         f"engine:{q.device}.{q.engine}")
                    else:
                        self._faulty_raise(rt2, end + c.fused_sync, q, cmd)
                idx += 1
            elif kind is CmdKind.COMPUTE:
                # GEMM tile on the CU timeline (DESIGN.md §15): block like
                # a wait when the tile's input chunk is tagged, then occupy
                # the device's compute units for setup + FLOPs/throughput.
                start = st.issue
                rt = None
                if cmd.tag is not None:
                    rt = self.resolve(cmd.tag)
                    t = tags.get(rt)
                    if t is None:
                        st.idx = idx
                        st.blocked = rt
                        return False
                    arrival = t + c.poll_trigger
                    if arrival > start:
                        start = arrival
                dur = c.cu_tile_setup + cmd.size / c.cu_flops
                if fp is not None:
                    dur *= fp.engine_slowdown(q.device, q.engine)
                res = f"cu:{q.device}"
                cstart, end = self.timeline(res).acquire(start, dur)
                if tr is not None:
                    if rt is not None:
                        tr.wait(res, q.device, st.key[0], st.issue,
                                start if start > st.issue else st.issue, rt)
                    tr.span(res, q.device, st.key[0], "compute", cstart, end,
                            tag=rt, size=cmd.size,
                            chunk=None if rt is None else tag_chunk(rt))
                st.issue = end
                if end > st.last_end:
                    st.last_end = end
                if end > st.copy_end:
                    st.copy_end = end
                if cmd.fused_tag is not None:
                    rt2 = self.resolve(cmd.fused_tag)
                    if fp is None:
                        tags[rt2] = end + c.fused_sync
                        self.raised.append(rt2)
                        if tr is not None:
                            tr.raise_tag(rt2, end + c.fused_sync, res)
                    else:
                        self._faulty_raise(rt2, end + c.fused_sync, q, cmd)
                idx += 1
            elif kind is CmdKind.SIGNAL:
                t = (st.issue if st.issue > st.last_end else st.last_end) + c.sync_engine
                self.engine_atomics[q.device] += 1
                if tr is not None:
                    tr.span(f"engine:{q.device}.{q.engine}", q.device,
                            st.key[0], "signal", t - c.sync_engine, t,
                            tag=cmd.tag)
                if cmd.tag is not None:
                    # Semaphore update gates the engine's next command.
                    st.issue = t
                    rt = self.resolve(cmd.tag)
                    if fp is None:
                        tags[rt] = t
                        self.raised.append(rt)
                        if tr is not None:
                            tr.raise_tag(rt, t,
                                         f"engine:{q.device}.{q.engine}")
                    else:
                        # The engine-side update happened (the queue front end
                        # is gated either way); what a drop loses is the
                        # raise's visibility to waiters (§13.2).
                        self._faulty_raise(rt, t, q, cmd)
                else:
                    # Completion signals post asynchronously (fire-and-forget):
                    # later copies in the queue are not delayed.
                    self.host_signals[st.key].append(t)
                idx += 1
            else:                           # POLL: arming handled via queue start
                idx += 1
        st.idx = idx
        return True

    # ------------------------------------------- fault layer (§13) ----------
    def _faulty_raise(self, rt: tuple, t: float, q: EngineQueue, cmd) -> None:
        """Raise ``rt`` at ``t`` through the fault plan's signal draws:
        dropped raises park in ``self.dropped`` for the watchdog, delayed
        raises land ``delay_s`` late, the rest raise normally."""
        fp = self.faults
        tr = self.trace
        res = f"engine:{q.device}.{q.engine}"
        if fp.drops_signal(rt, 0):
            self.dropped[rt] = _DroppedSignal(t, q.device, q.engine, cmd)
            self.drop_log.append(rt)
            if tr is not None:
                tr.instant(res, q.device, 0, "drop", t, tag=rt,
                           args={"fault": "signal dropped", "attempt": 0})
            return
        if fp.delays_signal(rt, 0):
            t += fp.delay_s
            self.delay_log.append(rt)
            if tr is not None:
                tr.instant(res, q.device, 0, "delay", t, tag=rt,
                           args={"fault": "signal delayed",
                                 "delay_s": fp.delay_s})
        self.tags[rt] = t
        self.raised.append(rt)
        if tr is not None:
            tr.raise_tag(rt, t, res)

    def retry_dropped(self, waiting: dict) -> bool:
        """Watchdog/retry step (§13.2), called when the heap drains with
        parked waiters left.  Re-issues the producer of the dropped tag with
        the earliest watchdog deadline (ties broken by tag repr —
        deterministic), charging host control+doorbell, engine fetch and the
        command's execution on the real contended timelines.  The re-raise
        runs the per-attempt fault draws again; a re-drop re-arms the
        watchdog with exponential backoff.  Returns False when no dropped,
        waited-on tag has attempts left — the caller then raises SimFault.
        """
        fp = self.faults
        cands = []
        for rt, rec in self.dropped.items():
            ws = waiting.get(rt)
            if not ws:
                continue                    # nobody waits: drop is harmless
            if rec.deadline is None:
                # The watchdog arms from the later of the lost raise and the
                # earliest parked wait (a waiter can't time out a signal it
                # hasn't started waiting for).
                park = min(w.issue for w in ws)
                base = rec.time if rec.time > park else park
                rec.deadline = base + fp.watchdog_s
            if rec.attempts < fp.max_attempts:
                cands.append((rec.deadline, repr(rt), rt))
        if not cands:
            return False
        deadline, _, rt = min(cands)
        rec = self.dropped[rt]
        cmd = rec.cmd
        c = self.calib
        tr = self.trace
        ekey = f"engine:{rec.device}.{rec.engine}"
        # Host re-creates the command packet and rings the doorbell; the
        # engine re-fetches and re-executes.  All on live contended timelines
        # so retry cost is real, not an additive constant.
        hs, t = self.timeline(f"host:{rec.device}").acquire(
            deadline, c.control + c.doorbell)
        engine = self.timeline(ekey)
        fs, t = engine.acquire(t, c.fetch)
        if tr is not None:
            tr.span(f"host:{rec.device}", rec.device, 0, "control", hs,
                    hs + c.control + c.doorbell, tag=rt, retry=True)
            tr.span(ekey, rec.device, 0, "fetch", fs, t, tag=rt, retry=True)
            tr.set_ctx(rec.device, 0, cmd.size, tag_chunk(rt), True)
        if cmd.kind in DATA_KINDS:
            stream = cmd.size if cmd.kind is CmdKind.COPY else 2 * cmd.size
            ts = (stream / c.engine_bw) * fp.engine_slowdown(rec.device, rec.engine)
            s0, end = engine.acquire(t + c.copy_setup, ts)
            if tr is not None:
                tr.span(ekey, rec.device, 0, cmd.kind.name.lower(), s0, end,
                        tag=rt, size=cmd.size, chunk=tag_chunk(rt),
                        retry=True,
                        args={"src": cmd.src, "dsts": list(cmd.dsts)})
            for dst in cmd.dsts:
                e = self.transfer(cmd.src, dst, cmd.size, s0)
                if e > end:
                    end = e
            if cmd.kind is CmdKind.SWAP:
                e = self.transfer(cmd.dsts[0], cmd.src, cmd.size, s0)
                if e > end:
                    end = e
            raise_t = end + c.fused_sync
        elif cmd.kind is CmdKind.REDUCE:
            setup = 0.0 if cmd.on_cu else c.reduce_setup
            dur = (setup + cmd.size / c.reduce_bytes_per_s) \
                * fp.engine_slowdown(rec.device, rec.engine)
            red_tl = self.timeline(f"cu:{rec.device}") if cmd.on_cu else engine
            rkey = f"cu:{rec.device}" if cmd.on_cu else ekey
            rs, end = red_tl.acquire(t, dur)
            if tr is not None:
                tr.span(rkey, rec.device, 0, "reduce", rs, end, tag=rt,
                        size=cmd.size, chunk=tag_chunk(rt), retry=True)
            raise_t = end + c.fused_sync
        elif cmd.kind is CmdKind.COMPUTE:
            dur = (c.cu_tile_setup + cmd.size / c.cu_flops) \
                * fp.engine_slowdown(rec.device, rec.engine)
            ckey = f"cu:{rec.device}"
            cs, end = self.timeline(ckey).acquire(t, dur)
            if tr is not None:
                tr.span(ckey, rec.device, 0, "compute", cs, end, tag=rt,
                        size=cmd.size, chunk=tag_chunk(rt), retry=True)
            raise_t = end + c.fused_sync
        else:                               # SIGNAL: engine atomic round-trip
            ss, raise_t = engine.acquire(t, c.sync_engine)
            self.engine_atomics[rec.device] += 1
            if tr is not None:
                tr.span(ekey, rec.device, 0, "signal", ss, raise_t, tag=rt,
                        retry=True)
        self.retry_seconds += raise_t - deadline
        attempt = rec.attempts              # draw-stream index of this re-raise
        dropped_again = fp.drops_signal(rt, attempt)
        self.retry_log.append(RetryRecord(
            tag=rt, attempt=attempt, issued_at=deadline,
            completed_at=raise_t, raised=not dropped_again))
        rec.attempts += 1
        if dropped_again:
            self.drop_log.append(rt)
            rec.time = raise_t
            rec.deadline = raise_t + fp.watchdog_s * fp.backoff ** attempt
            if tr is not None:
                tr.instant(ekey, rec.device, 0, "drop", raise_t, tag=rt,
                           args={"fault": "signal dropped",
                                 "attempt": attempt})
        else:
            del self.dropped[rt]
            if fp.delays_signal(rt, attempt):
                raise_t += fp.delay_s
                self.delay_log.append(rt)
            self.tags[rt] = raise_t
            self.raised.append(rt)
            if tr is not None:
                tr.raise_tag(rt, raise_t, ekey)
        return True

    def fault_report(self) -> FaultReport:
        return FaultReport(
            dropped=tuple(sorted(self.drop_log, key=repr)),
            delayed=tuple(sorted(self.delay_log, key=repr)),
            retries=tuple(self.retry_log),
            retry_seconds=self.retry_seconds,
        )


def _control_cost(live: list[EngineQueue], c) -> tuple[float, int]:
    """Host packet-creation (seconds, scheduling events) for one device.

    Baseline (``batch=1``): ``control`` per command, one scheduling event
    each.  Batched submission (§7.1): commands are created in groups of up to
    ``batch`` per host scheduling event — the first command of each event
    pays the full ``control``, the rest the amortized ``control_batched``.
    Events span queue boundaries: consecutively submitted batched queues fill
    the same scheduling event (the host builds all their packets in one
    pass).  The event count feeds the host-wakeup power term (§8.4).
    """
    t = 0.0
    events = 0
    room = 0                       # remaining commands in the current event
    for q in live:
        if q.batch <= 1:
            t += len(q.commands) * c.control
            events += len(q.commands)
            room = 0               # an unbatched submission breaks the event
            continue
        for _ in q.commands:
            if room == 0:
                t += c.control
                events += 1
                room = q.batch - 1
            else:
                t += c.control_batched
                room -= 1
    return t, events


def _start_device(sim: _Sim, dev: int, queues: list[EngineQueue],
                  t0: float, key: tuple) -> tuple[float, float, list[_QueueState]]:
    """Host control + doorbells; returns (cstart, cend, queue states).

    ``t0`` is the schedule's release time (DESIGN.md §12): host
    packet-creation may not begin earlier, and prelaunched queues arm
    relative to it.  ``cstart``/``cend`` are the absolute control-phase
    grant/end on the (possibly contended) host timeline; a plain
    simulate() passes ``t0=0`` on fresh timelines, where
    ``cend - t0 == t_control`` exactly.

    Doorbells are serial MMIO writes on the host.  Batched queues
    (``batch > 1``) submitted consecutively ring back-to-back: the first
    rings at the full ``doorbell`` cost, subsequent ones at
    ``doorbell_batched`` (§7.1).  This is deliberately coarser than the
    command-level event accounting of :func:`_control_cost` (which may
    start a new event mid-queue when ``batch`` commands fill up): doorbells
    are per *queue*, so only an intervening unbatched queue resets the
    amortization.  Unbatched queues always pay ``doorbell``.
    """
    c = sim.topo.calib
    tr = sim.trace
    live = [q for q in queues if not q.prelaunched]
    pre = [q for q in queues if q.prelaunched]
    host = sim.timeline(f"host:{dev}")

    t_control, events = _control_cost(live, c)
    if live:
        cstart, cend = host.acquire(t0, t_control)
        if tr is not None:
            # args["events"] = command-creation scheduling events only; the
            # trace-count reconciliation adds full-cost doorbells and the
            # completion drain to rebuild host_events (§14).
            tr.span(f"host:{dev}", dev, key[0], "control", cstart, cend,
                    args={"events": events,
                          "commands": sum(len(q.commands) for q in live)})
    else:
        cstart = cend = t0

    states: list[_QueueState] = []
    batched_seen = False
    for q in live:
        if q.batch > 1 and batched_seen:
            bell_cost = c.doorbell_batched
            full_ring = False
        else:
            bell_cost = c.doorbell
            full_ring = True
            events += 1            # a full-cost ring is its own host event
        # An intervening unbatched submission resets the amortization:
        # the next batched queue rings at full cost again.
        batched_seen = q.batch > 1
        bs, bell = host.acquire(host.free, bell_cost)
        engine_tl = sim.timeline(f"engine:{dev}.{q.engine}")
        engine_tl.acquire(bell, c.fetch)
        if tr is not None:
            tr.span(f"host:{dev}", dev, key[0], "doorbell", bs, bell,
                    args={"engine": q.engine, "full": full_ring})
            tr.span(f"engine:{dev}.{q.engine}", dev, key[0], "fetch",
                    bell, bell + c.fetch)
        states.append(_QueueState(q, bell + c.fetch, engine_tl, key))
    for q in pre:
        if tr is not None:
            tr.instant(f"engine:{dev}.{q.engine}", dev, key[0], "armed",
                       t0 + c.poll_trigger)
        states.append(_QueueState(q, t0 + c.poll_trigger,
                                  sim.timeline(f"engine:{dev}.{q.engine}"), key))
    sim.host_events[key] += events
    return cstart, cend, states


def _finish_device(sim: _Sim, dev: int, cend: float,
                   states: list[_QueueState], key: tuple) -> tuple[float, float, float]:
    """Drain this job's completion signals; returns absolute
    (sched_end, copy_end, total)."""
    c = sim.topo.calib
    sched_end = max((st.start for st in states), default=cend)
    copy_end = max((st.copy_end for st in states), default=sched_end)
    sigs = sim.host_signals.get(key, [])
    fused = sim.fused_signals.get(key, [])
    # The host drains its completion-signal set serially once the last
    # engine signal has landed: one observation per scattered per-queue
    # signal; fused completions (§7.3) share one contiguous completion
    # record, so the sweep pays sync_obs once plus sync_obs_batched per
    # further entry.
    t_obs = len(sigs) * c.sync_obs
    if fused:
        t_obs += c.sync_obs + (len(fused) - 1) * c.sync_obs_batched
    # One host wakeup drains the whole completion set (scattered signals
    # still cost a serial sync_obs read each — time, not an extra wakeup).
    if sigs or fused:
        sim.host_events[key] += 1
    signal_done = max([copy_end] + sigs + fused)
    ds, total = sim.timeline(f"host:{dev}").acquire(signal_done, t_obs)
    if sim.trace is not None and (sigs or fused):
        sim.trace.span(f"host:{dev}", dev, key[0], "sync", ds, total,
                       args={"signals": len(sigs), "fused": len(fused)})
    return sched_end, copy_end, total


def _breakdown(t0: float, cend: float, sched_end: float, copy_end: float,
               total: float) -> PhaseBreakdown:
    """Phase split of one job's absolute milestones relative to ``t0``."""
    return PhaseBreakdown(
        control=cend - t0,
        schedule=max(0.0, sched_end - cend),
        copy=max(0.0, copy_end - sched_end),
        sync=max(0.0, total - copy_end),
    )


def _run(sim: _Sim, jobs: list[tuple[tuple, int, list[EngineQueue], float]]
         ) -> dict[tuple, tuple[float, float, float, list[_QueueState]]]:
    """Heap-based event loop (DESIGN.md §8.2).

    ``jobs`` is a list of (key, device, queues, release) in submission
    order — host control/doorbells are booked eagerly per job in that
    order, so composed callers (§12) must pre-sort by release time.
    Returns key -> (release, cstart, cend, states); phase accounting
    happens in :func:`_finish_device` once the loop drains.

    Each queue enters a heap keyed by its ready time (doorbell + fetch, or
    the poll trigger for prelaunched queues) and runs until it finishes or
    blocks on an unraised tag; blocked queues park on a tag -> waiters map
    and re-enter the heap at the producer's raise time.  Grant order on
    shared timelines is therefore deterministic: ready time, then submission
    order.  A drained heap with parked waiters left is a deadlock, reported
    with the blocked tags.
    """
    started: dict[tuple, tuple[float, float, float, list[_QueueState]]] = {}
    for key, dev, queues, t0 in jobs:
        cstart, cend, states = _start_device(sim, dev, queues, t0, key)
        started[key] = (t0, cstart, cend, states)
    heap: list[tuple[float, int, _QueueState]] = []
    seq = 0
    for _, _, _, states in started.values():
        for st in states:
            heap.append((st.start, seq, st))
            seq += 1
    heapq.heapify(heap)
    waiting: dict[tuple, list[_QueueState]] = {}
    n_waiting = 0

    def wake() -> None:
        nonlocal n_waiting, seq
        for rt in sim.raised:
            ws = waiting.pop(rt, None)
            if ws:
                t = sim.tags[rt]
                for w in ws:
                    heapq.heappush(heap, (t, seq, w))
                    seq += 1
                n_waiting -= len(ws)
        sim.raised.clear()

    while True:
        while heap:
            _, _, st = heapq.heappop(heap)
            if not sim.advance(st):
                waiting.setdefault(st.blocked, []).append(st)
                n_waiting += 1
            if sim.raised:
                wake()
        if not n_waiting:
            break
        # Drained heap with parked waiters: under a FaultPlan, the watchdog
        # re-issues the producer of a dropped tag (§13.2) and the loop
        # continues; otherwise — or once retries are exhausted — this is a
        # deadlock, reported with the full blocked-dependency diagnosis.
        if sim.faults is None or not sim.retry_dropped(waiting):
            raise _deadlock_fault(sim, started, waiting)
        if sim.raised:
            wake()
    return started


def _producers(sim: _Sim, started) -> dict[tuple, str]:
    """Resolved tag -> human description of the command that produces it."""
    out: dict[tuple, str] = {}
    for _, _, _, states in started.values():
        for st in states:
            q = st.q
            for i, c in enumerate(q.commands):
                if c.kind is CmdKind.SIGNAL and c.tag is not None:
                    out.setdefault(
                        sim.resolve(c.tag),
                        f"signal (cmd {i}) on device {q.device} engine {q.engine}")
                if c.fused_tag is not None:
                    out.setdefault(
                        sim.resolve(c.fused_tag),
                        f"fused {c.kind.name.lower()} (cmd {i}) "
                        f"on device {q.device} engine {q.engine}")
    return out


def _nearest_tag(tag: tuple, raised) -> tuple | None:
    """The raised tag most similar to ``tag``: same name, smallest summed
    distance over trailing elements (numeric difference where both are
    numbers, a large constant otherwise).  Ties break on repr — the
    diagnosis stays deterministic.  The usual hit is an off-by-one step or
    chunk index: the breadcrumb that turns a deadlock report into a fix."""
    best = None
    best_key = None
    for cand in raised:
        if not cand or not tag or cand[0] != tag[0] or cand == tag:
            continue
        d = 1000.0 * abs(len(cand) - len(tag))
        for a, b in zip(tag[1:], cand[1:]):
            if isinstance(a, (int, float)) and isinstance(b, (int, float)):
                d += abs(a - b)
            elif a != b:
                d += 1000.0
        k = (d, repr(cand))
        if best_key is None or k < best_key:
            best, best_key = cand, k
    return best


def _deadlock_fault(sim: _Sim, started, waiting: dict) -> SimFault:
    """Build the structured deadlock/fault report (DESIGN.md §13.3): one
    sorted :class:`BlockedWaiter` row per parked queue — device, engine,
    blocked tag, producing command, nearest raised sibling tag — plus the
    watchdog retry history when a FaultPlan was active."""
    producers = _producers(sim, started)
    raised = list(sim.tags)
    rows = []
    for rt, ws in waiting.items():
        for st in ws:
            rows.append(BlockedWaiter(
                device=st.q.device, engine=st.q.engine, tag=rt,
                producer=producers.get(rt),
                nearest=_nearest_tag(rt, raised)))
    rows.sort(key=lambda w: (repr(w.tag), w.device, w.engine))
    lines = [f"deadlocked schedule: {len(rows)} queue(s) parked on "
             f"unsignaled tags"]
    for w in rows:
        line = f"  device {w.device} engine {w.engine} waits on {w.tag!r}"
        if w.producer is not None:
            line += f" [producer: {w.producer}]"
        if w.nearest is not None:
            line += f"; nearest raised: {w.nearest!r}"
        lines.append(line)
    retries = tuple(sim.retry_log)
    if retries:
        lines.append(f"  retry history ({len(retries)} attempt(s)):")
        for r in retries:
            lines.append(
                f"    {r.tag!r} attempt {r.attempt} issued {r.issued_at:.6g}s"
                f" -> {'raised' if r.raised else 'dropped'} {r.completed_at:.6g}s")
    return SimFault("\n".join(lines), waiters=tuple(rows), retries=retries)


def _device_hbm_bytes(queues: list[EngineQueue]) -> int:
    """Local-HBM traffic generated by this device's outbound commands.

    Incoming writes are attributed by the collective-level wrapper (the
    schedule is symmetric so local accounting suffices for relative power).
    Every data kind reads ``size`` bytes locally and a reduction reads both
    operands (``Command.local_read_bytes``), inlined here because chunking
    makes this walk O(chunks).
    """
    total = 0
    for q in queues:
        for c in q.commands:
            if c.kind in DATA_KINDS:
                total += c.size
            elif c.kind is CmdKind.REDUCE:
                total += 2 * c.size
    return total


def simulate(schedule: Schedule, topo: Topology, *,
             symmetric: bool | None = None,
             faults: FaultPlan | None = None,
             record_trace: bool = False) -> SimResult:
    """Execute ``schedule`` on ``topo`` and return a :class:`SimResult`.

    ``symmetric=None`` (default) honors the builder's ``Schedule.symmetric``
    marking: marked schedules run the one-representative-device fast path
    (DESIGN.md §6), everything else runs the full multi-device event loop.
    Pass ``True``/``False`` to override — forcing ``True`` on a schedule that
    is not actually device-symmetric produces wrong (optimistic) timings and
    is only useful for testing the fast path itself.

    ``faults`` injects a seeded :class:`~repro.core.dma.faults.FaultPlan`
    (DESIGN.md §13).  An *empty* plan is normalized to ``None`` — the
    fault-free path runs untouched, bit-identical to passing no plan.  A
    non-empty plan forces the full event loop (faults break the translation
    symmetry the fast path relies on) and fills ``SimResult.fault_report``.

    Raises :class:`~repro.core.dma.faults.SimFault` (a ``RuntimeError``) if
    the schedule deadlocks — a ``wait`` on a tag no remaining queue can
    raise, or a dropped signal whose watchdog retries are exhausted; the
    message carries the sorted per-waiter diagnosis (§13.3).

    ``record_trace=True`` attaches a :class:`~repro.core.dma.trace.SimTrace`
    to ``SimResult.trace`` (DESIGN.md §14).  Recording forces the full event
    loop — the symmetric (§6) and closed-form chunk (§8.3/§9.2) fast paths
    commit aggregate timeline updates and would skip per-command spans — but
    ``latency`` (and every per-device phase) stays bit-identical to the
    unrecorded run; coalesced busy intervals agree to the same ulp tolerance
    the fast-path equivalence tests pin (closed forms multiply where the
    loop accumulates).
    """
    if faults is not None and faults.is_empty():
        faults = None
    sym = schedule.symmetric if symmetric is None else symmetric
    if faults is not None or record_trace:
        sym = False
    trace = TraceRecorder() if record_trace else None
    devices = schedule.devices

    def run_full(run_devices: list[int]) -> dict[int, PhaseBreakdown]:
        started = _run(sim, [((0, d), d, schedule.queues_for(d), 0.0)
                             for d in run_devices])
        return {d: _breakdown(t0, cend, *_finish_device(sim, d, cend, states, key))
                for key, (t0, cstart, cend, states) in started.items()
                for d in (key[1],)}

    if sym and len(devices) > 1:
        rep = devices[0]
        sim = _Sim(topo, rep)
        rep_queues = schedule.queues_for(rep)
        breakdown = run_full([rep])[rep]
        per_device = {d: breakdown for d in devices}
        engines = {d: len({q.engine for q in rep_queues}) for d in devices}
        hbm = {d: _device_hbm_bytes(rep_queues) for d in devices}
        events = {d: sim.host_events.get((0, rep), 0) for d in devices}
        atomics = {d: sim.engine_atomics.get(rep, 0) for d in devices}
        reduces = {d: sim.reduce_chunks.get(rep, 0) for d in devices}
    else:
        sim = _Sim(topo, None, faults, trace)
        per_device = run_full(devices)
        engines = {d: schedule.engines_used(d) for d in devices}
        hbm = {d: _device_hbm_bytes(schedule.queues_for(d)) for d in devices}
        events = {d: sim.host_events.get((0, d), 0) for d in devices}
        atomics = {d: sim.engine_atomics.get(d, 0) for d in devices}
        reduces = {d: sim.reduce_chunks.get(d, 0) for d in devices}
        rep = None

    latency = max(b.total for b in per_device.values())
    return SimResult(
        latency=latency,
        per_device=per_device,
        engines_used=engines,
        hbm_bytes=hbm,
        timelines={k: tuple(tl.intervals) for k, tl in sim.timelines.items()},
        busy={k: tl.busy for k, tl in sim.timelines.items()},
        host_events=events,
        engine_atomics=atomics,
        reduce_chunks=reduces,
        representative=rep,
        fault_report=sim.fault_report() if faults is not None else None,
        trace=_finish_trace(trace, faults),
    )


def _finish_trace(trace: TraceRecorder | None,
                  faults: FaultPlan | None) -> SimTrace | None:
    """Freeze the recorder (plus fault windows, §14) into a SimTrace."""
    if trace is None:
        return None
    if faults is not None:
        trace.fault_windows(faults)
    return trace.finish()


@dataclasses.dataclass(frozen=True)
class ScheduleOutcome:
    """One schedule's timing inside a composed run (DESIGN.md §12).

    ``release`` is the arrival time passed to :func:`run_composed`;
    ``start`` is when the shared host first granted its control phase
    (``start - release`` is pure queueing delay); ``latency`` is the
    request-observed completion measured from ``release`` — the max over
    the schedule's per-device phase sums, the *same arithmetic*
    ``simulate()`` uses for ``SimResult.latency``, so under zero contention
    (or K=1) the two are bit-identical.  ``finish`` is the absolute
    completion, ``release + latency``.
    """

    index: int
    name: str
    release: float
    start: float
    latency: float
    per_device: dict[int, PhaseBreakdown]

    @property
    def finish(self) -> float:
        """Absolute completion time of the schedule's last device."""
        return self.release + self.latency


@dataclasses.dataclass(frozen=True)
class ComposedResult:
    """K schedules executed in one resource world (:func:`run_composed`).

    ``outcomes[k]`` times schedule k against its own release;``result`` is
    the composed world's :class:`SimResult` — ``latency`` is the makespan
    (time origin 0), ``timelines``/``busy`` cover every shared resource,
    per-device counters aggregate across schedules, and ``per_device``
    holds the breakdown of the last-finishing schedule on each device
    measured from 0 (so ``latency == max(total)`` still holds).
    """

    outcomes: tuple[ScheduleOutcome, ...]
    result: SimResult

    @property
    def makespan(self) -> float:
        return self.result.latency


def _namespace_schedule(schedule: Schedule, k: int) -> Schedule:
    """Prefix every tag/fused_tag with the schedule index (DESIGN.md §12).

    Streams composed into one world must never satisfy each other's waits:
    schedule k's tag ``(name, dev, step, ...)`` becomes
    ``(k, name, dev, step, ...)``.  The rewrite is memoized by command
    *identity* so a run of identical chunk commands (one shared instance,
    §8.3) maps to one shared rewritten instance — the closed-form chunk-run
    detection survives composition.  Tagless commands pass through
    unchanged.
    """
    memo: dict[int, object] = {}

    def rewrite(c):
        nc = memo.get(id(c))
        if nc is None:
            if c.tag is None and c.fused_tag is None:
                nc = c
            else:
                nc = dataclasses.replace(
                    c,
                    tag=None if c.tag is None else (k,) + tuple(c.tag),
                    fused_tag=(None if c.fused_tag is None
                               else (k,) + tuple(c.fused_tag)))
            memo[id(c)] = nc
        return nc

    queues = tuple(
        dataclasses.replace(q, commands=tuple(rewrite(c) for c in q.commands))
        for q in schedule.queues)
    return dataclasses.replace(schedule, queues=queues, symmetric=False)


def run_composed(schedules, topo: Topology,
                 release_times=None,
                 faults: FaultPlan | None = None,
                 record_trace: bool = False) -> ComposedResult:
    """Execute K independent schedules in ONE resource world (§12).

    ``schedules`` is a sequence of :class:`Schedule`; ``release_times``
    (default all 0) gives each stream's arrival time — its host control may
    not start earlier.  All host/engine/link/NIC timelines are shared, so
    concurrent streams contend exactly like concurrent collectives on real
    hardware; tags are namespaced per schedule so streams stay causally
    independent.  Host control/doorbells are granted in release order (ties:
    argument order), matching a driver that submits work as it arrives.

    Composed runs always execute the full event loop: the symmetric fast
    path (§6) models ONE schedule's translation symmetry and bails out here
    by construction.  With K=1 and release 0 the composed result is
    bit-identical to ``simulate(schedule, topo, symmetric=False)`` — and
    hence, for symmetric schedules, to ``simulate(schedule, topo)``.

    ``faults`` threads a :class:`~repro.core.dma.faults.FaultPlan` through
    the composed world (DESIGN.md §13) — fault windows are in the composed
    run's time frame (0 = the first release).  An empty plan is normalized
    to ``None`` (bit-identical to no plan).

    ``record_trace=True`` attaches a :class:`~repro.core.dma.trace.SimTrace`
    to ``ComposedResult.result.trace`` (§14); composed spans carry their
    schedule index so per-stream tracks render per-device/per-resource with
    the namespace in the slice label.  Recording never changes timing: the
    composed path already runs the full event loop.
    """
    schedules = list(schedules)
    if faults is not None and faults.is_empty():
        faults = None
    if not schedules:
        raise ValueError("run_composed needs at least one schedule")
    if release_times is None:
        release_times = [0.0] * len(schedules)
    release_times = [float(t) for t in release_times]
    if len(release_times) != len(schedules):
        raise ValueError(
            f"{len(schedules)} schedules but {len(release_times)} release times")
    if any(t < 0.0 for t in release_times):
        raise ValueError("release times must be >= 0")

    trace = TraceRecorder() if record_trace else None
    sim = _Sim(topo, None, faults, trace)
    namespaced = [_namespace_schedule(s, k) for k, s in enumerate(schedules)]
    jobs = []
    for k, (ns, t0) in enumerate(zip(namespaced, release_times)):
        for d in ns.devices:
            jobs.append(((k, d), d, ns.queues_for(d), t0))
    jobs.sort(key=lambda j: j[3])       # stable: ties keep submission order
    started = _run(sim, jobs)

    # Per-job milestones, finished in submission order (the host drains
    # completion sets serially; order is the same deterministic ready-time/
    # submission order the event loop used).
    raw: dict[tuple, tuple[float, float, float, float, float, float]] = {}
    for key, (t0, cstart, cend, states) in started.items():
        sched_end, copy_end, total = _finish_device(sim, key[1], cend, states, key)
        raw[key] = (t0, cstart, cend, sched_end, copy_end, total)

    outcomes = []
    for k, (s, ns, t0) in enumerate(zip(schedules, namespaced, release_times)):
        devs = ns.devices
        per_device = {}
        for d in devs:
            _, _, cend, sched_end, copy_end, total = raw[(k, d)]
            per_device[d] = _breakdown(t0, cend, sched_end, copy_end, total)
        outcomes.append(ScheduleOutcome(
            index=k,
            name=s.name,
            release=t0,
            start=min(raw[(k, d)][1] for d in devs),
            latency=max(b.total for b in per_device.values()),
            per_device=per_device,
        ))

    # Composed world view: on each device, report the breakdown of the
    # last-finishing schedule measured from time 0, so the SimResult keeps
    # its `latency == max(per_device total)` invariant (= the makespan).
    all_devices = sorted({d for ns in namespaced for d in ns.devices})
    per_device = {}
    engines: dict[int, int] = {}
    hbm: dict[int, int] = {}
    events: dict[int, int] = {}
    for d in all_devices:
        keys = [(k, d) for k, ns in enumerate(namespaced) if d in ns.devices]
        last = max(keys, key=lambda key: raw[key][5])
        _, _, cend, sched_end, copy_end, total = raw[last]
        per_device[d] = _breakdown(0.0, cend, sched_end, copy_end, total)
        engines[d] = len({q.engine for ns in namespaced for q in ns.queues_for(d)})
        hbm[d] = sum(_device_hbm_bytes(ns.queues_for(d)) for ns in namespaced)
        events[d] = sum(sim.host_events.get(key, 0) for key in keys)

    # max-of-totals rather than max(outcome.finish): bitwise the same
    # arithmetic simulate() uses (sum of phases), so K=1 stays bit-identical.
    result = SimResult(
        latency=max(b.total for b in per_device.values()),
        per_device=per_device,
        engines_used=engines,
        hbm_bytes=hbm,
        timelines={k2: tuple(tl.intervals) for k2, tl in sim.timelines.items()},
        busy={k2: tl.busy for k2, tl in sim.timelines.items()},
        host_events=events,
        engine_atomics={d: sim.engine_atomics.get(d, 0) for d in all_devices},
        reduce_chunks={d: sim.reduce_chunks.get(d, 0) for d in all_devices},
        representative=None,
        fault_report=sim.fault_report() if faults is not None else None,
        trace=_finish_trace(trace, faults),
    )
    return ComposedResult(outcomes=tuple(outcomes), result=result)


def single_copy_breakdown(size: int, topo: Topology, *, prelaunch: bool = False) -> PhaseBreakdown:
    """Fig. 7: phase breakdown of one GPU-to-GPU copy of ``size`` bytes."""
    from . import commands as cmd

    cmds = (cmd.copy(0, 1, size), cmd.signal())
    if prelaunch:
        cmds = (cmd.poll(),) + cmds
    q = EngineQueue(device=0, engine=0, commands=cmds, prelaunched=prelaunch)
    res = simulate(Schedule(name="single_copy", queues=(q,)), topo)
    return res.per_device[0]
