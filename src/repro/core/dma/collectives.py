"""Collective schedule builders — the paper's DMA collective designs (§4).

Each builder turns (topology, collective size, variant) into an explicit
:class:`Schedule` of engine queues, exactly as described in the paper:

* ``pcpy``  — baseline: one engine per peer, one copy+signal each (Fig. 8).
* ``bcst``  — all-gather only: broadcast commands pair up peers, halving
  commands/engines/signals (Fig. 9).
* ``swap``  — all-to-all only: in-place pairwise exchange; each pair's
  transfer is ONE command executed by one of the two devices (Fig. 10).
* ``b2b``   — all copies back-to-back on a single engine, one signal (Fig. 11).
* ``prelaunch_<v>`` — any of the above with queues armed ahead of time behind
  a ``poll`` command (Fig. 12).

Topology awareness (DESIGN.md §3): on a non-fully-connected topology the
direct variants above still build the same queue shapes — the simulator
routes each transfer over the torus (multi-hop, contended links).  Two
additional *neighbor-only* variants render the JAX ``ring``/``bidir_ring``
collectives of :mod:`repro.core.collectives` as explicit schedules with real
cross-device dependencies (``wait`` on the predecessor's tagged signal):

* ``ring``       — unidirectional ring over :meth:`Topology.ring_order`,
  chained on ONE engine; all-gather forwards the received shard each step,
  all-to-all uses the rotation algorithm (step ``r`` forwards the ``n-1-r``
  chunks still in transit).
* ``bidir_ring`` — all-gather only: both directions per step (the step-0
  send is a single-read ``bcst`` feeding both neighbors), halving steps.

Optimized command streams (DESIGN.md §7): any variant may be prefixed with
``opt_`` (``opt_pcpy``, ``opt_prelaunch_b2b``, ``opt_ring``, ...) to run the
same schedule through :func:`repro.core.dma.optimizations.optimize` — batched
submission, SDMA queue-slot parallelism and fused write+signal.  The ring /
bidir-ring / rotation-AA builders benefit chiefly from fused signaling (each
chained step drops its standalone semaphore command) and batching; the
one-shot builders additionally pick up multi-queue dispatch.

Pipelined ring collectives (DESIGN.md §9): the ``pipe_b2b`` /
``pipe_bidir_ring`` variants re-render the chained rings with *per-chunk
semaphore signaling* — every shard is split into ``pipe_depth`` chunk
commands (bounded by the sDMA packet ceiling), each chunk raises its own
fused chunk-indexed tag, each ring step runs on its own engine queue, and
step *k+1* waits per-chunk: it starts forwarding chunk *i* the moment chunk
*i* of step *k* landed, instead of waiting for the whole shard.  Successive
ring steps overlap on distinct engines while the per-link wire floor is
kept saturated; ``per_chunk_signaling=False`` builds the same queue shape
with final-chunk-only waits (the control arm of the §9 claims).

Reduce collectives (DESIGN.md §10): :func:`reduce_scatter_schedule` renders
the ring family with a consumer-side reduction per arrived shard —
``ring_rs`` / ``bidir_ring_rs`` reduce at transfer granularity, the
``pipe_ring_rs`` / ``pipe_bidir_ring_rs`` variants reduce each chunk the
moment it lands and forward the reduced partial while later chunks are
still in flight (the compute/communication overlap model of
arXiv:2512.10236).  :func:`allreduce_schedule` composes a reduce-scatter
with the matching (pipelined) all-gather: each device's terminal reductions
raise result tags that gate the all-gather's source queue chunk by chunk,
so the gather phase starts on the first *reduced* chunk instead of the
whole reduced shard.

Hierarchical multi-node collectives (DESIGN.md §11): on a topology with
``n_nodes > 1`` the ``hier_`` variants split every collective into an
intra-node tier (DMA links) and an inter-node tier (each device's NIC):

* ``hier_ring`` (all-gather) — ring all-gather across the *rank group*
  (same local rank, one device per node, each hop a NIC transfer), then a
  ring all-gather of the gathered node-blocks around each node's local
  ring.  Only ``(n_nodes - 1) / n_nodes`` of the payload ever crosses a
  NIC, vs everything on a flat ring whose node-boundary hops are NICs.
* ``hier_pipe`` (all-gather) — same two tiers, but the intra tier runs one
  sub-round per node-block and sub-round ``j`` is gated only on inter-node
  arrival ``j - 1``, so the local gather of block ``j`` overlaps the NIC
  transfer of block ``j + 1``.
* ``hier_ring_rs`` / ``hier_pipe_rs`` (reduce-scatter / all-reduce) — the
  reverse composition: ring reduce-scatter of node-blocks within the node,
  then ring reduce-scatter of the result shard across the rank group; the
  ``pipe`` rendering slices the inter tier per result shard so NIC sends
  start on the first node-reduced slice.

All ``hier_`` builders are translation invariant (every device runs the
same queue shapes; NICs are sender-owned) so the symmetric fast path (§6)
applies whenever each node's local ring closes on physical neighbors, and
the ``opt_`` / ``prelaunch_`` prefixes compose exactly as for the flat
variants.

Size convention: ``size`` is the collective's *total message size* as in the
paper's figures (1KB–4GB).  Each device's per-peer shard is ``size / n``.

Representative-only builds (DESIGN.md §11.3): every public builder takes
``device=<d>`` to construct only that device's queues — the dispatch sweep
builds just the symmetric representative, which is what makes schedule
construction (the sweep's dominant cost) O(1) in device count.
"""
from __future__ import annotations

import dataclasses

from . import commands as cmd
from .commands import (CmdKind, DATA_KINDS, EngineQueue, Schedule,
                       chunk_schedule, chunk_sizes, chunk_tag, chunked_copies,
                       chunked_reduces)
from .optimizations import OptimizationConfig, optimize, parse_optimized
from .topology import Topology

AG_VARIANTS = ("pcpy", "bcst", "b2b", "ring", "bidir_ring",
               "pipe_b2b", "pipe_bidir_ring")
AA_VARIANTS = ("pcpy", "swap", "b2b", "ring", "pipe_b2b")
RS_VARIANTS = ("ring_rs", "bidir_ring_rs", "pipe_ring_rs", "pipe_bidir_ring_rs")

#: Hierarchical two-tier variants (DESIGN.md §11) — only buildable on
#: topologies with ``n_nodes > 1``; kept out of the flat tuples so existing
#: single-node sweeps/claims are untouched.
HIER_AG_VARIANTS = ("hier_ring", "hier_pipe")
HIER_RS_VARIANTS = ("hier_ring_rs", "hier_pipe_rs")

#: Fused compute-collective variants (DESIGN.md §15).  ``seq`` is the
#: sequential baseline (same GEMM tiles and collective pipeline, but the
#: collective is gated on the *final* tile / the GEMM on the *final*
#: arrival); ``fused_*_d{2,4,8}`` overlap at that pipeline depth, and the
#: GEMM+reduce-scatter axis additionally picks the per-chunk reduction
#: placement (``cu`` vs ``engine``).
FUSED_RS_VARIANTS = ("seq", "fused_cu_d2", "fused_cu_d4", "fused_cu_d8",
                     "fused_engine_d2", "fused_engine_d4", "fused_engine_d8")
FUSED_AG_VARIANTS = ("seq", "fused_d2", "fused_d4", "fused_d8")

#: Default GEMM arithmetic intensity (FLOPs per byte of collective payload)
#: of the fused builders (DESIGN.md §15).  2 * K for a bf16 GEMM whose
#: reduction dimension K = 16384 — a large-model layer where the tile
#: stream is compute-bound on the modeled platforms, so the engine-side
#: reduce placement has CU slack to win at bandwidth-bound sizes.
GEMM_FLOPS_PER_BYTE = 32768

#: Default pipeline depth of the ``pipe_`` variants (DESIGN.md §9): the
#: minimum number of chunk commands a shard is split into.  Deeper splits
#: keep shrinking the per-step fill latency but pay per-chunk packet/issue
#: costs; depth 4 is where the chunk-count sweep stops improving on the
#: modeled platforms (the "sweep ceiling" of the §9 claims).
PIPE_DEPTH = 4


def _maybe_chunk(sched: Schedule, topo: Topology,
                 max_chunk_bytes: int | None) -> Schedule:
    """Split oversized copies into sDMA chunk commands (DESIGN.md §8.1).

    ``None`` uses the topology's calibrated ``Calibration.max_chunk_bytes``
    (the hardware packet ceiling); ``0`` disables chunking (used by tests
    comparing chunked and monolithic timing).  Runs before the optimization
    transforms so batching/slots/fusion operate on the chunked stream.
    """
    mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
    return chunk_schedule(sched, mcb)


def _maybe_prelaunch(queues: list[EngineQueue], prelaunch: bool) -> tuple[EngineQueue, ...]:
    if not prelaunch:
        return tuple(queues)
    out = []
    for q in queues:
        out.append(
            EngineQueue(
                device=q.device,
                engine=q.engine,
                commands=(cmd.poll(),) + q.commands,
                prelaunched=True,
            )
        )
    return tuple(out)


def parse_variant(variant: str) -> tuple[str, bool]:
    if variant.startswith("prelaunch_"):
        return variant[len("prelaunch_"):], True
    return variant, False


def _maybe_optimize(sched: Schedule, optimized: bool,
                    config: OptimizationConfig | None) -> Schedule:
    return optimize(sched, config) if optimized else sched


def _bidir_split(n: int) -> tuple[int, int]:
    """(forward, backward) step split of the ``n - 1`` ring deliveries
    shared by EVERY bidirectional builder (all-gather and reduce-scatter)
    and by the all-reduce result-tag gating — these must stay in lockstep,
    or the gather phase waits on a terminal-reduction tag the reduce phase
    never raises (``ceil``/``floor`` of ``(n-1)/2``)."""
    n_fwd = (n - 1 + 1) // 2
    return n_fwd, (n - 1) - n_fwd


def _ring_neighbors(topo: Topology,
                    device: int | None = None) -> dict[int, tuple[int, int]]:
    """device -> (predecessor, successor) along the topology's ring embedding.

    ``device`` restricts the map to that one device (representative-only
    builds, DESIGN.md §11.3) — neighbors are still resolved on the full
    ring, only the iteration shrinks.
    """
    order = topo.ring_order()
    n = len(order)
    items = ((order[i], (order[(i - 1) % n], order[(i + 1) % n]))
             for i in range(n))
    if device is None:
        return dict(items)
    return {d: ps for d, ps in items if d == device}


def _ring_closes_on_neighbors(topo: Topology) -> bool:
    """True when every consecutive ring_order pair (incl. the wraparound) is a
    single physical link.  On odd-by-odd torus grids the snake ring's
    wraparound is multi-hop, which makes the devices asymmetric — such rings
    must run the full simulation, not the symmetric fast path."""
    order = topo.ring_order()
    n = len(order)
    return all(topo.is_neighbor(order[i], order[(i + 1) % n]) for i in range(n))


def _ring_ag_queues(topo: Topology, shard: int,
                    device: int | None = None) -> list[EngineQueue]:
    """Unidirectional ring all-gather: n-1 chained forward steps per device."""
    n = topo.n_devices
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        cs: list[cmd.Command] = []
        for k in range(n - 1):
            if k > 0:
                cs.append(cmd.wait(("ag", pred, k - 1)))
            cs.append(cmd.copy(d, succ, shard))
            cs.append(cmd.signal(("ag", d, k)))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(cs)))
    return queues


def _bidir_ring_ag_queues(topo: Topology, shard: int,
                          device: int | None = None) -> list[EngineQueue]:
    """Bidirectional ring all-gather: ceil((n-1)/2) forward + floor((n-1)/2)
    backward deliveries; the step-0 send reads the local shard ONCE for both
    directions (a bcst command), covering forward AND backward distance 1,
    so the backward chain adds ``n_bwd - 1`` further steps (distances
    ``2..n_bwd``) — every device receives exactly ``n - 1`` distinct shards
    (the ``n_bwd``-distance shard arrives from the forward side only)."""
    n = topo.n_devices
    n_fwd, n_bwd = _bidir_split(n)
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        fwd: list[cmd.Command] = []
        if n == 2:
            fwd.append(cmd.copy(d, succ, shard))
        else:
            fwd.append(cmd.bcst(d, succ, pred, shard))
        fwd.append(cmd.signal(("agf", d, 0)))
        if n_bwd > 1 and n > 2:
            fwd.append(cmd.signal(("agb", d, 0)))
        for k in range(1, n_fwd):
            fwd.append(cmd.wait(("agf", pred, k - 1)))
            fwd.append(cmd.copy(d, succ, shard))
            fwd.append(cmd.signal(("agf", d, k)))
        fwd.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(fwd)))

        if n_bwd > 1 and n > 2:
            bwd: list[cmd.Command] = []
            for k in range(1, n_bwd):
                bwd.append(cmd.wait(("agb", succ, k - 1)))
                bwd.append(cmd.copy(d, pred, shard))
                bwd.append(cmd.signal(("agb", d, k)))
            bwd.append(cmd.signal())
            queues.append(EngineQueue(d, 1, tuple(bwd)))
    return queues


def _ring_aa_queues(topo: Topology, shard: int,
                    device: int | None = None) -> list[EngineQueue]:
    """Rotation ring all-to-all: every chunk moves one hop per round until it
    reaches its destination, so round r forwards n-1-r chunks."""
    n = topo.n_devices
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        cs: list[cmd.Command] = []
        for r in range(n - 1):
            if r > 0:
                cs.append(cmd.wait(("aar", pred, r - 1)))
            cs.append(cmd.copy(d, succ, (n - 1 - r) * shard))
            cs.append(cmd.signal(("aar", d, r)))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(cs)))
    return queues


def _pipe_granularity(payload: int, depth: int, mcb: int) -> int:
    """Chunk granularity of a pipelined transfer (DESIGN.md §9): split
    ``payload`` into at least ``depth`` chunks, never exceeding the sDMA
    packet ceiling ``mcb`` (``mcb <= 0`` = ceiling disabled)."""
    if depth < 1:
        raise ValueError(f"pipe_depth must be >= 1, got {depth}")
    g = max(1, -(-payload // depth))
    return min(g, mcb) if mcb > 0 else g


def _pipe_ring_ag_queues(topo: Topology, shard: int, granularity: int,
                         per_chunk: bool,
                         device: int | None = None) -> list[EngineQueue]:
    """Pipelined unidirectional ring all-gather (``pipe_b2b``, DESIGN.md §9).

    One engine queue per ring step: step ``k`` forwards the shard received
    in step ``k-1`` as chunk commands, each raising a fused chunk-indexed
    tag, and waits on its predecessor *per chunk* — chunk ``i`` of step
    ``k`` issues as soon as chunk ``i`` of step ``k-1`` landed, so
    successive ring steps overlap on distinct engines while every link
    stays back-to-back at the ring's wire floor.  With
    ``per_chunk=False`` each step waits only on the predecessor's final
    chunk (the serialized control arm).  Only the final step notifies the
    host: its completion transitively implies every earlier chained step.
    """
    n = topo.n_devices
    c = len(chunk_sizes(shard, granularity))
    last = c - 1
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        for k in range(n - 1):
            tag = ("pag", d, k) if k < n - 2 else None
            copies = chunked_copies(CmdKind.COPY, d, (succ,), shard,
                                    granularity, tag, per_chunk=per_chunk)
            cs: list[cmd.Command] = []
            for i, cc in enumerate(copies):
                if k > 0 and (per_chunk or i == 0):
                    w = i if per_chunk else last
                    cs.append(cmd.wait(chunk_tag(("pag", pred, k - 1), w)))
                cs.append(cc)
            if k == n - 2:
                cs.append(cmd.signal())
            queues.append(EngineQueue(d, k % topo.n_engines, tuple(cs)))
    return queues


def _pipe_bidir_ag_queues(topo: Topology, shard: int, granularity: int,
                          per_chunk: bool,
                          device: int | None = None) -> list[EngineQueue]:
    """Pipelined bidirectional ring all-gather (``pipe_bidir_ring``, §9).

    The step-0 ``bcst`` feeds both directions reading the local shard once;
    its per-chunk tags unblock the forward AND backward step-1 queues chunk
    by chunk — in the chained bidir ring the backward engine idles until the
    *whole* bcst finished, which is the largest stall per-chunk signaling
    removes (a full shard's wire time at bandwidth-bound sizes).

    When steps outnumber engines, each direction's chain wraps onto its own
    engine subset (forward on the lower half, backward on the upper half).
    Sharing an engine *within* a chain keeps wake times strictly staggered
    (step ``k+E`` only unblocks after step ``k+E-1``), so grant order on the
    shared engine is unambiguous; mixing the two chains on one engine would
    tie their wake times exactly (the directions are mirror-symmetric) and
    leave the interleaving to the event loop's submission-order tie-break,
    which is not translation invariant — the schedule would stop being
    device-symmetric in the full simulation.
    """
    n = topo.n_devices
    n_fwd, n_bwd = _bidir_split(n)
    e_fwd = max(1, (topo.n_engines + 1) // 2)
    e_bwd = max(1, topo.n_engines - e_fwd)
    c = len(chunk_sizes(shard, granularity))
    last = c - 1
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        # step 0: one read feeds both directions (copy when n == 2).
        kind = CmdKind.COPY if n == 2 else CmdKind.BCST
        dsts = (succ,) if n == 2 else (succ, pred)
        tag = ("pg0", d, 0) if n > 2 else None
        cs = list(chunked_copies(kind, d, dsts, shard, granularity, tag,
                                 per_chunk=per_chunk))
        if n_fwd == 1:
            cs.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(cs)))
        # The bcst covers distance 1 BOTH ways, so the backward chain adds
        # n_bwd - 1 steps (distances 2..n_bwd) — n - 1 deliveries total,
        # mirroring _bidir_ring_ag_queues.
        for name_prev, name, peer, steps in (
                ("pg0", "pagf", pred, range(1, n_fwd)),
                ("pg0", "pagb", succ, range(1, n_bwd))):
            n_last = steps.stop - 1
            for k in steps:
                prev = name_prev if k == 1 else name
                tag = (name, d, k) if k < n_last else None
                target = succ if name == "pagf" else pred
                copies = chunked_copies(CmdKind.COPY, d, (target,), shard,
                                        granularity, tag, per_chunk=per_chunk)
                cs = []
                for i, cc in enumerate(copies):
                    if per_chunk or i == 0:
                        w = i if per_chunk else last
                        cs.append(cmd.wait(chunk_tag((prev, peer, k - 1), w)))
                    cs.append(cc)
                if k == n_last:
                    cs.append(cmd.signal())
                if name == "pagf":
                    e = k % e_fwd
                else:
                    # min(): on a 1-engine device both chains share engine 0
                    # (no phantom engine index past n_engines - 1).
                    e = min(e_fwd + ((k - 1) % e_bwd), topo.n_engines - 1)
                queues.append(EngineQueue(d, e, tuple(cs)))
    return queues


def _pipe_aa_queues(topo: Topology, shard: int, depth: int, mcb: int,
                    per_chunk: bool,
                    device: int | None = None) -> list[EngineQueue]:
    """Pipelined rotation ring all-to-all (``pipe_b2b``, DESIGN.md §9).

    Round ``r`` forwards the ``(n-1-r) * shard`` bytes still in transit as
    ``depth`` chunk commands (bounded by the packet ceiling).  Chunk ``i``
    of round ``r`` forwards bytes that arrived *after* the local shard of
    round ``r-1``, so its per-chunk wait resolves to the predecessor chunk
    covering offset ``(i+1)*g_r + shard`` — the dependency lands near the
    END of the previous round's stream (the rotation's forwarded payload is
    the tail of what arrived), which is why rotation all-to-all gains far
    less from per-chunk signaling than the all-gather rings (§9.3).
    """
    n = topo.n_devices
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        for r in range(n - 1):
            payload = (n - 1 - r) * shard
            g_r = _pipe_granularity(payload, depth, mcb)
            tag = ("paa", d, r) if r < n - 2 else None
            copies = chunked_copies(CmdKind.COPY, d, (succ,), payload, g_r,
                                    tag, per_chunk=per_chunk)
            cs: list[cmd.Command] = []
            if r > 0:
                prev_payload = (n - r) * shard
                g_p = _pipe_granularity(prev_payload, depth, mcb)
                c_prev = len(chunk_sizes(prev_payload, g_p))
            for i, cc in enumerate(copies):
                if r > 0 and (per_chunk or i == 0):
                    if per_chunk:
                        need = (i + 1) * g_r + shard
                        dep = min(-(-need // g_p) - 1, c_prev - 1)
                    else:
                        dep = c_prev - 1
                    cs.append(cmd.wait(chunk_tag(("paa", pred, r - 1), dep)))
                cs.append(cc)
            if r == n - 2:
                cs.append(cmd.signal())
            queues.append(EngineQueue(d, r % topo.n_engines, tuple(cs)))
    return queues


def _ring_rs_queues(topo: Topology, shard: int, *,
                    ar: bool = False,
                    device: int | None = None) -> list[EngineQueue]:
    """Unidirectional ring reduce-scatter (DESIGN.md §10): n-1 chained
    send steps per device, each (after step 0) preceded by the reduction of
    the predecessor's arrived partial, plus the terminal reduction that
    folds the last arrival into the device's result shard.  Tags are
    transfer-granular; chunking splits the copies AND the reductions at the
    same grain (``chunk_schedule``).  With ``ar=True`` the terminal
    reduction raises ``("arf", d, 0)`` — the all-reduce chaining hook.
    """
    n = topo.n_devices
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        cs: list[cmd.Command] = []
        for k in range(n - 1):
            if k > 0:
                cs.append(cmd.reduce_tag(("rs", pred, k - 1), shard))
            cs.append(cmd.copy(d, succ, shard))
            cs.append(cmd.signal(("rs", d, k)))
        cs.append(cmd.reduce_tag(("rs", pred, n - 2), shard,
                                 ("arf", d, 0) if ar else None))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(cs)))
    return queues


def _bidir_ring_rs_queues(topo: Topology, shard: int, *,
                          ar: bool = False,
                          device: int | None = None) -> list[EngineQueue]:
    """Bidirectional ring reduce-scatter (DESIGN.md §10): partials flow in
    both directions — the forward chain accumulates the ``n_fwd``
    predecessors' contributions, the backward chain the ``n_bwd``
    successors' — and each device folds both terminal partials into its
    result shard (its own contribution seeds the accumulator).  Every
    device reduces exactly ``n - 1`` arrived shards, mirroring
    ``_bidir_ring_ag_queues``'s ``n - 1`` deliveries.  Unlike the bidir
    all-gather there is no step-0 ``bcst``: the two directions carry
    *different* partials, so step 0 is one copy per direction.
    """
    n = topo.n_devices
    n_fwd, n_bwd = _bidir_split(n)
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        for name, peer, target, steps, raise_name, engine in (
                ("rsf", pred, succ, n_fwd, "arf", 0),
                ("rsb", succ, pred, n_bwd, "arb",
                 min(1, topo.n_engines - 1))):
            if steps == 0:
                continue
            cs: list[cmd.Command] = []
            cs.append(cmd.copy(d, target, shard))
            cs.append(cmd.signal((name, d, 0)))
            for k in range(1, steps):
                cs.append(cmd.reduce_tag((name, peer, k - 1), shard))
                cs.append(cmd.copy(d, target, shard))
                cs.append(cmd.signal((name, d, k)))
            cs.append(cmd.reduce_tag((name, peer, steps - 1), shard,
                                     (raise_name, d, 0) if ar else None))
            cs.append(cmd.signal())
            queues.append(EngineQueue(d, engine, tuple(cs)))
    return queues


def _pipe_ring_rs_queues(topo: Topology, shard: int, granularity: int,
                         per_chunk: bool, *, ar: bool = False,
                         device: int | None = None) -> list[EngineQueue]:
    """Pipelined unidirectional ring reduce-scatter (``pipe_ring_rs``,
    DESIGN.md §10).

    One engine queue per ring step, like ``_pipe_ring_ag_queues``, but step
    ``k >= 1`` *reduces* each arrived chunk before forwarding the reduced
    partial: chunk ``i``'s reduction blocks on chunk ``i`` of the
    predecessor's step ``k-1`` transfer, so the reduce+forward of chunk
    ``i`` overlaps the wire time of chunk ``i+1`` — the finer-grain
    compute/communication overlap of arXiv:2512.10236.  A terminal
    reduce-only queue folds the last arrival into the result shard and
    notifies the host.  Every send step carries per-chunk tags (its
    consumer reduces every arrival), unlike the all-gather rings where the
    last step's payload is unconsumed.  ``per_chunk=False`` blocks every
    chunk reduction on the predecessor's final chunk (the control arm).
    """
    n = topo.n_devices
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        for k in range(n - 1):
            copies = chunked_copies(CmdKind.COPY, d, (succ,), shard,
                                    granularity, ("prs", d, k),
                                    per_chunk=per_chunk)
            if k == 0:
                cs = list(copies)
            else:
                reduces = chunked_reduces(("prs", pred, k - 1), shard,
                                          granularity, per_chunk=per_chunk)
                cs = []
                for r, cc in zip(reduces, copies):
                    cs.append(r)
                    cs.append(cc)
            queues.append(EngineQueue(d, k % topo.n_engines, tuple(cs)))
        cs = list(chunked_reduces(("prs", pred, n - 2), shard, granularity,
                                  per_chunk=per_chunk,
                                  raise_tag=("arf", d, 0) if ar else None))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, (n - 1) % topo.n_engines, tuple(cs)))
    return queues


def _pipe_bidir_rs_queues(topo: Topology, shard: int, granularity: int,
                          per_chunk: bool, *, ar: bool = False,
                          device: int | None = None) -> list[EngineQueue]:
    """Pipelined bidirectional ring reduce-scatter (``pipe_bidir_ring_rs``,
    DESIGN.md §10): the two partial chains of ``_bidir_ring_rs_queues``
    with per-chunk reductions and per-chunk tags.  As in
    ``_pipe_bidir_ag_queues``, each direction's chain wraps onto its own
    engine subset (chain-local sharing keeps wake times strictly staggered
    and the schedule translation-invariant); the terminal reduce-only
    queues extend their chain's engine rotation.
    """
    n = topo.n_devices
    n_fwd, n_bwd = _bidir_split(n)
    e_fwd = max(1, (topo.n_engines + 1) // 2)
    e_bwd = max(1, topo.n_engines - e_fwd)
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        for name, peer, target, steps, raise_name, fwd in (
                ("prf", pred, succ, n_fwd, "arf", True),
                ("prb", succ, pred, n_bwd, "arb", False)):
            if steps == 0:
                continue

            def engine(k: int) -> int:
                if fwd:
                    return k % e_fwd
                # min(): on a 1-engine device both chains share engine 0.
                return min(e_fwd + (k % e_bwd), topo.n_engines - 1)

            for k in range(steps):
                copies = chunked_copies(CmdKind.COPY, d, (target,), shard,
                                        granularity, (name, d, k),
                                        per_chunk=per_chunk)
                if k == 0:
                    cs = list(copies)
                else:
                    reduces = chunked_reduces((name, peer, k - 1), shard,
                                              granularity, per_chunk=per_chunk)
                    cs = []
                    for r, cc in zip(reduces, copies):
                        cs.append(r)
                        cs.append(cc)
                queues.append(EngineQueue(d, engine(k), tuple(cs)))
            cs = list(chunked_reduces((name, peer, steps - 1), shard,
                                      granularity, per_chunk=per_chunk,
                                      raise_tag=(raise_name, d, 0) if ar else None))
            cs.append(cmd.signal())
            queues.append(EngineQueue(d, engine(steps), tuple(cs)))
    return queues


# ------------------------------------------------ hierarchical (§11) ----

def _require_hier(topo: Topology, variant: str) -> None:
    if topo.n_nodes < 2:
        raise ValueError(
            f"variant {variant!r} needs a multi-node topology "
            f"(n_nodes >= 2), got {topo.name!r} with n_nodes={topo.n_nodes}")
    if topo.node_devices < 2:
        raise ValueError(
            f"variant {variant!r} needs >= 2 devices per node, "
            f"got node_devices={topo.node_devices}")


def _node_ring_neighbors(topo: Topology,
                         device: int | None = None) -> dict[int, tuple[int, int]]:
    """device -> (predecessor, successor) along its *node's* local ring."""
    out: dict[int, tuple[int, int]] = {}
    for node in range(topo.n_nodes):
        order = topo.node_ring_order(node)
        p = len(order)
        for i, d in enumerate(order):
            if device is not None and d != device:
                continue
            out[d] = (order[(i - 1) % p], order[(i + 1) % p])
    return out


def _internode_neighbors(topo: Topology, d: int) -> tuple[int, int]:
    """(predecessor, successor) on ``d``'s rank-group ring — the same local
    rank on the previous/next node (every NIC hop stays inside one rank
    group, so each device's cross-node traffic serializes only on its own
    NIC)."""
    step = topo.node_devices
    return (d - step) % topo.n_devices, (d + step) % topo.n_devices


def _hier_symmetric(topo: Topology) -> bool:
    """True when each node's local ring closes on physical neighbors — the
    per-tier translation-invariance condition of the ``hier_`` builders
    (the rank-group rings are always symmetric: one sender-owned NIC per
    device).  All nodes share one shape, so checking node 0 suffices."""
    order = topo.node_ring_order(0)
    p = len(order)
    if p < 2:
        return False
    return all(topo.is_neighbor(order[i], order[(i + 1) % p]) for i in range(p))


def _build_devices(topo: Topology, device: int | None):
    if device is None:
        return range(topo.n_devices)
    return (device,)


def _hier_ring_ag_queues(topo: Topology, shard: int,
                         device: int | None = None) -> list[EngineQueue]:
    """Two-tier ring all-gather (``hier_ring``, DESIGN.md §11.2).

    Inter tier (engine 0): ring all-gather of ``shard`` across the rank
    group — ``n_nodes - 1`` chained NIC steps.  Intra tier (engine 1):
    once the device's node-block is complete (the rank-group predecessor's
    final inter step landed), ring all-gather of the ``n_nodes * shard``
    block around the node's local ring — ``node_devices - 1`` steps over
    DMA links.  One host signal per device, on the (later-finishing)
    intra queue.
    """
    m = topo.n_nodes
    block = m * shard
    e1 = min(1, topo.n_engines - 1)
    intra = _node_ring_neighbors(topo, device)
    queues = []
    for d in _build_devices(topo, device):
        npred, nsucc = _internode_neighbors(topo, d)
        inter: list[cmd.Command] = []
        for k in range(m - 1):
            if k > 0:
                inter.append(cmd.wait(("hgi", npred, k - 1)))
            inter.append(cmd.copy(d, nsucc, shard))
            inter.append(cmd.signal(("hgi", d, k)))
        queues.append(EngineQueue(d, 0, tuple(inter)))
        ipred, isucc = intra[d]
        cs: list[cmd.Command] = [cmd.wait(("hgi", npred, m - 2))]
        for k in range(topo.node_devices - 1):
            if k > 0:
                cs.append(cmd.wait(("hga", ipred, k - 1)))
            cs.append(cmd.copy(d, isucc, block))
            cs.append(cmd.signal(("hga", d, k)))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, e1, tuple(cs)))
    return queues


def _hier_pipe_ag_queues(topo: Topology, shard: int,
                         device: int | None = None) -> list[EngineQueue]:
    """Tier-pipelined two-tier all-gather (``hier_pipe``, DESIGN.md §11.2).

    Same inter tier as ``hier_ring``, but the intra tier runs one
    *sub-round* per node-block: sub-round ``j`` ring-all-gathers block
    ``j`` (``shard`` bytes per step) around the node and is gated only on
    that block's inter-node arrival (``j = 0``, the local block, starts
    with the doorbell) — the local gather of block ``j`` overlaps the NIC
    transfer of block ``j + 1`` instead of waiting for the whole inter
    phase.  All sub-rounds share ONE intra queue (engine 1): every
    sub-round sends over the same ``d -> isucc`` link, so separate queues
    would buy no wire overlap while their link-bound wake times tie
    exactly — and exact ties leave the grant interleaving to the event
    loop's global submission order, which is not translation invariant.
    Serial engine issue keeps the link FIFO deterministic and the schedule
    symmetric.
    """
    m = topo.n_nodes
    p = topo.node_devices
    e1 = min(1, topo.n_engines - 1)
    intra = _node_ring_neighbors(topo, device)
    queues = []
    for d in _build_devices(topo, device):
        npred, nsucc = _internode_neighbors(topo, d)
        inter: list[cmd.Command] = []
        for k in range(m - 1):
            if k > 0:
                inter.append(cmd.wait(("hgi", npred, k - 1)))
            inter.append(cmd.copy(d, nsucc, shard))
            inter.append(cmd.signal(("hgi", d, k)))
        queues.append(EngineQueue(d, 0, tuple(inter)))
        ipred, isucc = intra[d]
        cs: list[cmd.Command] = []
        for j in range(m):
            if j > 0:
                cs.append(cmd.wait(("hgi", npred, j - 1)))
            for k in range(p - 1):
                if k > 0:
                    cs.append(cmd.wait(("hgp", ipred, j, k - 1)))
                cs.append(cmd.copy(d, isucc, shard))
                cs.append(cmd.signal(("hgp", d, j, k)))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, e1, tuple(cs)))
    return queues


def _hier_ring_rs_queues(topo: Topology, shard: int, *, ar: bool = False,
                         device: int | None = None) -> list[EngineQueue]:
    """Two-tier ring reduce-scatter (``hier_ring_rs``, DESIGN.md §11.2).

    Intra tier (engine 0): ring reduce-scatter of ``n_nodes * shard``
    node-blocks around the local ring — after ``node_devices - 1`` steps
    each device holds its block reduced over the node; the terminal
    reduction raises ``("hrit", d, 0)``.  Inter tier (engine 1): ring
    reduce-scatter of the result ``shard`` across the rank group, gated on
    the intra terminal — ``n_nodes - 1`` NIC steps.  Reduction work per
    device is ``(node_devices - 1) * n_nodes * shard + (n_nodes - 1) *
    shard = (n - 1) * shard`` bytes, exactly the flat rings' conservation
    invariant.  ``ar=True`` makes the inter terminal reduction raise
    ``("arf", d, 0)`` (all-reduce chaining).
    """
    m = topo.n_nodes
    block = m * shard
    e1 = min(1, topo.n_engines - 1)
    intra = _node_ring_neighbors(topo, device)
    queues = []
    for d in _build_devices(topo, device):
        npred, nsucc = _internode_neighbors(topo, d)
        ipred, isucc = intra[d]
        cs: list[cmd.Command] = []
        for k in range(topo.node_devices - 1):
            if k > 0:
                cs.append(cmd.reduce_tag(("hri", ipred, k - 1), block))
            cs.append(cmd.copy(d, isucc, block))
            cs.append(cmd.signal(("hri", d, k)))
        cs.append(cmd.reduce_tag(("hri", ipred, topo.node_devices - 2), block,
                                 ("hrit", d, 0)))
        queues.append(EngineQueue(d, 0, tuple(cs)))
        cs = [cmd.wait(("hrit", d, 0))]
        for k in range(m - 1):
            if k > 0:
                cs.append(cmd.reduce_tag(("hrx", npred, k - 1), shard))
            cs.append(cmd.copy(d, nsucc, shard))
            cs.append(cmd.signal(("hrx", d, k)))
        cs.append(cmd.reduce_tag(("hrx", npred, m - 2), shard,
                                 ("arf", d, 0) if ar else None))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, e1, tuple(cs)))
    return queues


def _hier_pipe_rs_queues(topo: Topology, shard: int,
                         per_chunk: bool = True, *, ar: bool = False,
                         device: int | None = None) -> list[EngineQueue]:
    """Tier-pipelined two-tier reduce-scatter (``hier_pipe_rs``, §11.2).

    The intra tier slices every node-block transfer and reduction at
    ``shard`` granularity with per-chunk tags (``chunked_copies`` /
    ``chunked_reduces``), so the terminal reduction raises one chunk tag
    ``("hrit", d, 0, i)`` per result slice; inter step ``k`` waits on
    slice ``k`` and starts its NIC send the moment that slice is
    node-reduced — the inter tier overlaps the intra tail instead of
    waiting for the whole block.  Slice index ``k`` is each device's
    *local* completion order (per-node slice rotation), which keeps the
    wait tags device-independent — the translation invariance the
    symmetric fast path needs.  ``per_chunk=False`` blocks every intra
    chunk on the predecessor's final chunk (the serialized control arm).
    """
    m = topo.n_nodes
    p = topo.node_devices
    block = m * shard
    e_intra = max(1, topo.n_engines - 1)
    intra = _node_ring_neighbors(topo, device)
    queues = []
    for d in _build_devices(topo, device):
        npred, nsucc = _internode_neighbors(topo, d)
        ipred, isucc = intra[d]
        for k in range(p - 1):
            copies = chunked_copies(CmdKind.COPY, d, (isucc,), block, shard,
                                    ("hri", d, k), per_chunk=per_chunk)
            if k == 0:
                cs = list(copies)
            else:
                reduces = chunked_reduces(("hri", ipred, k - 1), block, shard,
                                          per_chunk=per_chunk)
                cs = []
                for r, cc in zip(reduces, copies):
                    cs.append(r)
                    cs.append(cc)
            queues.append(EngineQueue(d, 1 + (k % e_intra), tuple(cs)))
        term = list(chunked_reduces(("hri", ipred, p - 2), block, shard,
                                    per_chunk=per_chunk,
                                    raise_tag=("hrit", d, 0)))
        queues.append(EngineQueue(d, 1 + ((p - 1) % e_intra), tuple(term)))
        # Inter tier on engine 0: step k consumes node-reduced slice k.
        cs = [cmd.wait(("hrit", d, 0, 0)), cmd.copy(d, nsucc, shard),
              cmd.signal(("hrx", d, 0))]
        for k in range(1, m - 1):
            cs.append(cmd.wait(("hrit", d, 0, k)))
            cs.append(cmd.reduce_tag(("hrx", npred, k - 1), shard))
            cs.append(cmd.copy(d, nsucc, shard))
            cs.append(cmd.signal(("hrx", d, k)))
        cs.append(cmd.wait(("hrit", d, 0, m - 1)))
        cs.append(cmd.reduce_tag(("hrx", npred, m - 2), shard,
                                 ("arf", d, 0) if ar else None))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(cs)))
    return queues


def reduce_scatter_schedule(topo: Topology, size: int, variant: str = "ring_rs", *,
                            opt_config: OptimizationConfig | None = None,
                            max_chunk_bytes: int | None = None,
                            pipe_depth: int = PIPE_DEPTH,
                            per_chunk_signaling: bool = True,
                            device: int | None = None) -> Schedule:
    """Reduce-scatter: every device ends with its ``size / n`` result shard
    reduced over all n contributions (DESIGN.md §10).

    Variants are the ring family (``ring_rs``, ``bidir_ring_rs``), its
    per-chunk-pipelined renderings (``pipe_ring_rs``, ``pipe_bidir_ring_rs``)
    and, on multi-node topologies, the hierarchical two-tier family
    (``hier_ring_rs``, ``hier_pipe_rs``, DESIGN.md §11); the ``opt_`` /
    ``prelaunch_`` prefixes compose as for the other collectives.
    ``pipe_depth`` / ``per_chunk_signaling`` parameterize the ``pipe_``
    variants exactly as in :func:`allgather_schedule`; reductions re-slice
    at the same chunk granularity as the copies feeding them, so reduction
    work is conserved at ``(n-1) * shard_chunks`` chunk reductions per
    device whatever the grain.  ``device`` builds only that device's queues
    (representative-only, §11.3).
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    if base not in RS_VARIANTS and base not in HIER_RS_VARIANTS:
        raise ValueError(f"unknown reduce-scatter variant {requested!r}")
    n = topo.n_devices
    shard = max(1, size // n)
    symmetric = _ring_closes_on_neighbors(topo)
    if base in HIER_RS_VARIANTS:
        _require_hier(topo, requested)
        symmetric = _hier_symmetric(topo)
        if base == "hier_pipe_rs":
            queues = _hier_pipe_rs_queues(topo, shard, per_chunk_signaling,
                                          device=device)
        else:
            queues = _hier_ring_rs_queues(topo, shard, device=device)
    elif base in ("pipe_ring_rs", "pipe_bidir_ring_rs"):
        mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
        g = _pipe_granularity(shard, pipe_depth, mcb)
        builder = _pipe_ring_rs_queues if base == "pipe_ring_rs" else _pipe_bidir_rs_queues
        queues = builder(topo, shard, g, per_chunk_signaling, device=device)
    else:
        builder = _ring_rs_queues if base == "ring_rs" else _bidir_ring_rs_queues
        queues = builder(topo, shard, device=device)
    name = f"rs_opt_{variant}" if optimized else f"rs_{variant}"
    sched = Schedule(name=name, queues=_maybe_prelaunch(queues, prelaunch),
                     symmetric=symmetric)
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, opt_config)


def _ar_result_tags(base: str, n: int, device: int) -> list[tuple]:
    """The tags a device's all-reduce result shard completion raises: one
    per terminal reduction (both directions on the bidir variants)."""
    n_bwd = _bidir_split(n)[1]
    tags = [("arf", device, 0)]
    if "bidir" in base and n_bwd:
        tags.append(("arb", device, 0))
    return tags


def _ar_gate_ag_sources(queues: list[EngineQueue], base: str, n: int,
                        chunks: int | None,
                        per_chunk: bool = True) -> list[EngineQueue]:
    """Gate each device's all-gather *source* queue (the one whose first
    command is a data command — every other queue chains off it through
    the ring tags) on the device's reduce-scatter result tags.

    ``chunks=None`` (the non-pipelined variants) prepends one
    transfer-granularity wait per result tag — the terminal reduction's
    fused raise rides its final chunk.  On the pipelined variants the
    result tags are chunk-indexed: with ``per_chunk=True`` the gather
    waits on result chunk ``i`` directly before its ``i``-th data chunk,
    so it starts on the first *reduced* chunk (DESIGN.md §10); with
    ``per_chunk=False`` one wait on the final result chunk gates the whole
    queue (the control arm).
    """
    out = []
    for q in queues:
        if not q.commands or q.commands[0].kind not in DATA_KINDS:
            out.append(q)
            continue
        tags = _ar_result_tags(base, n, q.device)
        cs: list[cmd.Command] = []
        if chunks is None:
            cs.extend(cmd.wait(t) for t in tags)
            cs.extend(q.commands)
        elif not per_chunk:
            cs.extend(cmd.wait(chunk_tag(t, chunks - 1)) for t in tags)
            cs.extend(q.commands)
        else:
            i = 0
            for c in q.commands:
                if c.kind in DATA_KINDS:
                    cs.extend(cmd.wait(chunk_tag(t, i)) for t in tags)
                    i += 1
                cs.append(c)
        out.append(dataclasses.replace(q, commands=tuple(cs)))
    return out


#: All-gather phase paired with each reduce-scatter variant by
#: :func:`allreduce_schedule` (same ring embedding, same chunk grain).
_AR_AG_BUILDERS = {
    "ring_rs": _ring_ag_queues,
    "bidir_ring_rs": _bidir_ring_ag_queues,
    "pipe_ring_rs": _pipe_ring_ag_queues,
    "pipe_bidir_ring_rs": _pipe_bidir_ag_queues,
}

#: The standalone all-gather *variant* each reduce-scatter variant pairs
#: with — what the RS-then-AG sequential baseline of the §10 decomposition
#: claims simulates (claims.py, tests/test_property.py).
AR_AG_VARIANT = {
    "ring_rs": "ring",
    "bidir_ring_rs": "bidir_ring",
    "pipe_ring_rs": "pipe_b2b",
    "pipe_bidir_ring_rs": "pipe_bidir_ring",
    "hier_ring_rs": "hier_ring",
    "hier_pipe_rs": "hier_pipe",
}


def allreduce_schedule(topo: Topology, size: int, variant: str = "ring_rs", *,
                       opt_config: OptimizationConfig | None = None,
                       max_chunk_bytes: int | None = None,
                       pipe_depth: int = PIPE_DEPTH,
                       per_chunk_signaling: bool = True,
                       device: int | None = None) -> Schedule:
    """All-reduce as reduce-scatter + pipelined all-gather (DESIGN.md §10).

    ``variant`` names the reduce-scatter flavor (:data:`RS_VARIANTS` plus
    the usual prefixes); the matching all-gather rendering
    (:data:`_AR_AG_BUILDERS`) gathers the reduced shards over the same ring
    embedding at the same chunk granularity.  The two phases are chained
    through the terminal reductions' result tags: on the ``pipe_`` variants
    the gather's source queue waits *per chunk*, so the all-gather fill
    overlaps the reduce-scatter tail instead of starting after it.

    The gather phase's queues are always *armed ahead of time* (prelaunch,
    §4.5): they cannot make progress before the reduce phase's result tags
    anyway, so a real runtime enqueues their packets while the reduce
    phase streams — leaving them live would serialize the gather phase's
    full control cost on the host *before* the reduce phase's first
    doorbell, delaying the wire start by more than the overlap gains on
    host-heavy platforms.  A ``prelaunch_`` prefix additionally arms the
    reduce phase.  This is why the composed schedule is never slower than
    running the two collectives back to back (asserted in
    ``tests/test_property.py``).
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    if base not in RS_VARIANTS and base not in HIER_RS_VARIANTS:
        raise ValueError(f"unknown all-reduce variant {requested!r}")
    n = topo.n_devices
    shard = max(1, size // n)
    symmetric = _ring_closes_on_neighbors(topo)
    if base in HIER_RS_VARIANTS:
        _require_hier(topo, requested)
        symmetric = _hier_symmetric(topo)
        if base == "hier_pipe_rs":
            rs_queues = _hier_pipe_rs_queues(topo, shard, per_chunk_signaling,
                                             ar=True, device=device)
            ag_queues = _hier_pipe_ag_queues(topo, shard, device=device)
        else:
            rs_queues = _hier_ring_rs_queues(topo, shard, ar=True,
                                             device=device)
            ag_queues = _hier_ring_ag_queues(topo, shard, device=device)
        # The hier terminal reduction raises one transfer-granular result
        # tag per device, so the gather gates exactly like the non-pipe
        # flat variants.
        ag_queues = _ar_gate_ag_sources(ag_queues, base, n, None)
    elif base in ("pipe_ring_rs", "pipe_bidir_ring_rs"):
        ag_builder = _AR_AG_BUILDERS[base]
        mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
        g = _pipe_granularity(shard, pipe_depth, mcb)
        rs_builder = _pipe_ring_rs_queues if base == "pipe_ring_rs" else _pipe_bidir_rs_queues
        rs_queues = rs_builder(topo, shard, g, per_chunk_signaling, ar=True,
                               device=device)
        ag_queues = _ar_gate_ag_sources(
            ag_builder(topo, shard, g, per_chunk_signaling, device), base, n,
            len(chunk_sizes(shard, g)), per_chunk_signaling)
    else:
        ag_builder = _AR_AG_BUILDERS[base]
        rs_builder = _ring_rs_queues if base == "ring_rs" else _bidir_ring_rs_queues
        rs_queues = rs_builder(topo, shard, ar=True, device=device)
        ag_queues = _ar_gate_ag_sources(ag_builder(topo, shard, device), base,
                                        n, None)
    name = f"ar_opt_{variant}" if optimized else f"ar_{variant}"
    queues = _maybe_prelaunch(rs_queues, prelaunch) \
        + _maybe_prelaunch(ag_queues, True)
    sched = Schedule(name=name, queues=queues, symmetric=symmetric)
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, opt_config)


def allgather_schedule(topo: Topology, size: int, variant: str = "pcpy", *,
                       opt_config: OptimizationConfig | None = None,
                       max_chunk_bytes: int | None = None,
                       pipe_depth: int = PIPE_DEPTH,
                       per_chunk_signaling: bool = True,
                       device: int | None = None) -> Schedule:
    """All-gather: every device sends its shard (size/n) to all n-1 peers.

    An ``opt_`` variant prefix applies the optimized command-stream
    transforms (DESIGN.md §7) to the built schedule; ``opt_config``
    customizes them.  Copies above ``max_chunk_bytes`` (default: the
    topology's calibrated sDMA packet ceiling, DESIGN.md §8.1) are split
    into pipelined chunk commands; pass ``0`` to disable chunking.

    The ``pipe_`` variants (DESIGN.md §9) additionally take ``pipe_depth``
    (minimum chunks per shard; an explicit ``max_chunk_bytes`` narrows the
    chunk granularity further, which is how the dispatch chunk sweep drives
    the pipeline depth) and ``per_chunk_signaling`` (``False`` builds the
    final-chunk-only control arm of the §9 claims).
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    if base not in AG_VARIANTS and base not in HIER_AG_VARIANTS:
        raise ValueError(f"unknown all-gather variant {requested!r}")
    n = topo.n_devices
    shard = max(1, size // n)
    queues: list[EngineQueue] = []
    symmetric = True
    if base in HIER_AG_VARIANTS:
        _require_hier(topo, requested)
        builder = _hier_ring_ag_queues if base == "hier_ring" else _hier_pipe_ag_queues
        queues = builder(topo, shard, device=device)
        symmetric = _hier_symmetric(topo)
    elif base in ("pipe_b2b", "pipe_bidir_ring"):
        mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
        g = _pipe_granularity(shard, pipe_depth, mcb)
        builder = _pipe_ring_ag_queues if base == "pipe_b2b" else _pipe_bidir_ag_queues
        queues = builder(topo, shard, g, per_chunk_signaling, device)
        symmetric = _ring_closes_on_neighbors(topo)
    elif base == "pcpy":
        for d in _build_devices(topo, device):
            for e, p in enumerate(x for x in range(n) if x != d):
                queues.append(EngineQueue(d, e, (cmd.copy(d, p, shard), cmd.signal())))
        symmetric = topo.fully_connected
    elif base == "bcst":
        for d in _build_devices(topo, device):
            peers = [p for p in range(n) if p != d]
            e = 0
            it = iter(peers)
            for a in it:
                b = next(it, None)
                if b is None:
                    queues.append(EngineQueue(d, e, (cmd.copy(d, a, shard), cmd.signal())))
                else:
                    queues.append(EngineQueue(d, e, (cmd.bcst(d, a, b, shard), cmd.signal())))
                e += 1
        symmetric = topo.fully_connected
    elif base == "b2b":
        for d in _build_devices(topo, device):
            copies = tuple(cmd.copy(d, p, shard) for p in range(n) if p != d)
            queues.append(EngineQueue(d, 0, copies + (cmd.signal(),)))
        symmetric = topo.fully_connected
    elif base == "ring":
        queues = _ring_ag_queues(topo, shard, device)
        symmetric = _ring_closes_on_neighbors(topo)
    else:  # bidir_ring
        queues = _bidir_ring_ag_queues(topo, shard, device)
        symmetric = _ring_closes_on_neighbors(topo)
    name = f"ag_opt_{variant}" if optimized else f"ag_{variant}"
    sched = Schedule(name=name, queues=_maybe_prelaunch(queues, prelaunch),
                     symmetric=symmetric)
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, opt_config)


def alltoall_schedule(topo: Topology, size: int, variant: str = "pcpy", *,
                      opt_config: OptimizationConfig | None = None,
                      max_chunk_bytes: int | None = None,
                      pipe_depth: int = PIPE_DEPTH,
                      per_chunk_signaling: bool = True,
                      device: int | None = None) -> Schedule:
    """All-to-all: every device exchanges a size/n shard with every peer.

    With ``swap``, pair (i, j) is served by a single in-place swap command
    executed by one of the two devices (balanced round-robin assignment), so
    system-wide command count halves.  An ``opt_`` variant prefix applies the
    optimized command-stream transforms (DESIGN.md §7); ``max_chunk_bytes``
    bounds the per-command payload as in :func:`allgather_schedule`;
    ``pipe_depth``/``per_chunk_signaling`` parameterize the ``pipe_b2b``
    pipelined rotation ring (DESIGN.md §9).
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    if base not in AA_VARIANTS:
        raise ValueError(f"unknown all-to-all variant {requested!r}")
    n = topo.n_devices
    shard = max(1, size // n)
    queues: list[EngineQueue] = []
    symmetric = True
    if base == "pipe_b2b":
        mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
        queues = _pipe_aa_queues(topo, shard, pipe_depth, mcb,
                                 per_chunk_signaling, device)
        symmetric = _ring_closes_on_neighbors(topo)
    elif base == "swap":
        # Executor assignment alternates per pair -> devices run different
        # command counts, so this schedule is never symmetric.
        symmetric = False
        per_dev_engine = {d: 0 for d in range(n)}
        for i in range(n):
            for j in range(i + 1, n):
                executor = i if (i + j) % 2 == 1 else j
                partner = j if executor == i else i
                e = per_dev_engine[executor]
                per_dev_engine[executor] += 1
                if device is not None and executor != device:
                    continue
                queues.append(EngineQueue(executor, e, (cmd.swap(executor, partner, shard), cmd.signal())))
    elif base == "ring":
        queues = _ring_aa_queues(topo, shard, device)
        symmetric = _ring_closes_on_neighbors(topo)
    else:
        symmetric = topo.fully_connected
        for d in _build_devices(topo, device):
            peers = [p for p in range(n) if p != d]
            if base == "pcpy":
                for e, p in enumerate(peers):
                    queues.append(EngineQueue(d, e, (cmd.copy(d, p, shard), cmd.signal())))
            else:  # b2b
                copies = tuple(cmd.copy(d, p, shard) for p in peers)
                queues.append(EngineQueue(d, 0, copies + (cmd.signal(),)))
    name = f"aa_opt_{variant}" if optimized else f"aa_{variant}"
    sched = Schedule(name=name, queues=_maybe_prelaunch(queues, prelaunch),
                     symmetric=symmetric)
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, opt_config)


def kv_fetch_schedule(
    topo: Topology,
    n_blocks: int,
    block_bytes: int,
    variant: str = "pcpy",
    *,
    device: int = 0,
    b2b_fanout_threshold: int = 4 * 1024 * 1024,
    max_chunk_bytes: int | None = None,
) -> Schedule:
    """Host->device fetch of ``n_blocks`` dispersed KV-cache blocks (§5.3).

    * ``pcpy``: baseline vLLM — one ``hipMemcpyAsync`` per block, spread
      round-robin over the device's DMA engines, one signal per copy.
    * ``b2b``: our optimized path — all copies back-to-back on ONE engine
      with a single trailing signal; above the empirical 4MB threshold the
      runtime fans out to multiple engines (one signal each) for parallelism
      (paper §5.3.1).

    An ``opt_`` prefix additionally applies the optimized command-stream
    transforms (DESIGN.md §7) to the built schedule.
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    total = n_blocks * block_bytes
    queues: list[EngineQueue] = []
    if base == "pcpy":
        per_engine: dict[int, list] = {}
        for b in range(n_blocks):
            e = b % topo.n_engines
            per_engine.setdefault(e, []).extend([cmd.copy("host", device, block_bytes), cmd.signal()])
        for e, cs in per_engine.items():
            queues.append(EngineQueue(device, e, tuple(cs)))
    elif base == "b2b":
        fanout = 1 if total < b2b_fanout_threshold else min(topo.n_engines, 4)
        for e in range(fanout):
            blocks = range(e, n_blocks, fanout)
            copies = tuple(cmd.copy("host", device, block_bytes) for _ in blocks)
            if copies:
                queues.append(EngineQueue(device, e, copies + (cmd.signal(),)))
    else:
        raise ValueError(f"unknown kv-fetch variant {requested!r}")
    name = f"kvfetch_opt_{variant}" if optimized else f"kvfetch_{variant}"
    sched = Schedule(name=name, queues=_maybe_prelaunch(queues, prelaunch))
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, None)


# ---------------------------------------------------------------------------
# Fused compute-collective overlap (DESIGN.md §15)
# ---------------------------------------------------------------------------

def _parse_fused_rs(base: str) -> tuple[bool, bool, int]:
    """``FUSED_RS_VARIANTS`` base -> (fused, on_cu, pipe_depth)."""
    if base == "seq":
        return False, False, PIPE_DEPTH
    _, placement, depth = base.split("_")
    return True, placement == "cu", int(depth[1:])


def _parse_fused_ag(base: str) -> tuple[bool, int]:
    """``FUSED_AG_VARIANTS`` base -> (fused, pipe_depth)."""
    if base == "seq":
        return False, PIPE_DEPTH
    _, depth = base.split("_")
    return True, int(depth[1:])


def _fused_gemm_rs_queues(topo: Topology, shard: int, granularity: int, *,
                          fused: bool, on_cu: bool, flops_per_byte: int,
                          device: int | None = None) -> list[EngineQueue]:
    """GEMM + pipelined ring reduce-scatter with tile-grain gating (§15).

    A per-device CU proxy queue (engine index ``topo.n_engines``, past the
    SDMA engines — its only engine-timeline use is the initial descriptor
    fetch) streams one ``compute`` tile per collective chunk, in the order
    the reduce-scatter consumes the local partials: step-0's send shard
    first, then each reduce step's accumulation shard, the result shard
    last.  Tile ``j*c + i`` raises ``("ftl", d, j*c + i)`` on completion.

    The collective itself is ``_pipe_ring_rs_queues`` re-rendered with tile
    gating: in the fused arms, step-0 chunk ``i`` waits on tile ``i`` and
    every chunk reduction at step ``j`` waits on tile ``j*c + i`` before
    consuming its arrival, so sends start the moment their partial exists;
    the ``seq`` arm keeps the identical wait stream but coarsens every
    gate to the *final* tile, serializing the whole GEMM before the
    collective (the status-quo kernel boundary) at the same host control
    cost.  ``on_cu`` selects the §15 reduction placement.
    """
    n = topo.n_devices
    sizes = chunk_sizes(shard, granularity)
    c = len(sizes)
    total = n * c
    e_cu = topo.n_engines
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        tiles = tuple(
            cmd.compute(max(1, flops_per_byte * sz),
                        raise_tag=("ftl", d, j * c + i))
            for j in range(n) for i, sz in enumerate(sizes))
        queues.append(EngineQueue(d, e_cu, tiles))
        for k in range(n - 1):
            copies = chunked_copies(CmdKind.COPY, d, (succ,), shard,
                                    granularity, ("frs", d, k),
                                    per_chunk=True)
            def tile(j: int, *, d=d) -> cmd.Command:
                # seq is the control arm: the SAME wait stream, every
                # gate coarsened to the final tile — identical host
                # control cost, only the gating grain differs (the
                # per_chunk=False idiom of the §9/§10 claims).
                return cmd.wait(("ftl", d, j if fused else total - 1))

            cs: list[cmd.Command] = []
            if k == 0:
                for i, cc in enumerate(copies):
                    cs.append(tile(i))
                    cs.append(cc)
            else:
                reduces = chunked_reduces(("frs", pred, k - 1), shard,
                                          granularity, on_cu=on_cu)
                for i, (r, cc) in enumerate(zip(reduces, copies)):
                    cs.append(tile(k * c + i))
                    cs.append(r)
                    cs.append(cc)
            queues.append(EngineQueue(d, k % topo.n_engines, tuple(cs)))
        term: list[cmd.Command] = []
        for i, r in enumerate(chunked_reduces(("frs", pred, n - 2), shard,
                                              granularity, on_cu=on_cu)):
            term.append(cmd.wait(("ftl", d,
                                  (n - 1) * c + i if fused else total - 1)))
            term.append(r)
        term.append(cmd.signal())
        queues.append(EngineQueue(d, (n - 1) % topo.n_engines, tuple(term)))
    return queues


def _fused_ag_gemm_queues(topo: Topology, shard: int, granularity: int, *,
                          fused: bool, flops_per_byte: int,
                          device: int | None = None) -> list[EngineQueue]:
    """Pipelined ring all-gather + GEMM with shard-grain launch (§15).

    The ring is ``_pipe_ring_ag_queues`` with one difference: EVERY step
    carries per-chunk tags (``("fga", d, k)``) — the last step's payload
    is consumed too, by the GEMM.  The CU proxy queue streams one tile per
    gathered chunk: the local shard's tiles launch unconditionally, and
    the tile for chunk ``i`` of arrival step ``k`` blocks (via the compute
    command's own wait tag) on ``chunk_tag(("fga", pred, k), i)`` — the
    ``seq`` arm coarsens every tile's gate to the final arrival chunk,
    so the whole GEMM trails the finished all-gather.  GEMM completion is
    the collective's completion (the CU queue's last tile end dominates
    ``copy_end``); the ring's own host signal mirrors the plain builder.
    """
    n = topo.n_devices
    sizes = chunk_sizes(shard, granularity)
    c = len(sizes)
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo, device).items():
        for k in range(n - 1):
            copies = chunked_copies(CmdKind.COPY, d, (succ,), shard,
                                    granularity, ("fga", d, k),
                                    per_chunk=True)
            cs: list[cmd.Command] = []
            for i, cc in enumerate(copies):
                if k > 0:
                    cs.append(cmd.wait(chunk_tag(("fga", pred, k - 1), i)))
                cs.append(cc)
            if k == n - 2:
                cs.append(cmd.signal())
            queues.append(EngineQueue(d, k % topo.n_engines, tuple(cs)))
        tiles: list[cmd.Command] = []
        # seq is the control arm: same tile stream, every arrival gate
        # coarsened to the final arrival chunk (the local-shard tiles
        # included) — only the gating grain differs from the fused arms.
        final = chunk_tag(("fga", pred, n - 2), c - 1)
        for i, sz in enumerate(sizes):
            gate = None if fused else final
            tiles.append(cmd.compute(max(1, flops_per_byte * sz), tag=gate))
        for k in range(n - 1):
            for i, sz in enumerate(sizes):
                gate = chunk_tag(("fga", pred, k), i) if fused else final
                tiles.append(cmd.compute(max(1, flops_per_byte * sz),
                                         tag=gate))
        queues.append(EngineQueue(d, topo.n_engines, tuple(tiles)))
    return queues


def fused_gemm_rs_schedule(topo: Topology, size: int,
                           variant: str = "fused_engine_d4", *,
                           opt_config: OptimizationConfig | None = None,
                           max_chunk_bytes: int | None = None,
                           flops_per_byte: int = GEMM_FLOPS_PER_BYTE,
                           device: int | None = None) -> Schedule:
    """Fused GEMM + reduce-scatter (DESIGN.md §15): each device computes a
    ``size``-byte local partial (``flops_per_byte * size`` FLOPs, tiled at
    the collective's chunk grain) and reduce-scatters it over the ring —
    tile ``i``'s partial feeds the chunk pipeline the moment it completes.

    Variants are ``FUSED_RS_VARIANTS``: ``seq`` (GEMM-then-collective
    kernel boundary) and ``fused_{cu,engine}_d{2,4,8}`` — overlap at that
    pipeline depth with the per-chunk reductions placed on the CU or the
    engine timeline.  The ``opt_`` / ``prelaunch_`` prefixes compose as
    for the plain collectives; ``device`` builds one device's queues
    (representative-only, §11.3).
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    if base not in FUSED_RS_VARIANTS:
        raise ValueError(
            f"unknown fused GEMM+reduce-scatter variant {requested!r}")
    fused, on_cu, depth = _parse_fused_rs(base)
    n = topo.n_devices
    shard = max(1, size // n)
    mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
    g = _pipe_granularity(shard, depth, mcb)
    queues = _fused_gemm_rs_queues(topo, shard, g, fused=fused, on_cu=on_cu,
                                   flops_per_byte=flops_per_byte,
                                   device=device)
    name = f"gemmrs_opt_{variant}" if optimized else f"gemmrs_{variant}"
    sched = Schedule(name=name, queues=_maybe_prelaunch(queues, prelaunch),
                     symmetric=_ring_closes_on_neighbors(topo))
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, opt_config)


def fused_ag_gemm_schedule(topo: Topology, size: int,
                           variant: str = "fused_d4", *,
                           opt_config: OptimizationConfig | None = None,
                           max_chunk_bytes: int | None = None,
                           flops_per_byte: int = GEMM_FLOPS_PER_BYTE,
                           device: int | None = None) -> Schedule:
    """Fused all-gather + GEMM (DESIGN.md §15): the ring gathers a
    ``size``-byte operand and each device's GEMM consumes it at
    ``flops_per_byte`` FLOPs per gathered byte — the tile over shard ``k``
    launches the moment that input shard lands, instead of after the
    whole gather (``seq``).

    Variants are ``FUSED_AG_VARIANTS`` (``seq``, ``fused_d{2,4,8}``); the
    ``opt_`` / ``prelaunch_`` prefixes and ``device`` compose as in
    :func:`fused_gemm_rs_schedule`.
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    if base not in FUSED_AG_VARIANTS:
        raise ValueError(
            f"unknown fused all-gather+GEMM variant {requested!r}")
    fused, depth = _parse_fused_ag(base)
    n = topo.n_devices
    shard = max(1, size // n)
    mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
    g = _pipe_granularity(shard, depth, mcb)
    queues = _fused_ag_gemm_queues(topo, shard, g, fused=fused,
                                   flops_per_byte=flops_per_byte,
                                   device=device)
    name = f"aggemm_opt_{variant}" if optimized else f"aggemm_{variant}"
    sched = Schedule(name=name, queues=_maybe_prelaunch(queues, prelaunch),
                     symmetric=_ring_closes_on_neighbors(topo))
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, opt_config)
