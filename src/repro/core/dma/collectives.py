"""Collective schedule builders — the paper's DMA collective designs (§4).

Each builder turns (topology, collective size, variant) into an explicit
:class:`Schedule` of engine queues, exactly as described in the paper:

* ``pcpy``  — baseline: one engine per peer, one copy+signal each (Fig. 8).
* ``bcst``  — all-gather only: broadcast commands pair up peers, halving
  commands/engines/signals (Fig. 9).
* ``swap``  — all-to-all only: in-place pairwise exchange; each pair's
  transfer is ONE command executed by one of the two devices (Fig. 10).
* ``b2b``   — all copies back-to-back on a single engine, one signal (Fig. 11).
* ``prelaunch_<v>`` — any of the above with queues armed ahead of time behind
  a ``poll`` command (Fig. 12).

Topology awareness (DESIGN.md §3): on a non-fully-connected topology the
direct variants above still build the same queue shapes — the simulator
routes each transfer over the torus (multi-hop, contended links).  Two
additional *neighbor-only* variants render the JAX ``ring``/``bidir_ring``
collectives of :mod:`repro.core.collectives` as explicit schedules with real
cross-device dependencies (``wait`` on the predecessor's tagged signal):

* ``ring``       — unidirectional ring over :meth:`Topology.ring_order`,
  chained on ONE engine; all-gather forwards the received shard each step,
  all-to-all uses the rotation algorithm (step ``r`` forwards the ``n-1-r``
  chunks still in transit).
* ``bidir_ring`` — all-gather only: both directions per step (the step-0
  send is a single-read ``bcst`` feeding both neighbors), halving steps.

Optimized command streams (DESIGN.md §7): any variant may be prefixed with
``opt_`` (``opt_pcpy``, ``opt_prelaunch_b2b``, ``opt_ring``, ...) to run the
same schedule through :func:`repro.core.dma.optimizations.optimize` — batched
submission, SDMA queue-slot parallelism and fused write+signal.  The ring /
bidir-ring / rotation-AA builders benefit chiefly from fused signaling (each
chained step drops its standalone semaphore command) and batching; the
one-shot builders additionally pick up multi-queue dispatch.

Pipelined ring collectives (DESIGN.md §9): the ``pipe_b2b`` /
``pipe_bidir_ring`` variants re-render the chained rings with *per-chunk
semaphore signaling* — every shard is split into ``pipe_depth`` chunk
commands (bounded by the sDMA packet ceiling), each chunk raises its own
fused chunk-indexed tag, each ring step runs on its own engine queue, and
step *k+1* waits per-chunk: it starts forwarding chunk *i* the moment chunk
*i* of step *k* landed, instead of waiting for the whole shard.  Successive
ring steps overlap on distinct engines while the per-link wire floor is
kept saturated; ``per_chunk_signaling=False`` builds the same queue shape
with final-chunk-only waits (the control arm of the §9 claims).

Reduce collectives (DESIGN.md §10): :func:`reduce_scatter_schedule` renders
the ring family with a consumer-side reduction per arrived shard —
``ring_rs`` / ``bidir_ring_rs`` reduce at transfer granularity, the
``pipe_ring_rs`` / ``pipe_bidir_ring_rs`` variants reduce each chunk the
moment it lands and forward the reduced partial while later chunks are
still in flight (the compute/communication overlap model of
arXiv:2512.10236).  :func:`allreduce_schedule` composes a reduce-scatter
with the matching (pipelined) all-gather: each device's terminal reductions
raise result tags that gate the all-gather's source queue chunk by chunk,
so the gather phase starts on the first *reduced* chunk instead of the
whole reduced shard.

Size convention: ``size`` is the collective's *total message size* as in the
paper's figures (1KB–4GB).  Each device's per-peer shard is ``size / n``.
"""
from __future__ import annotations

import dataclasses

from . import commands as cmd
from .commands import (CmdKind, DATA_KINDS, EngineQueue, Schedule,
                       chunk_schedule, chunk_sizes, chunk_tag, chunked_copies,
                       chunked_reduces)
from .optimizations import OptimizationConfig, optimize, parse_optimized
from .topology import Topology

AG_VARIANTS = ("pcpy", "bcst", "b2b", "ring", "bidir_ring",
               "pipe_b2b", "pipe_bidir_ring")
AA_VARIANTS = ("pcpy", "swap", "b2b", "ring", "pipe_b2b")
RS_VARIANTS = ("ring_rs", "bidir_ring_rs", "pipe_ring_rs", "pipe_bidir_ring_rs")

#: Default pipeline depth of the ``pipe_`` variants (DESIGN.md §9): the
#: minimum number of chunk commands a shard is split into.  Deeper splits
#: keep shrinking the per-step fill latency but pay per-chunk packet/issue
#: costs; depth 4 is where the chunk-count sweep stops improving on the
#: modeled platforms (the "sweep ceiling" of the §9 claims).
PIPE_DEPTH = 4


def _maybe_chunk(sched: Schedule, topo: Topology,
                 max_chunk_bytes: int | None) -> Schedule:
    """Split oversized copies into sDMA chunk commands (DESIGN.md §8.1).

    ``None`` uses the topology's calibrated ``Calibration.max_chunk_bytes``
    (the hardware packet ceiling); ``0`` disables chunking (used by tests
    comparing chunked and monolithic timing).  Runs before the optimization
    transforms so batching/slots/fusion operate on the chunked stream.
    """
    mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
    return chunk_schedule(sched, mcb)


def _maybe_prelaunch(queues: list[EngineQueue], prelaunch: bool) -> tuple[EngineQueue, ...]:
    if not prelaunch:
        return tuple(queues)
    out = []
    for q in queues:
        out.append(
            EngineQueue(
                device=q.device,
                engine=q.engine,
                commands=(cmd.poll(),) + q.commands,
                prelaunched=True,
            )
        )
    return tuple(out)


def parse_variant(variant: str) -> tuple[str, bool]:
    if variant.startswith("prelaunch_"):
        return variant[len("prelaunch_"):], True
    return variant, False


def _maybe_optimize(sched: Schedule, optimized: bool,
                    config: OptimizationConfig | None) -> Schedule:
    return optimize(sched, config) if optimized else sched


def _bidir_split(n: int) -> tuple[int, int]:
    """(forward, backward) step split of the ``n - 1`` ring deliveries
    shared by EVERY bidirectional builder (all-gather and reduce-scatter)
    and by the all-reduce result-tag gating — these must stay in lockstep,
    or the gather phase waits on a terminal-reduction tag the reduce phase
    never raises (``ceil``/``floor`` of ``(n-1)/2``)."""
    n_fwd = (n - 1 + 1) // 2
    return n_fwd, (n - 1) - n_fwd


def _ring_neighbors(topo: Topology) -> dict[int, tuple[int, int]]:
    """device -> (predecessor, successor) along the topology's ring embedding."""
    order = topo.ring_order()
    n = len(order)
    return {order[i]: (order[(i - 1) % n], order[(i + 1) % n]) for i in range(n)}


def _ring_closes_on_neighbors(topo: Topology) -> bool:
    """True when every consecutive ring_order pair (incl. the wraparound) is a
    single physical link.  On odd-by-odd torus grids the snake ring's
    wraparound is multi-hop, which makes the devices asymmetric — such rings
    must run the full simulation, not the symmetric fast path."""
    order = topo.ring_order()
    n = len(order)
    return all(topo.is_neighbor(order[i], order[(i + 1) % n]) for i in range(n))


def _ring_ag_queues(topo: Topology, shard: int) -> list[EngineQueue]:
    """Unidirectional ring all-gather: n-1 chained forward steps per device."""
    n = topo.n_devices
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo).items():
        cs: list[cmd.Command] = []
        for k in range(n - 1):
            if k > 0:
                cs.append(cmd.wait(("ag", pred, k - 1)))
            cs.append(cmd.copy(d, succ, shard))
            cs.append(cmd.signal(("ag", d, k)))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(cs)))
    return queues


def _bidir_ring_ag_queues(topo: Topology, shard: int) -> list[EngineQueue]:
    """Bidirectional ring all-gather: ceil((n-1)/2) forward + floor((n-1)/2)
    backward deliveries; the step-0 send reads the local shard ONCE for both
    directions (a bcst command), covering forward AND backward distance 1,
    so the backward chain adds ``n_bwd - 1`` further steps (distances
    ``2..n_bwd``) — every device receives exactly ``n - 1`` distinct shards
    (the ``n_bwd``-distance shard arrives from the forward side only)."""
    n = topo.n_devices
    n_fwd, n_bwd = _bidir_split(n)
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo).items():
        fwd: list[cmd.Command] = []
        if n == 2:
            fwd.append(cmd.copy(d, succ, shard))
        else:
            fwd.append(cmd.bcst(d, succ, pred, shard))
        fwd.append(cmd.signal(("agf", d, 0)))
        if n_bwd > 1 and n > 2:
            fwd.append(cmd.signal(("agb", d, 0)))
        for k in range(1, n_fwd):
            fwd.append(cmd.wait(("agf", pred, k - 1)))
            fwd.append(cmd.copy(d, succ, shard))
            fwd.append(cmd.signal(("agf", d, k)))
        fwd.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(fwd)))

        if n_bwd > 1 and n > 2:
            bwd: list[cmd.Command] = []
            for k in range(1, n_bwd):
                bwd.append(cmd.wait(("agb", succ, k - 1)))
                bwd.append(cmd.copy(d, pred, shard))
                bwd.append(cmd.signal(("agb", d, k)))
            bwd.append(cmd.signal())
            queues.append(EngineQueue(d, 1, tuple(bwd)))
    return queues


def _ring_aa_queues(topo: Topology, shard: int) -> list[EngineQueue]:
    """Rotation ring all-to-all: every chunk moves one hop per round until it
    reaches its destination, so round r forwards n-1-r chunks."""
    n = topo.n_devices
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo).items():
        cs: list[cmd.Command] = []
        for r in range(n - 1):
            if r > 0:
                cs.append(cmd.wait(("aar", pred, r - 1)))
            cs.append(cmd.copy(d, succ, (n - 1 - r) * shard))
            cs.append(cmd.signal(("aar", d, r)))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(cs)))
    return queues


def _pipe_granularity(payload: int, depth: int, mcb: int) -> int:
    """Chunk granularity of a pipelined transfer (DESIGN.md §9): split
    ``payload`` into at least ``depth`` chunks, never exceeding the sDMA
    packet ceiling ``mcb`` (``mcb <= 0`` = ceiling disabled)."""
    g = max(1, -(-payload // depth))
    return min(g, mcb) if mcb > 0 else g


def _pipe_ring_ag_queues(topo: Topology, shard: int, granularity: int,
                         per_chunk: bool) -> list[EngineQueue]:
    """Pipelined unidirectional ring all-gather (``pipe_b2b``, DESIGN.md §9).

    One engine queue per ring step: step ``k`` forwards the shard received
    in step ``k-1`` as chunk commands, each raising a fused chunk-indexed
    tag, and waits on its predecessor *per chunk* — chunk ``i`` of step
    ``k`` issues as soon as chunk ``i`` of step ``k-1`` landed, so
    successive ring steps overlap on distinct engines while every link
    stays back-to-back at the ring's wire floor.  With
    ``per_chunk=False`` each step waits only on the predecessor's final
    chunk (the serialized control arm).  Only the final step notifies the
    host: its completion transitively implies every earlier chained step.
    """
    n = topo.n_devices
    c = len(chunk_sizes(shard, granularity))
    last = c - 1
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo).items():
        for k in range(n - 1):
            tag = ("pag", d, k) if k < n - 2 else None
            copies = chunked_copies(CmdKind.COPY, d, (succ,), shard,
                                    granularity, tag, per_chunk=per_chunk)
            cs: list[cmd.Command] = []
            for i, cc in enumerate(copies):
                if k > 0 and (per_chunk or i == 0):
                    w = i if per_chunk else last
                    cs.append(cmd.wait(chunk_tag(("pag", pred, k - 1), w)))
                cs.append(cc)
            if k == n - 2:
                cs.append(cmd.signal())
            queues.append(EngineQueue(d, k % topo.n_engines, tuple(cs)))
    return queues


def _pipe_bidir_ag_queues(topo: Topology, shard: int, granularity: int,
                          per_chunk: bool) -> list[EngineQueue]:
    """Pipelined bidirectional ring all-gather (``pipe_bidir_ring``, §9).

    The step-0 ``bcst`` feeds both directions reading the local shard once;
    its per-chunk tags unblock the forward AND backward step-1 queues chunk
    by chunk — in the chained bidir ring the backward engine idles until the
    *whole* bcst finished, which is the largest stall per-chunk signaling
    removes (a full shard's wire time at bandwidth-bound sizes).

    When steps outnumber engines, each direction's chain wraps onto its own
    engine subset (forward on the lower half, backward on the upper half).
    Sharing an engine *within* a chain keeps wake times strictly staggered
    (step ``k+E`` only unblocks after step ``k+E-1``), so grant order on the
    shared engine is unambiguous; mixing the two chains on one engine would
    tie their wake times exactly (the directions are mirror-symmetric) and
    leave the interleaving to the event loop's submission-order tie-break,
    which is not translation invariant — the schedule would stop being
    device-symmetric in the full simulation.
    """
    n = topo.n_devices
    n_fwd, n_bwd = _bidir_split(n)
    e_fwd = max(1, (topo.n_engines + 1) // 2)
    e_bwd = max(1, topo.n_engines - e_fwd)
    c = len(chunk_sizes(shard, granularity))
    last = c - 1
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo).items():
        # step 0: one read feeds both directions (copy when n == 2).
        kind = CmdKind.COPY if n == 2 else CmdKind.BCST
        dsts = (succ,) if n == 2 else (succ, pred)
        tag = ("pg0", d, 0) if n > 2 else None
        cs = list(chunked_copies(kind, d, dsts, shard, granularity, tag,
                                 per_chunk=per_chunk))
        if n_fwd == 1:
            cs.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(cs)))
        # The bcst covers distance 1 BOTH ways, so the backward chain adds
        # n_bwd - 1 steps (distances 2..n_bwd) — n - 1 deliveries total,
        # mirroring _bidir_ring_ag_queues.
        for name_prev, name, peer, steps in (
                ("pg0", "pagf", pred, range(1, n_fwd)),
                ("pg0", "pagb", succ, range(1, n_bwd))):
            n_last = steps.stop - 1
            for k in steps:
                prev = name_prev if k == 1 else name
                tag = (name, d, k) if k < n_last else None
                target = succ if name == "pagf" else pred
                copies = chunked_copies(CmdKind.COPY, d, (target,), shard,
                                        granularity, tag, per_chunk=per_chunk)
                cs = []
                for i, cc in enumerate(copies):
                    if per_chunk or i == 0:
                        w = i if per_chunk else last
                        cs.append(cmd.wait(chunk_tag((prev, peer, k - 1), w)))
                    cs.append(cc)
                if k == n_last:
                    cs.append(cmd.signal())
                if name == "pagf":
                    e = k % e_fwd
                else:
                    # min(): on a 1-engine device both chains share engine 0
                    # (no phantom engine index past n_engines - 1).
                    e = min(e_fwd + ((k - 1) % e_bwd), topo.n_engines - 1)
                queues.append(EngineQueue(d, e, tuple(cs)))
    return queues


def _pipe_aa_queues(topo: Topology, shard: int, depth: int, mcb: int,
                    per_chunk: bool) -> list[EngineQueue]:
    """Pipelined rotation ring all-to-all (``pipe_b2b``, DESIGN.md §9).

    Round ``r`` forwards the ``(n-1-r) * shard`` bytes still in transit as
    ``depth`` chunk commands (bounded by the packet ceiling).  Chunk ``i``
    of round ``r`` forwards bytes that arrived *after* the local shard of
    round ``r-1``, so its per-chunk wait resolves to the predecessor chunk
    covering offset ``(i+1)*g_r + shard`` — the dependency lands near the
    END of the previous round's stream (the rotation's forwarded payload is
    the tail of what arrived), which is why rotation all-to-all gains far
    less from per-chunk signaling than the all-gather rings (§9.3).
    """
    n = topo.n_devices
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo).items():
        for r in range(n - 1):
            payload = (n - 1 - r) * shard
            g_r = _pipe_granularity(payload, depth, mcb)
            tag = ("paa", d, r) if r < n - 2 else None
            copies = chunked_copies(CmdKind.COPY, d, (succ,), payload, g_r,
                                    tag, per_chunk=per_chunk)
            cs: list[cmd.Command] = []
            if r > 0:
                prev_payload = (n - r) * shard
                g_p = _pipe_granularity(prev_payload, depth, mcb)
                c_prev = len(chunk_sizes(prev_payload, g_p))
            for i, cc in enumerate(copies):
                if r > 0 and (per_chunk or i == 0):
                    if per_chunk:
                        need = (i + 1) * g_r + shard
                        dep = min(-(-need // g_p) - 1, c_prev - 1)
                    else:
                        dep = c_prev - 1
                    cs.append(cmd.wait(chunk_tag(("paa", pred, r - 1), dep)))
                cs.append(cc)
            if r == n - 2:
                cs.append(cmd.signal())
            queues.append(EngineQueue(d, r % topo.n_engines, tuple(cs)))
    return queues


def _ring_rs_queues(topo: Topology, shard: int, *,
                    ar: bool = False) -> list[EngineQueue]:
    """Unidirectional ring reduce-scatter (DESIGN.md §10): n-1 chained
    send steps per device, each (after step 0) preceded by the reduction of
    the predecessor's arrived partial, plus the terminal reduction that
    folds the last arrival into the device's result shard.  Tags are
    transfer-granular; chunking splits the copies AND the reductions at the
    same grain (``chunk_schedule``).  With ``ar=True`` the terminal
    reduction raises ``("arf", d, 0)`` — the all-reduce chaining hook.
    """
    n = topo.n_devices
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo).items():
        cs: list[cmd.Command] = []
        for k in range(n - 1):
            if k > 0:
                cs.append(cmd.reduce_tag(("rs", pred, k - 1), shard))
            cs.append(cmd.copy(d, succ, shard))
            cs.append(cmd.signal(("rs", d, k)))
        cs.append(cmd.reduce_tag(("rs", pred, n - 2), shard,
                                 ("arf", d, 0) if ar else None))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, 0, tuple(cs)))
    return queues


def _bidir_ring_rs_queues(topo: Topology, shard: int, *,
                          ar: bool = False) -> list[EngineQueue]:
    """Bidirectional ring reduce-scatter (DESIGN.md §10): partials flow in
    both directions — the forward chain accumulates the ``n_fwd``
    predecessors' contributions, the backward chain the ``n_bwd``
    successors' — and each device folds both terminal partials into its
    result shard (its own contribution seeds the accumulator).  Every
    device reduces exactly ``n - 1`` arrived shards, mirroring
    ``_bidir_ring_ag_queues``'s ``n - 1`` deliveries.  Unlike the bidir
    all-gather there is no step-0 ``bcst``: the two directions carry
    *different* partials, so step 0 is one copy per direction.
    """
    n = topo.n_devices
    n_fwd, n_bwd = _bidir_split(n)
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo).items():
        for name, peer, target, steps, raise_name, engine in (
                ("rsf", pred, succ, n_fwd, "arf", 0),
                ("rsb", succ, pred, n_bwd, "arb",
                 min(1, topo.n_engines - 1))):
            if steps == 0:
                continue
            cs: list[cmd.Command] = []
            cs.append(cmd.copy(d, target, shard))
            cs.append(cmd.signal((name, d, 0)))
            for k in range(1, steps):
                cs.append(cmd.reduce_tag((name, peer, k - 1), shard))
                cs.append(cmd.copy(d, target, shard))
                cs.append(cmd.signal((name, d, k)))
            cs.append(cmd.reduce_tag((name, peer, steps - 1), shard,
                                     (raise_name, d, 0) if ar else None))
            cs.append(cmd.signal())
            queues.append(EngineQueue(d, engine, tuple(cs)))
    return queues


def _pipe_ring_rs_queues(topo: Topology, shard: int, granularity: int,
                         per_chunk: bool, *, ar: bool = False) -> list[EngineQueue]:
    """Pipelined unidirectional ring reduce-scatter (``pipe_ring_rs``,
    DESIGN.md §10).

    One engine queue per ring step, like ``_pipe_ring_ag_queues``, but step
    ``k >= 1`` *reduces* each arrived chunk before forwarding the reduced
    partial: chunk ``i``'s reduction blocks on chunk ``i`` of the
    predecessor's step ``k-1`` transfer, so the reduce+forward of chunk
    ``i`` overlaps the wire time of chunk ``i+1`` — the finer-grain
    compute/communication overlap of arXiv:2512.10236.  A terminal
    reduce-only queue folds the last arrival into the result shard and
    notifies the host.  Every send step carries per-chunk tags (its
    consumer reduces every arrival), unlike the all-gather rings where the
    last step's payload is unconsumed.  ``per_chunk=False`` blocks every
    chunk reduction on the predecessor's final chunk (the control arm).
    """
    n = topo.n_devices
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo).items():
        for k in range(n - 1):
            copies = chunked_copies(CmdKind.COPY, d, (succ,), shard,
                                    granularity, ("prs", d, k),
                                    per_chunk=per_chunk)
            if k == 0:
                cs = list(copies)
            else:
                reduces = chunked_reduces(("prs", pred, k - 1), shard,
                                          granularity, per_chunk=per_chunk)
                cs = []
                for r, cc in zip(reduces, copies):
                    cs.append(r)
                    cs.append(cc)
            queues.append(EngineQueue(d, k % topo.n_engines, tuple(cs)))
        cs = list(chunked_reduces(("prs", pred, n - 2), shard, granularity,
                                  per_chunk=per_chunk,
                                  raise_tag=("arf", d, 0) if ar else None))
        cs.append(cmd.signal())
        queues.append(EngineQueue(d, (n - 1) % topo.n_engines, tuple(cs)))
    return queues


def _pipe_bidir_rs_queues(topo: Topology, shard: int, granularity: int,
                          per_chunk: bool, *, ar: bool = False) -> list[EngineQueue]:
    """Pipelined bidirectional ring reduce-scatter (``pipe_bidir_ring_rs``,
    DESIGN.md §10): the two partial chains of ``_bidir_ring_rs_queues``
    with per-chunk reductions and per-chunk tags.  As in
    ``_pipe_bidir_ag_queues``, each direction's chain wraps onto its own
    engine subset (chain-local sharing keeps wake times strictly staggered
    and the schedule translation-invariant); the terminal reduce-only
    queues extend their chain's engine rotation.
    """
    n = topo.n_devices
    n_fwd, n_bwd = _bidir_split(n)
    e_fwd = max(1, (topo.n_engines + 1) // 2)
    e_bwd = max(1, topo.n_engines - e_fwd)
    queues = []
    for d, (pred, succ) in _ring_neighbors(topo).items():
        for name, peer, target, steps, raise_name, fwd in (
                ("prf", pred, succ, n_fwd, "arf", True),
                ("prb", succ, pred, n_bwd, "arb", False)):
            if steps == 0:
                continue

            def engine(k: int) -> int:
                if fwd:
                    return k % e_fwd
                # min(): on a 1-engine device both chains share engine 0.
                return min(e_fwd + (k % e_bwd), topo.n_engines - 1)

            for k in range(steps):
                copies = chunked_copies(CmdKind.COPY, d, (target,), shard,
                                        granularity, (name, d, k),
                                        per_chunk=per_chunk)
                if k == 0:
                    cs = list(copies)
                else:
                    reduces = chunked_reduces((name, peer, k - 1), shard,
                                              granularity, per_chunk=per_chunk)
                    cs = []
                    for r, cc in zip(reduces, copies):
                        cs.append(r)
                        cs.append(cc)
                queues.append(EngineQueue(d, engine(k), tuple(cs)))
            cs = list(chunked_reduces((name, peer, steps - 1), shard,
                                      granularity, per_chunk=per_chunk,
                                      raise_tag=(raise_name, d, 0) if ar else None))
            cs.append(cmd.signal())
            queues.append(EngineQueue(d, engine(steps), tuple(cs)))
    return queues


def reduce_scatter_schedule(topo: Topology, size: int, variant: str = "ring_rs", *,
                            opt_config: OptimizationConfig | None = None,
                            max_chunk_bytes: int | None = None,
                            pipe_depth: int = PIPE_DEPTH,
                            per_chunk_signaling: bool = True) -> Schedule:
    """Reduce-scatter: every device ends with its ``size / n`` result shard
    reduced over all n contributions (DESIGN.md §10).

    Variants are the ring family (``ring_rs``, ``bidir_ring_rs``) and its
    per-chunk-pipelined renderings (``pipe_ring_rs``, ``pipe_bidir_ring_rs``);
    the ``opt_`` / ``prelaunch_`` prefixes compose as for the other
    collectives.  ``pipe_depth`` / ``per_chunk_signaling`` parameterize the
    ``pipe_`` variants exactly as in :func:`allgather_schedule`; reductions
    re-slice at the same chunk granularity as the copies feeding them, so
    reduction work is conserved at ``(n-1) * shard_chunks`` chunk
    reductions per device whatever the grain.
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    if base not in RS_VARIANTS:
        raise ValueError(f"unknown reduce-scatter variant {requested!r}")
    n = topo.n_devices
    shard = max(1, size // n)
    symmetric = _ring_closes_on_neighbors(topo)
    if base in ("pipe_ring_rs", "pipe_bidir_ring_rs"):
        mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
        g = _pipe_granularity(shard, pipe_depth, mcb)
        builder = _pipe_ring_rs_queues if base == "pipe_ring_rs" else _pipe_bidir_rs_queues
        queues = builder(topo, shard, g, per_chunk_signaling)
    else:
        builder = _ring_rs_queues if base == "ring_rs" else _bidir_ring_rs_queues
        queues = builder(topo, shard)
    name = f"rs_opt_{variant}" if optimized else f"rs_{variant}"
    sched = Schedule(name=name, queues=_maybe_prelaunch(queues, prelaunch),
                     symmetric=symmetric)
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, opt_config)


def _ar_result_tags(base: str, n: int, device: int) -> list[tuple]:
    """The tags a device's all-reduce result shard completion raises: one
    per terminal reduction (both directions on the bidir variants)."""
    n_bwd = _bidir_split(n)[1]
    tags = [("arf", device, 0)]
    if "bidir" in base and n_bwd:
        tags.append(("arb", device, 0))
    return tags


def _ar_gate_ag_sources(queues: list[EngineQueue], base: str, n: int,
                        chunks: int | None,
                        per_chunk: bool = True) -> list[EngineQueue]:
    """Gate each device's all-gather *source* queue (the one whose first
    command is a data command — every other queue chains off it through
    the ring tags) on the device's reduce-scatter result tags.

    ``chunks=None`` (the non-pipelined variants) prepends one
    transfer-granularity wait per result tag — the terminal reduction's
    fused raise rides its final chunk.  On the pipelined variants the
    result tags are chunk-indexed: with ``per_chunk=True`` the gather
    waits on result chunk ``i`` directly before its ``i``-th data chunk,
    so it starts on the first *reduced* chunk (DESIGN.md §10); with
    ``per_chunk=False`` one wait on the final result chunk gates the whole
    queue (the control arm).
    """
    out = []
    for q in queues:
        if not q.commands or q.commands[0].kind not in DATA_KINDS:
            out.append(q)
            continue
        tags = _ar_result_tags(base, n, q.device)
        cs: list[cmd.Command] = []
        if chunks is None:
            cs.extend(cmd.wait(t) for t in tags)
            cs.extend(q.commands)
        elif not per_chunk:
            cs.extend(cmd.wait(chunk_tag(t, chunks - 1)) for t in tags)
            cs.extend(q.commands)
        else:
            i = 0
            for c in q.commands:
                if c.kind in DATA_KINDS:
                    cs.extend(cmd.wait(chunk_tag(t, i)) for t in tags)
                    i += 1
                cs.append(c)
        out.append(dataclasses.replace(q, commands=tuple(cs)))
    return out


#: All-gather phase paired with each reduce-scatter variant by
#: :func:`allreduce_schedule` (same ring embedding, same chunk grain).
_AR_AG_BUILDERS = {
    "ring_rs": _ring_ag_queues,
    "bidir_ring_rs": _bidir_ring_ag_queues,
    "pipe_ring_rs": _pipe_ring_ag_queues,
    "pipe_bidir_ring_rs": _pipe_bidir_ag_queues,
}

#: The standalone all-gather *variant* each reduce-scatter variant pairs
#: with — what the RS-then-AG sequential baseline of the §10 decomposition
#: claims simulates (claims.py, tests/test_property.py).
AR_AG_VARIANT = {
    "ring_rs": "ring",
    "bidir_ring_rs": "bidir_ring",
    "pipe_ring_rs": "pipe_b2b",
    "pipe_bidir_ring_rs": "pipe_bidir_ring",
}


def allreduce_schedule(topo: Topology, size: int, variant: str = "ring_rs", *,
                       opt_config: OptimizationConfig | None = None,
                       max_chunk_bytes: int | None = None,
                       pipe_depth: int = PIPE_DEPTH,
                       per_chunk_signaling: bool = True) -> Schedule:
    """All-reduce as reduce-scatter + pipelined all-gather (DESIGN.md §10).

    ``variant`` names the reduce-scatter flavor (:data:`RS_VARIANTS` plus
    the usual prefixes); the matching all-gather rendering
    (:data:`_AR_AG_BUILDERS`) gathers the reduced shards over the same ring
    embedding at the same chunk granularity.  The two phases are chained
    through the terminal reductions' result tags: on the ``pipe_`` variants
    the gather's source queue waits *per chunk*, so the all-gather fill
    overlaps the reduce-scatter tail instead of starting after it.

    The gather phase's queues are always *armed ahead of time* (prelaunch,
    §4.5): they cannot make progress before the reduce phase's result tags
    anyway, so a real runtime enqueues their packets while the reduce
    phase streams — leaving them live would serialize the gather phase's
    full control cost on the host *before* the reduce phase's first
    doorbell, delaying the wire start by more than the overlap gains on
    host-heavy platforms.  A ``prelaunch_`` prefix additionally arms the
    reduce phase.  This is why the composed schedule is never slower than
    running the two collectives back to back (asserted in
    ``tests/test_property.py``).
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    if base not in RS_VARIANTS:
        raise ValueError(f"unknown all-reduce variant {requested!r}")
    n = topo.n_devices
    shard = max(1, size // n)
    symmetric = _ring_closes_on_neighbors(topo)
    ag_builder = _AR_AG_BUILDERS[base]
    if base in ("pipe_ring_rs", "pipe_bidir_ring_rs"):
        mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
        g = _pipe_granularity(shard, pipe_depth, mcb)
        rs_builder = _pipe_ring_rs_queues if base == "pipe_ring_rs" else _pipe_bidir_rs_queues
        rs_queues = rs_builder(topo, shard, g, per_chunk_signaling, ar=True)
        ag_queues = _ar_gate_ag_sources(
            ag_builder(topo, shard, g, per_chunk_signaling), base, n,
            len(chunk_sizes(shard, g)), per_chunk_signaling)
    else:
        rs_builder = _ring_rs_queues if base == "ring_rs" else _bidir_ring_rs_queues
        rs_queues = rs_builder(topo, shard, ar=True)
        ag_queues = _ar_gate_ag_sources(ag_builder(topo, shard), base, n, None)
    name = f"ar_opt_{variant}" if optimized else f"ar_{variant}"
    queues = _maybe_prelaunch(rs_queues, prelaunch) \
        + _maybe_prelaunch(ag_queues, True)
    sched = Schedule(name=name, queues=queues, symmetric=symmetric)
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, opt_config)


def allgather_schedule(topo: Topology, size: int, variant: str = "pcpy", *,
                       opt_config: OptimizationConfig | None = None,
                       max_chunk_bytes: int | None = None,
                       pipe_depth: int = PIPE_DEPTH,
                       per_chunk_signaling: bool = True) -> Schedule:
    """All-gather: every device sends its shard (size/n) to all n-1 peers.

    An ``opt_`` variant prefix applies the optimized command-stream
    transforms (DESIGN.md §7) to the built schedule; ``opt_config``
    customizes them.  Copies above ``max_chunk_bytes`` (default: the
    topology's calibrated sDMA packet ceiling, DESIGN.md §8.1) are split
    into pipelined chunk commands; pass ``0`` to disable chunking.

    The ``pipe_`` variants (DESIGN.md §9) additionally take ``pipe_depth``
    (minimum chunks per shard; an explicit ``max_chunk_bytes`` narrows the
    chunk granularity further, which is how the dispatch chunk sweep drives
    the pipeline depth) and ``per_chunk_signaling`` (``False`` builds the
    final-chunk-only control arm of the §9 claims).
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    if base not in AG_VARIANTS:
        raise ValueError(f"unknown all-gather variant {requested!r}")
    n = topo.n_devices
    shard = max(1, size // n)
    queues: list[EngineQueue] = []
    symmetric = True
    if base in ("pipe_b2b", "pipe_bidir_ring"):
        mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
        g = _pipe_granularity(shard, pipe_depth, mcb)
        builder = _pipe_ring_ag_queues if base == "pipe_b2b" else _pipe_bidir_ag_queues
        queues = builder(topo, shard, g, per_chunk_signaling)
        symmetric = _ring_closes_on_neighbors(topo)
    elif base == "pcpy":
        for d in range(n):
            for e, p in enumerate(x for x in range(n) if x != d):
                queues.append(EngineQueue(d, e, (cmd.copy(d, p, shard), cmd.signal())))
        symmetric = topo.fully_connected
    elif base == "bcst":
        for d in range(n):
            peers = [p for p in range(n) if p != d]
            e = 0
            it = iter(peers)
            for a in it:
                b = next(it, None)
                if b is None:
                    queues.append(EngineQueue(d, e, (cmd.copy(d, a, shard), cmd.signal())))
                else:
                    queues.append(EngineQueue(d, e, (cmd.bcst(d, a, b, shard), cmd.signal())))
                e += 1
        symmetric = topo.fully_connected
    elif base == "b2b":
        for d in range(n):
            copies = tuple(cmd.copy(d, p, shard) for p in range(n) if p != d)
            queues.append(EngineQueue(d, 0, copies + (cmd.signal(),)))
        symmetric = topo.fully_connected
    elif base == "ring":
        queues = _ring_ag_queues(topo, shard)
        symmetric = _ring_closes_on_neighbors(topo)
    else:  # bidir_ring
        queues = _bidir_ring_ag_queues(topo, shard)
        symmetric = _ring_closes_on_neighbors(topo)
    name = f"ag_opt_{variant}" if optimized else f"ag_{variant}"
    sched = Schedule(name=name, queues=_maybe_prelaunch(queues, prelaunch),
                     symmetric=symmetric)
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, opt_config)


def alltoall_schedule(topo: Topology, size: int, variant: str = "pcpy", *,
                      opt_config: OptimizationConfig | None = None,
                      max_chunk_bytes: int | None = None,
                      pipe_depth: int = PIPE_DEPTH,
                      per_chunk_signaling: bool = True) -> Schedule:
    """All-to-all: every device exchanges a size/n shard with every peer.

    With ``swap``, pair (i, j) is served by a single in-place swap command
    executed by one of the two devices (balanced round-robin assignment), so
    system-wide command count halves.  An ``opt_`` variant prefix applies the
    optimized command-stream transforms (DESIGN.md §7); ``max_chunk_bytes``
    bounds the per-command payload as in :func:`allgather_schedule`;
    ``pipe_depth``/``per_chunk_signaling`` parameterize the ``pipe_b2b``
    pipelined rotation ring (DESIGN.md §9).
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    if base not in AA_VARIANTS:
        raise ValueError(f"unknown all-to-all variant {requested!r}")
    n = topo.n_devices
    shard = max(1, size // n)
    queues: list[EngineQueue] = []
    symmetric = True
    if base == "pipe_b2b":
        mcb = topo.calib.max_chunk_bytes if max_chunk_bytes is None else max_chunk_bytes
        queues = _pipe_aa_queues(topo, shard, pipe_depth, mcb, per_chunk_signaling)
        symmetric = _ring_closes_on_neighbors(topo)
    elif base == "swap":
        # Executor assignment alternates per pair -> devices run different
        # command counts, so this schedule is never symmetric.
        symmetric = False
        per_dev_engine = {d: 0 for d in range(n)}
        for i in range(n):
            for j in range(i + 1, n):
                executor = i if (i + j) % 2 == 1 else j
                partner = j if executor == i else i
                e = per_dev_engine[executor]
                per_dev_engine[executor] += 1
                queues.append(EngineQueue(executor, e, (cmd.swap(executor, partner, shard), cmd.signal())))
    elif base == "ring":
        queues = _ring_aa_queues(topo, shard)
        symmetric = _ring_closes_on_neighbors(topo)
    else:
        symmetric = topo.fully_connected
        for d in range(n):
            peers = [p for p in range(n) if p != d]
            if base == "pcpy":
                for e, p in enumerate(peers):
                    queues.append(EngineQueue(d, e, (cmd.copy(d, p, shard), cmd.signal())))
            else:  # b2b
                copies = tuple(cmd.copy(d, p, shard) for p in peers)
                queues.append(EngineQueue(d, 0, copies + (cmd.signal(),)))
    name = f"aa_opt_{variant}" if optimized else f"aa_{variant}"
    sched = Schedule(name=name, queues=_maybe_prelaunch(queues, prelaunch),
                     symmetric=symmetric)
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, opt_config)


def kv_fetch_schedule(
    topo: Topology,
    n_blocks: int,
    block_bytes: int,
    variant: str = "pcpy",
    *,
    device: int = 0,
    b2b_fanout_threshold: int = 4 * 1024 * 1024,
    max_chunk_bytes: int | None = None,
) -> Schedule:
    """Host->device fetch of ``n_blocks`` dispersed KV-cache blocks (§5.3).

    * ``pcpy``: baseline vLLM — one ``hipMemcpyAsync`` per block, spread
      round-robin over the device's DMA engines, one signal per copy.
    * ``b2b``: our optimized path — all copies back-to-back on ONE engine
      with a single trailing signal; above the empirical 4MB threshold the
      runtime fans out to multiple engines (one signal each) for parallelism
      (paper §5.3.1).

    An ``opt_`` prefix additionally applies the optimized command-stream
    transforms (DESIGN.md §7) to the built schedule.
    """
    requested = variant
    variant, optimized = parse_optimized(variant)
    base, prelaunch = parse_variant(variant)
    total = n_blocks * block_bytes
    queues: list[EngineQueue] = []
    if base == "pcpy":
        per_engine: dict[int, list] = {}
        for b in range(n_blocks):
            e = b % topo.n_engines
            per_engine.setdefault(e, []).extend([cmd.copy("host", device, block_bytes), cmd.signal()])
        for e, cs in per_engine.items():
            queues.append(EngineQueue(device, e, tuple(cs)))
    elif base == "b2b":
        fanout = 1 if total < b2b_fanout_threshold else min(topo.n_engines, 4)
        for e in range(fanout):
            blocks = range(e, n_blocks, fanout)
            copies = tuple(cmd.copy("host", device, block_bytes) for _ in blocks)
            if copies:
                queues.append(EngineQueue(device, e, copies + (cmd.signal(),)))
    else:
        raise ValueError(f"unknown kv-fetch variant {requested!r}")
    name = f"kvfetch_opt_{variant}" if optimized else f"kvfetch_{variant}"
    sched = Schedule(name=name, queues=_maybe_prelaunch(queues, prelaunch))
    sched = _maybe_chunk(sched, topo, max_chunk_bytes)
    return _maybe_optimize(sched, optimized, None)
