"""Collective schedule builders — the paper's DMA collective designs (§4).

Each builder turns (topology, collective size, variant) into an explicit
:class:`Schedule` of engine queues, exactly as described in the paper:

* ``pcpy``  — baseline: one engine per peer, one copy+signal each (Fig. 8).
* ``bcst``  — all-gather only: broadcast commands pair up peers, halving
  commands/engines/signals (Fig. 9).
* ``swap``  — all-to-all only: in-place pairwise exchange; each pair's
  transfer is ONE command executed by one of the two devices (Fig. 10).
* ``b2b``   — all copies back-to-back on a single engine, one signal (Fig. 11).
* ``prelaunch_<v>`` — any of the above with queues armed ahead of time behind
  a ``poll`` command (Fig. 12).

Size convention: ``size`` is the collective's *total message size* as in the
paper's figures (1KB–4GB).  Each device's per-peer shard is ``size / n``.
"""
from __future__ import annotations

from . import commands as cmd
from .commands import EngineQueue, Schedule
from .topology import Topology

AG_VARIANTS = ("pcpy", "bcst", "b2b")
AA_VARIANTS = ("pcpy", "swap", "b2b")


def _maybe_prelaunch(queues: list[EngineQueue], prelaunch: bool) -> tuple[EngineQueue, ...]:
    if not prelaunch:
        return tuple(queues)
    out = []
    for q in queues:
        out.append(
            EngineQueue(
                device=q.device,
                engine=q.engine,
                commands=(cmd.poll(),) + q.commands,
                prelaunched=True,
            )
        )
    return tuple(out)


def parse_variant(variant: str) -> tuple[str, bool]:
    if variant.startswith("prelaunch_"):
        return variant[len("prelaunch_"):], True
    return variant, False


def allgather_schedule(topo: Topology, size: int, variant: str = "pcpy") -> Schedule:
    """All-gather: every device sends its shard (size/n) to all n-1 peers."""
    base, prelaunch = parse_variant(variant)
    if base not in AG_VARIANTS:
        raise ValueError(f"unknown all-gather variant {variant!r}")
    n = topo.n_devices
    shard = max(1, size // n)
    queues: list[EngineQueue] = []
    for d in range(n):
        peers = [p for p in range(n) if p != d]
        if base == "pcpy":
            for e, p in enumerate(peers):
                queues.append(EngineQueue(d, e, (cmd.copy(d, p, shard), cmd.signal())))
        elif base == "bcst":
            e = 0
            it = iter(peers)
            for a in it:
                b = next(it, None)
                if b is None:
                    queues.append(EngineQueue(d, e, (cmd.copy(d, a, shard), cmd.signal())))
                else:
                    queues.append(EngineQueue(d, e, (cmd.bcst(d, a, b, shard), cmd.signal())))
                e += 1
        elif base == "b2b":
            copies = tuple(cmd.copy(d, p, shard) for p in peers)
            queues.append(EngineQueue(d, 0, copies + (cmd.signal(),)))
    return Schedule(name=f"ag_{variant}", queues=_maybe_prelaunch(queues, prelaunch))


def alltoall_schedule(topo: Topology, size: int, variant: str = "pcpy") -> Schedule:
    """All-to-all: every device exchanges a size/n shard with every peer.

    With ``swap``, pair (i, j) is served by a single in-place swap command
    executed by one of the two devices (balanced round-robin assignment), so
    system-wide command count halves.
    """
    base, prelaunch = parse_variant(variant)
    if base not in AA_VARIANTS:
        raise ValueError(f"unknown all-to-all variant {variant!r}")
    n = topo.n_devices
    shard = max(1, size // n)
    queues: list[EngineQueue] = []
    if base == "swap":
        per_dev_engine = {d: 0 for d in range(n)}
        for i in range(n):
            for j in range(i + 1, n):
                executor = i if (i + j) % 2 == 1 else j
                partner = j if executor == i else i
                e = per_dev_engine[executor]
                per_dev_engine[executor] += 1
                queues.append(EngineQueue(executor, e, (cmd.swap(executor, partner, shard), cmd.signal())))
    else:
        for d in range(n):
            peers = [p for p in range(n) if p != d]
            if base == "pcpy":
                for e, p in enumerate(peers):
                    queues.append(EngineQueue(d, e, (cmd.copy(d, p, shard), cmd.signal())))
            else:  # b2b
                copies = tuple(cmd.copy(d, p, shard) for p in peers)
                queues.append(EngineQueue(d, 0, copies + (cmd.signal(),)))
    return Schedule(name=f"aa_{variant}", queues=_maybe_prelaunch(queues, prelaunch))


def kv_fetch_schedule(
    topo: Topology,
    n_blocks: int,
    block_bytes: int,
    variant: str = "pcpy",
    *,
    device: int = 0,
    b2b_fanout_threshold: int = 4 * 1024 * 1024,
) -> Schedule:
    """Host->device fetch of ``n_blocks`` dispersed KV-cache blocks (§5.3).

    * ``pcpy``: baseline vLLM — one ``hipMemcpyAsync`` per block, spread
      round-robin over the device's DMA engines, one signal per copy.
    * ``b2b``: our optimized path — all copies back-to-back on ONE engine
      with a single trailing signal; above the empirical 4MB threshold the
      runtime fans out to multiple engines (one signal each) for parallelism
      (paper §5.3.1).
    """
    base, prelaunch = parse_variant(variant)
    total = n_blocks * block_bytes
    queues: list[EngineQueue] = []
    if base == "pcpy":
        per_engine: dict[int, list] = {}
        for b in range(n_blocks):
            e = b % topo.n_engines
            per_engine.setdefault(e, []).extend([cmd.copy("host", device, block_bytes), cmd.signal()])
        for e, cs in per_engine.items():
            queues.append(EngineQueue(device, e, tuple(cs)))
    elif base == "b2b":
        fanout = 1 if total < b2b_fanout_threshold else min(topo.n_engines, 4)
        for e in range(fanout):
            blocks = range(e, n_blocks, fanout)
            copies = tuple(cmd.copy("host", device, block_bytes) for _ in blocks)
            if copies:
                queues.append(EngineQueue(device, e, copies + (cmd.signal(),)))
    else:
        raise ValueError(f"unknown kv-fetch variant {variant!r}")
    return Schedule(name=f"kvfetch_{variant}", queues=_maybe_prelaunch(queues, prelaunch))
