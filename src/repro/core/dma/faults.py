"""Deterministic fault injection for the DMA simulator (DESIGN.md §13).

The fault-free simulator models a world where every doorbell rings, every
semaphore raises and every link runs at nominal bandwidth.  Production
collective libraries must survive straggler engines, delayed signals and
degraded links — this module is the seeded, reproducible model of that
world, threaded through the event loop by ``simulate(..., faults=...)`` /
``run_composed(..., faults=...)``:

* :class:`Straggler` — a device (optionally one engine of it) whose data
  commands stream ``slowdown``× slower (a thermally-throttled or
  firmware-degraded sDMA engine).
* :class:`LinkDerate` — a windowed bandwidth derate of one wire resource
  (``link:{a}>{b}``, ``hostlink:{dev}:{dirn}`` or ``nic:{dev}``): transfers
  granted inside ``[start, end)`` run at ``factor`` of nominal bandwidth.
* :class:`NicFlap` — an outage window of one device's NIC: cross-node
  transfers requesting the NIC inside ``[start, end)`` are held until the
  flap clears (link-level retransmit, invisible to the command layer).
* Signal faults — every *tagged* raise (engine-scope semaphores: tagged
  ``signal`` commands and fused per-chunk tags) draws from a seeded,
  order-independent hash stream: with probability ``drop_rate`` the raise
  is lost (the doorbell that never rang), with ``delay_rate`` it lands
  ``delay_s`` late.  ``drop_tags`` names tag *names* whose first raise is
  always dropped — the deterministic handle the retry tests use.

Determinism (§13.1): every stochastic decision is a pure function of
``(seed, kind, tag, attempt)`` — a blake2b draw, independent of event-loop
iteration order and process hashing — so a fault run is reproducible from
the plan alone, and two plans differing only in ``seed`` decorrelate.  An
empty plan is *normalized away* by the simulator entry points: the
fault-free code path runs untouched and the results are bit-identical to
``simulate()`` with no plan at all (property-tested in
``tests/test_faults.py``).

Watchdog/retry semantics (§13.2) live in the event loop (``sim.py``): a
queue parked on a tag whose raise was dropped is recovered by re-issuing
the producing command after a watchdog timeout with exponential backoff
(``watchdog_s``, ``backoff``), costs charged on the real host/engine/link
timelines, at most ``max_attempts`` total attempts per tag; exhaustion
raises :class:`SimFault` carrying the full blocked-dependency diagnosis
(:class:`BlockedWaiter` rows + :class:`RetryRecord` history).
"""
from __future__ import annotations

import dataclasses
import hashlib

from .commands import tag_name

_INF = float("inf")

#: Wire-resource prefixes a :class:`LinkDerate` may target (the simulator's
#: timeline vocabulary, DESIGN.md §2/§11).
_WIRE_PREFIXES = ("link:", "hostlink:", "nic:")


def _tag_name(tag: tuple) -> object:
    """The semantic name of a (possibly composition-namespaced) tag: the
    first string element — composed runs prefix the schedule index (§12).
    Shared with the trace layer via :func:`repro.core.dma.commands.tag_name`."""
    return tag_name(tag)


def resource_device(key: str) -> int | None:
    """Device owning a wire resource key (the *sender* for links and NICs),
    or ``None`` for keys that name no device (e.g. ``host:{d}`` is not a
    wire).  Used to map live fault state onto admission decisions
    (DESIGN.md §13.4)."""
    if key.startswith("link:"):
        return int(key[5:].split(">", 1)[0])
    if key.startswith("hostlink:"):
        return int(key.split(":")[1])
    if key.startswith("nic:"):
        return int(key.split(":")[1])
    return None


@dataclasses.dataclass(frozen=True)
class Straggler:
    """One device's engines stream ``slowdown``× slower (``engine=None``
    covers every engine of the device)."""

    device: int
    engine: int | None = None
    slowdown: float = 4.0

    def __post_init__(self) -> None:
        if not self.slowdown >= 1.0:
            raise ValueError(
                f"straggler slowdown must be >= 1, got {self.slowdown}")


@dataclasses.dataclass(frozen=True)
class LinkDerate:
    """Bandwidth derate window of one wire resource: transfers granted in
    ``[start, end)`` run at ``factor`` (0 < factor <= 1) of nominal."""

    resource: str
    factor: float
    start: float = 0.0
    end: float = _INF

    def __post_init__(self) -> None:
        if not any(self.resource.startswith(p) for p in _WIRE_PREFIXES):
            raise ValueError(
                f"derate resource must be a wire key ({'/'.join(_WIRE_PREFIXES)}"
                f"...), got {self.resource!r}")
        if not 0.0 < self.factor <= 1.0:
            raise ValueError(f"derate factor must be in (0, 1], got {self.factor}")
        if self.end < self.start:
            raise ValueError(f"derate window end {self.end} < start {self.start}")


@dataclasses.dataclass(frozen=True)
class NicFlap:
    """Outage window of one device's NIC: transfers requesting ``nic:{device}``
    inside ``[start, end)`` are held until ``end``."""

    device: int
    start: float
    end: float

    def __post_init__(self) -> None:
        if self.end < self.start:
            raise ValueError(f"flap window end {self.end} < start {self.start}")


@dataclasses.dataclass(frozen=True)
class RetryRecord:
    """One watchdog-driven re-issue of a dropped signal's producer (§13.2).

    ``attempt`` counts from 1 (the original, dropped raise is attempt 0);
    ``issued_at`` is the watchdog expiry the retry was charged from,
    ``completed_at`` the re-issued command's completion, and ``raised``
    whether the re-raise survived its own fault draw."""

    tag: tuple
    attempt: int
    issued_at: float
    completed_at: float
    raised: bool


@dataclasses.dataclass(frozen=True)
class FaultReport:
    """What the fault layer did to one run (``SimResult.fault_report``).

    ``dropped``/``delayed`` list the tags whose raise was lost/delayed
    (sorted, deterministic); ``retries`` is the chronological watchdog
    retry history; ``retry_seconds`` the total wall charged to retries
    (watchdog expiry -> re-raise) across the run."""

    dropped: tuple[tuple, ...] = ()
    delayed: tuple[tuple, ...] = ()
    retries: tuple[RetryRecord, ...] = ()
    retry_seconds: float = 0.0

    @property
    def recovered(self) -> int:
        """Dropped tags eventually re-raised by a successful retry."""
        return sum(1 for r in self.retries if r.raised)


@dataclasses.dataclass(frozen=True)
class BlockedWaiter:
    """One parked queue in a :class:`SimFault` diagnosis: who waits, on
    what, who should have produced it, and the nearest tag that *was*
    raised with the same name (the off-by-one breadcrumb)."""

    device: int
    engine: int
    tag: tuple
    producer: str | None
    nearest: tuple | None


class SimFault(RuntimeError):
    """Structured deadlock/fault report (DESIGN.md §13.3).

    Raised when the event loop drains with parked waiters left and no
    retryable dropped signal remains — either a genuine schedule deadlock
    (fault-free path included) or retry exhaustion under a
    :class:`FaultPlan`.  Subclasses ``RuntimeError`` and keeps
    ``"deadlock"`` in the message so historical handlers keep working;
    ``waiters`` (sorted :class:`BlockedWaiter` rows) and ``retries`` (the
    watchdog history) carry the machine-readable diagnosis."""

    def __init__(self, message: str,
                 waiters: tuple[BlockedWaiter, ...] = (),
                 retries: tuple[RetryRecord, ...] = ()) -> None:
        super().__init__(message)
        self.waiters = waiters
        self.retries = retries


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic set of faults to inject into one run.

    ``drop_rate``/``delay_rate`` apply per tagged raise (independent draws
    from the ``seed``-keyed hash stream); ``drop_tags`` names tag *names*
    whose first raise is always dropped.  ``watchdog_s`` is the base wait
    before a parked queue's producer is re-issued, growing by ``backoff``×
    per failed attempt, up to ``max_attempts`` total attempts (the original
    raise included) before :class:`SimFault`.  An empty plan (``is_empty``)
    is normalized to ``None`` by the simulator entry points, making the
    no-fault identity structural rather than numerical.
    """

    stragglers: tuple[Straggler, ...] = ()
    link_derates: tuple[LinkDerate, ...] = ()
    nic_flaps: tuple[NicFlap, ...] = ()
    drop_rate: float = 0.0
    delay_rate: float = 0.0
    delay_s: float = 20e-6
    drop_tags: tuple[str, ...] = ()
    seed: int = 0
    watchdog_s: float = 50e-6
    max_attempts: int = 3
    backoff: float = 2.0

    def __post_init__(self) -> None:
        if not 0.0 <= self.drop_rate <= 1.0:
            raise ValueError(f"drop_rate must be in [0, 1], got {self.drop_rate}")
        if not 0.0 <= self.delay_rate <= 1.0:
            raise ValueError(f"delay_rate must be in [0, 1], got {self.delay_rate}")
        if self.delay_s < 0.0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")
        if self.watchdog_s <= 0.0:
            raise ValueError(f"watchdog_s must be > 0, got {self.watchdog_s}")
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.backoff < 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")
        # Precomputed lookup maps (not fields: eq/hash stay value-based).
        slow: dict[tuple[int, int | None], float] = {}
        for s in self.stragglers:
            k = (s.device, s.engine)
            slow[k] = max(slow.get(k, 1.0), s.slowdown)
        derates: dict[str, list[LinkDerate]] = {}
        for d in self.link_derates:
            derates.setdefault(d.resource, []).append(d)
        flaps: dict[str, list[NicFlap]] = {}
        for f in self.nic_flaps:
            flaps.setdefault(f"nic:{f.device}", []).append(f)
        object.__setattr__(self, "_slow", slow)
        object.__setattr__(self, "_derates", derates)
        object.__setattr__(self, "_flaps", flaps)

    # ------------------------------------------------------------ queries ----
    def is_empty(self) -> bool:
        """True when this plan injects nothing — the simulator then runs the
        untouched fault-free path (the §13.1 no-fault identity)."""
        return (not self.stragglers and not self.link_derates
                and not self.nic_flaps and self.drop_rate == 0.0
                and self.delay_rate == 0.0 and not self.drop_tags)

    def engine_slowdown(self, device: int, engine: int) -> float:
        """Streaming slowdown factor of one engine (>= 1)."""
        s = self._slow
        if not s:
            return 1.0
        f = s.get((device, engine), 1.0)
        g = s.get((device, None), 1.0)
        return f if f > g else g

    def derate_factor(self, resource: str, t: float) -> float:
        """Available bandwidth fraction of a wire at time ``t`` (<= 1)."""
        ds = self._derates.get(resource)
        if not ds:
            return 1.0
        f = 1.0
        for d in ds:
            if d.start <= t < d.end and d.factor < f:
                f = d.factor
        return f

    def outage_release(self, resource: str, t: float) -> float:
        """Earliest time a transfer requesting ``resource`` at ``t`` may
        start (NIC flaps hold requests until the window clears)."""
        fs = self._flaps.get(resource)
        if not fs:
            return t
        moved = True
        while moved:            # windows may chain back-to-back
            moved = False
            for f in fs:
                if f.start <= t < f.end:
                    t = f.end
                    moved = True
        return t

    def shifted(self, dt: float) -> "FaultPlan":
        """This plan expressed in a time frame whose origin is ``dt`` later:
        every derate/flap window moves earlier by ``dt``.  The serving loop
        (DESIGN.md §13.4) uses it to map workload-absolute fault windows
        into each composed round's local frame (round release times are
        offsets from the round start).  Stragglers and the signal draws are
        time-invariant and pass through; returns ``self`` when nothing is
        windowed."""
        if dt == 0.0 or (not self.link_derates and not self.nic_flaps):
            return self
        return dataclasses.replace(
            self,
            link_derates=tuple(
                dataclasses.replace(d, start=d.start - dt, end=d.end - dt)
                for d in self.link_derates),
            nic_flaps=tuple(
                dataclasses.replace(f, start=f.start - dt, end=f.end - dt)
                for f in self.nic_flaps))

    def waitable_degraded(self, t: float = 0.0) -> frozenset[int]:
        """Devices whose degradation at ``t`` is an outage window that will
        *clear* — a finite-end derate or a NIC flap.  This is the set the
        ``defer`` admission policy steers around (DESIGN.md §13.4): pushing
        a launch past a transient outage trades a bounded wait for full-rate
        service.  Permanent degradation (stragglers, unbounded derates) is
        deliberately excluded — a request's KV home is pinned, so deferring
        it would starve the request without ever finding healthier hardware;
        riding through at degraded rate strictly dominates."""
        out = set()
        for key, ds in self._derates.items():
            if any(d.start <= t < d.end and d.end < _INF for d in ds):
                dev = resource_device(key)
                if dev is not None:
                    out.add(dev)
        for key, fs in self._flaps.items():
            if any(f.start <= t < f.end for f in fs):
                out.add(resource_device(key))
        return frozenset(out)

    def degraded_devices(self, t: float = 0.0) -> frozenset[int]:
        """Devices with live fault state at time ``t``: straggler devices
        (time-invariant) plus owners of a derated wire or flapping NIC whose
        window contains ``t``.  The ``defer`` admission policy consults this
        (DESIGN.md §13.4)."""
        out = {d for d, _ in self._slow}
        for key, ds in self._derates.items():
            if any(d.start <= t < d.end for d in ds):
                dev = resource_device(key)
                if dev is not None:
                    out.add(dev)
        for key, fs in self._flaps.items():
            if any(f.start <= t < f.end for f in fs):
                out.add(resource_device(key))
        return frozenset(out)

    # -------------------------------------------------------- signal draws ----
    def _draw(self, kind: str, tag: tuple, attempt: int) -> float:
        """Uniform [0, 1) draw, a pure function of (seed, kind, tag, attempt)
        — order-independent and stable across processes (blake2b, not
        ``hash()``), so fault runs replay from the seed alone (§13.1)."""
        payload = repr((self.seed, kind, tag, attempt)).encode()
        h = hashlib.blake2b(payload, digest_size=8).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64

    def drops_signal(self, tag: tuple, attempt: int) -> bool:
        """Whether this raise of ``tag`` (attempt 0 = the original) is lost."""
        if attempt == 0 and self.drop_tags \
                and _tag_name(tag) in self.drop_tags:
            return True
        return (self.drop_rate > 0.0
                and self._draw("drop", tag, attempt) < self.drop_rate)

    def delays_signal(self, tag: tuple, attempt: int) -> bool:
        """Whether this raise of ``tag`` lands ``delay_s`` late."""
        return (self.delay_rate > 0.0
                and self._draw("delay", tag, attempt) < self.delay_rate)


def straggler_plan(device: int = 0, slowdown: float = 4.0,
                   engine: int | None = None, **kwargs) -> FaultPlan:
    """The canonical one-straggler scenario (claims/benchmarks)."""
    return FaultPlan(stragglers=(Straggler(device, engine, slowdown),), **kwargs)
