"""Span recording and Chrome trace-event export (DESIGN.md §14).

The event loop in :mod:`repro.core.dma.sim` computes every command's grant
and completion on every contended resource, then collapses them into
coalesced busy intervals.  This module keeps the per-command view: an
opt-in :class:`TraceRecorder` (``simulate(..., record_trace=True)`` /
``run_composed(..., record_trace=True)``) captures one span per command
execution — device, resource track, kind, tag, size, chunk index,
schedule namespace, fault/retry annotations — plus a flow arrow from each
tagged raise to every wait it wakes, and :func:`chrome_trace` renders the
result as Chrome ``trace_event`` JSON (the format ``ui.perfetto.dev`` and
``chrome://tracing`` load):

  * one *process* per device, one *thread* per resource
    (``host:{d}``, ``engine:{d}.{e}``, ``hostlink:{d}:{dir}``,
    ``link:{a}>{b}``, ``nic:{d}`` — links/NICs belong to the sender);
  * ``ph:"X"`` complete slices for every positive-duration command span;
  * zero-duration events (a wait whose tag already arrived, a
    zero-cost grant) are deliberately synthesized as ``ph:"i"`` instant
    events — never dropped — so span counts reconcile with the
    ``host_events``/``engine_atomics`` counters (property-tested);
  * ``ph:"s"``/``ph:"f"`` flow arrows from a raise to the waits it wakes;
  * fault windows (link derates, NIC flaps, stragglers) and
    dropped/delayed signals as instant events.

Recording forces the full event loop: the symmetric fast path (§6) and
the closed-form chunk runs (§8.3/§9.2) commit O(1) timeline updates and
would skip per-command spans, so a traced run disables them — timing is
bit-identical to the unrecorded run by the same invariants that license
those fast paths (asserted in ``tests/test_trace.py`` and by the
``benchmarks/trace_export.py`` exporter).  ``record_trace=False`` leaves
the hot path structurally untouched (``sim_perf --check`` guards the
wall-clock ratio).
"""
from __future__ import annotations

import dataclasses
import json

from .commands import tag_chunk, tag_name
from .faults import FaultPlan, resource_device


@dataclasses.dataclass(frozen=True)
class TraceSpan:
    """One command execution on one resource track (positive duration)."""

    resource: str               # timeline key, e.g. "engine:0.1", "link:0>1"
    device: int                 # owning device (sender for wires)
    schedule: int               # composition namespace index (0 for simulate)
    kind: str                   # control|doorbell|fetch|copy|bcst|swap|wire|
                                # wait|reduce|signal|sync
    start: float
    end: float
    tag: tuple | None = None
    size: int | None = None
    chunk: int | None = None
    retry: bool = False         # charged by the watchdog re-issue (§13.2)
    args: dict | None = None

    @property
    def dur(self) -> float:
        return self.end - self.start


@dataclasses.dataclass(frozen=True)
class TraceInstant:
    """A zero-duration occurrence: zero-cost command spans (synthesized,
    never dropped), prelaunch arming, dropped/delayed signals, fault
    windows."""

    resource: str
    device: int
    schedule: int
    kind: str
    time: float
    tag: tuple | None = None
    args: dict | None = None


@dataclasses.dataclass(frozen=True)
class TraceFlow:
    """One raise-to-wait dependency edge (rendered as a flow arrow)."""

    id: int
    tag: tuple
    src_resource: str
    src_time: float             # the raise (visibility time, delays included)
    dst_resource: str
    dst_time: float             # the woken wait's end (signal arrival)


@dataclasses.dataclass(frozen=True)
class SimTrace:
    """Everything one recorded run captured (``SimResult.trace``)."""

    spans: tuple[TraceSpan, ...]
    instants: tuple[TraceInstant, ...]
    flows: tuple[TraceFlow, ...]


class TraceRecorder:
    """Collects spans/instants/flows from one event-loop run.

    The simulator calls these hooks only when tracing was requested
    (``if tr is not None`` at every site), so the unrecorded path stays
    structurally untouched.  ``_ctx`` carries the issuing command's
    metadata into :meth:`wire`, which fires per route hop inside
    ``_Sim.transfer`` where the command is out of scope.
    """

    __slots__ = ("spans", "instants", "flows", "_raises", "_fid", "_ctx")

    def __init__(self) -> None:
        self.spans: list[TraceSpan] = []
        self.instants: list[TraceInstant] = []
        self.flows: list[TraceFlow] = []
        self._raises: dict[tuple, tuple[float, str]] = {}
        self._fid = 0
        self._ctx: tuple = (0, 0, None, None, False)

    # ------------------------------------------------------------ record ----
    def span(self, resource: str, device: int, schedule: int, kind: str,
             start: float, end: float, *, tag: tuple | None = None,
             size: int | None = None, chunk: int | None = None,
             retry: bool = False, args: dict | None = None) -> None:
        """Record one command execution; zero-duration spans become
        instant events (the §14 zero-duration policy)."""
        if end > start:
            self.spans.append(TraceSpan(resource, device, schedule, kind,
                                        start, end, tag=tag, size=size,
                                        chunk=chunk, retry=retry, args=args))
        else:
            self.instants.append(TraceInstant(resource, device, schedule,
                                              kind, start, tag=tag, args=args))

    def instant(self, resource: str, device: int, schedule: int, kind: str,
                time: float, *, tag: tuple | None = None,
                args: dict | None = None) -> None:
        self.instants.append(TraceInstant(resource, device, schedule, kind,
                                          time, tag=tag, args=args))

    def set_ctx(self, device: int, schedule: int, size: int | None,
                chunk: int | None, retry: bool) -> None:
        """Stash the issuing command's metadata for the wire hops its
        transfers will occupy."""
        self._ctx = (device, schedule, size, chunk, retry)

    def wire(self, resource: str, start: float, end: float) -> None:
        """One route hop's wire occupancy (called from ``_Sim.transfer``)."""
        device, schedule, size, chunk, retry = self._ctx
        self.span(resource, device, schedule, "wire", start, end,
                  size=size, chunk=chunk, retry=retry)

    def raise_tag(self, tag: tuple, time: float, resource: str) -> None:
        """A tagged semaphore became visible to waiters at ``time``."""
        self._raises[tag] = (time, resource)

    def wait(self, resource: str, device: int, schedule: int,
             start: float, end: float, tag: tuple) -> None:
        """A satisfied wait/reduce-block on ``tag`` (span from the engine
        reaching the wait to signal arrival) plus its flow edge."""
        self.span(resource, device, schedule, "wait", start, end,
                  tag=tag, chunk=tag_chunk(tag))
        src = self._raises.get(tag)
        if src is not None:
            t0, res0 = src
            self.flows.append(TraceFlow(self._fid, tag, res0, t0,
                                        resource, end))
            self._fid += 1

    def fault_windows(self, plan: FaultPlan) -> None:
        """Materialize the plan's declared fault state as instant events:
        a window start/end pair per derate and flap, one marker per
        straggler (§13 → §14)."""
        for d in plan.link_derates:
            dev = resource_device(d.resource) or 0
            self.instant(d.resource, dev, 0, "fault", d.start,
                         args={"fault": "derate", "factor": d.factor,
                               "start": d.start, "end": d.end})
            if d.end != float("inf"):
                self.instant(d.resource, dev, 0, "fault", d.end,
                             args={"fault": "derate_end", "factor": d.factor})
        for f in plan.nic_flaps:
            res = f"nic:{f.device}"
            self.instant(res, f.device, 0, "fault", f.start,
                         args={"fault": "flap", "start": f.start,
                               "end": f.end})
            self.instant(res, f.device, 0, "fault", f.end,
                         args={"fault": "flap_end"})
        for s in plan.stragglers:
            e = 0 if s.engine is None else s.engine
            self.instant(f"engine:{s.device}.{e}", s.device, 0, "fault", 0.0,
                         args={"fault": "straggler", "slowdown": s.slowdown,
                               "all_engines": s.engine is None})

    def finish(self) -> SimTrace:
        return SimTrace(spans=tuple(self.spans),
                        instants=tuple(self.instants),
                        flows=tuple(self.flows))


# ------------------------------------------------------------------------- #
# Chrome trace-event rendering                                              #
# ------------------------------------------------------------------------- #

_US = 1e6                        # simulator seconds -> trace microseconds


def _track_device(resource: str) -> int:
    """Owning device of a resource key (sender for wires)."""
    head, _, rest = resource.partition(":")
    if head == "host":
        return int(rest)
    if head == "engine":
        return int(rest.split(".", 1)[0])
    if head == "cu":
        return int(rest)
    dev = resource_device(resource)
    return 0 if dev is None else dev


def _track_rank(resource: str) -> tuple:
    """Stable thread ordering inside a device: host, engines, CUs, host
    links, DMA links, NIC."""
    order = {"host": 0, "engine": 1, "cu": 2, "hostlink": 3, "link": 4,
             "nic": 5}
    return (order.get(resource.split(":", 1)[0], 6), resource)


def _span_label(s: TraceSpan) -> str:
    if s.kind == "wait":
        return f"wait {tag_name(s.tag)}" if s.tag else "wait"
    if s.retry:
        return f"retry {s.kind}"
    return s.kind


def _span_args(s: TraceSpan) -> dict:
    args = {"schedule": s.schedule}
    if s.tag is not None:
        args["tag"] = repr(s.tag)
    if s.size is not None:
        args["size"] = s.size
    if s.chunk is not None:
        args["chunk"] = s.chunk
    if s.retry:
        args["retry"] = True
    if s.args:
        args.update(s.args)
    return args


def _extract(obj) -> SimTrace:
    trace = obj
    result = getattr(obj, "result", None)      # ComposedResult
    if result is not None:
        trace = result
    trace = getattr(trace, "trace", trace)     # SimResult
    if not isinstance(trace, SimTrace):
        raise ValueError(
            "no recorded trace: run simulate()/run_composed() with "
            "record_trace=True (got "
            f"{type(obj).__name__})")
    return trace


def chrome_trace(obj, *, label: str | None = None) -> dict:
    """Render a recorded run as a Chrome ``trace_event`` JSON object.

    ``obj`` is a :class:`SimTrace`, or a ``SimResult``/``ComposedResult``
    whose run recorded one.  One process per device, one thread per
    resource; load the dump in ``ui.perfetto.dev`` or ``chrome://tracing``.
    """
    trace = _extract(obj)
    resources = {s.resource for s in trace.spans}
    resources.update(i.resource for i in trace.instants)
    for f in trace.flows:
        resources.add(f.src_resource)
        resources.add(f.dst_resource)

    tids: dict[str, tuple[int, int]] = {}      # resource -> (pid, tid)
    by_dev: dict[int, list[str]] = {}
    for r in resources:
        by_dev.setdefault(_track_device(r), []).append(r)

    events: list[dict] = []
    for dev in sorted(by_dev):
        events.append({"name": "process_name", "ph": "M", "ts": 0,
                       "pid": dev, "tid": 0,
                       "args": {"name": f"device {dev}"}})
        events.append({"name": "process_sort_index", "ph": "M", "ts": 0,
                       "pid": dev, "tid": 0, "args": {"sort_index": dev}})
        for tid, r in enumerate(sorted(by_dev[dev], key=_track_rank)):
            tids[r] = (dev, tid)
            events.append({"name": "thread_name", "ph": "M", "ts": 0,
                           "pid": dev, "tid": tid, "args": {"name": r}})
            events.append({"name": "thread_sort_index", "ph": "M", "ts": 0,
                           "pid": dev, "tid": tid,
                           "args": {"sort_index": tid}})

    for s in trace.spans:
        pid, tid = tids[s.resource]
        events.append({"name": _span_label(s), "cat": s.kind, "ph": "X",
                       "ts": s.start * _US, "dur": s.dur * _US,
                       "pid": pid, "tid": tid, "args": _span_args(s)})
    for i in trace.instants:
        pid, tid = tids[i.resource]
        args = {"schedule": i.schedule}
        if i.tag is not None:
            args["tag"] = repr(i.tag)
        if i.args:
            args.update(i.args)
        events.append({"name": i.kind, "cat": i.kind, "ph": "i", "s": "t",
                       "ts": i.time * _US, "pid": pid, "tid": tid,
                       "args": args})
    for f in trace.flows:
        name = str(tag_name(f.tag))
        spid, stid = tids[f.src_resource]
        dpid, dtid = tids[f.dst_resource]
        events.append({"name": name, "cat": "signal", "ph": "s",
                       "id": f.id, "ts": f.src_time * _US,
                       "pid": spid, "tid": stid})
        events.append({"name": name, "cat": "signal", "ph": "f", "bp": "e",
                       "id": f.id, "ts": f.dst_time * _US,
                       "pid": dpid, "tid": dtid})

    events.sort(key=lambda e: (e["ts"], e["pid"], e["tid"]))
    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if label is not None:
        out["otherData"] = {"label": label}
    return out


def write_chrome_trace(obj, path: str, *, label: str | None = None) -> str:
    """Dump :func:`chrome_trace` JSON to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(chrome_trace(obj, label=label), f, indent=None,
                  separators=(",", ":"), sort_keys=True)
        f.write("\n")
    return path
