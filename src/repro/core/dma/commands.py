"""DMA command set modeled after AMD Instinct MI300X sDMA engines (paper §2.2, §4).

A DMA *queue* is an ordered list of commands executed by one engine. The host
(CPU) creates commands (control phase), rings the engine's doorbell (schedule
phase), the engine executes copies (copy phase) and raises completion signals
(sync phase). The novel commands — ``bcst`` (one source, two destinations),
``swap`` (in-place exchange) and ``poll`` (pre-launch trigger) — are the
hitherto-untapped features the paper exploits (Table 1).

Cross-device dependencies (DESIGN.md §2): a ``signal`` may carry a *tag*
``(name, device, step)``; a ``wait`` command blocks its engine until the
tagged signal has been raised (plus the remote-observation latency).  Tagged
signals are engine-to-engine semaphores and are NOT observed by the host;
untagged signals are the host-observed completion signals of the original
model.  Ring/torus schedules are built from these so that step *k* is timed
from the real arrival of step *k-1*'s data rather than assumed overlap.

Optimized command streams (DESIGN.md §7): a data command may carry a *fused*
signal (``fused_signal``/``fused_tag``, §7.3) that rides the transfer's final
write packet instead of occupying a standalone ``signal`` slot, and an
:class:`EngineQueue` records the host submission batch size (``batch``, §7.1)
and its SDMA queue slot on the engine (``slot``, §7.2).  The transforms in
:mod:`repro.core.dma.optimizations` produce these; baseline builders never
set them, so default schedules time identically to the unoptimized model.

Chunking (DESIGN.md §8.1): one sDMA command carries at most
``Calibration.max_chunk_bytes`` of payload, so the runtime splits GB-scale
copies into pipelined chunk commands — :func:`chunk_command` /
:func:`chunk_schedule` model exactly that.  Chunks of one transfer share a
single :class:`Command` instance (the simulator detects such runs by object
identity and executes them closed-form); a fused signal rides only the
*final* chunk.

Per-chunk signaling (DESIGN.md §9): a tag may carry a fourth element — the
*chunk index* — so each chunk of a split transfer raises its own semaphore
(:func:`chunk_tag` / :func:`chunked_copies`) and a consumer can ``wait`` on
chunk *i* instead of the whole transfer.  This is what the pipelined ring
builders in :mod:`repro.core.dma.collectives` use to start forwarding a
shard's first arrived chunk while the rest is still in flight (the
finer-grain overlap direction of arXiv:2512.10236).  Per-chunk tags are
always *fused* (they ride each chunk's final write packet): a standalone
``signal`` per chunk would double the command count and serialize the
engine front end on ``sync_engine`` round-trips.

Per-chunk reduction (DESIGN.md §10): a ``reduce_tag`` command models the
consumer side of a reduce-scatter step — it blocks like a ``wait`` on the
named (chunk) tag, then charges the reduction of ``size`` arrived bytes
(``Calibration.reduce_setup + size / reduce_bytes_per_s``) on the
consumer's engine timeline before the queue may forward the reduced
partial.  An optional ``fused_tag`` raises a semaphore at reduction
completion, which is how the all-reduce builder chains its all-gather
phase off the final reduce chunk by chunk.  :func:`chunk_command` /
:func:`chunk_schedule` split oversized reductions exactly like oversized
copies, and :func:`reduce_work` exposes the schedule-level conservation
invariant (every device of an n-device reduce-scatter performs exactly
``(n-1) * shard_chunks`` chunk reductions).

Compute tiles (DESIGN.md §15): a ``compute`` command occupies the device's
*CU timeline* (``cu:{dev}``) for one GEMM tile —
``Calibration.cu_tile_setup + size / cu_flops`` with ``size`` carrying the
tile's FLOP count.  An optional ``tag`` blocks the tile like a ``wait``
(the all-gather+GEMM fusion: tile *k* launches when shard *k* lands); an
optional ``fused_tag`` raises a semaphore at tile completion (the
GEMM+reduce-scatter fusion: tile *i*'s partial releases the RS chunk
pipeline).  A ``reduce_tag`` may set ``on_cu=True`` to charge its §10
reduction on the CU timeline instead of the consumer's engine — the
reduce-placement axis of arXiv:2512.10236.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

# A signal/wait tag: (name, producer device, step[, chunk]). Waits name the
# exact producer; the symmetric fast path rewrites the producer to the
# representative device (DESIGN.md §6).  The optional fourth element is the
# chunk index of a per-chunk-signaled transfer (DESIGN.md §9).
Tag = tuple


class CmdKind(enum.Enum):
    COPY = "copy"          # one src -> one dst
    BCST = "bcst"          # one src -> two dsts (single source read)
    SWAP = "swap"          # exchange contents of two buffers (in-place)
    POLL = "poll"          # wait until *location* satisfies a condition (prelaunch)
    SIGNAL = "signal"      # atomic inc/dec of a 64b completion signal
    WAIT = "wait"          # block engine until a tagged signal was raised
    REDUCE = "reduce_tag"  # wait on a tagged chunk, then reduce it locally (§10)
    COMPUTE = "compute"    # occupy the CU timeline for one GEMM tile (§15)


@dataclasses.dataclass(frozen=True)
class Command:
    """A single DMA engine command.

    ``src``/``dsts`` are device ids (or "host").  ``size`` is bytes moved per
    destination.  A ``swap`` moves ``size`` bytes in each direction between
    ``src`` and ``dsts[0]``.  ``poll``/``signal``/``wait`` carry no payload.
    ``tag`` names the semaphore a ``signal`` raises / a ``wait`` or
    ``reduce_tag`` blocks on; a tagged signal is engine-scope (not
    host-observed).

    Per-chunk reduction (DESIGN.md §10): a ``reduce_tag`` command carries the
    tag of the arrived chunk it consumes and ``size`` = the bytes it reduces
    on the consumer's engine timeline; an optional ``fused_tag`` raises a
    semaphore at reduction completion (the all-reduce chaining hook).

    Fused signaling (DESIGN.md §7.3): a *data* command may additionally carry
    ``fused_signal=True`` (a host-observed completion rides the final write
    packet — the host still pays one observation per fused completion) and/or
    ``fused_tag`` (an engine-scope semaphore is raised at write completion
    plus ``Calibration.fused_sync`` instead of via a standalone ``signal``
    command costing a ``sync_engine`` scheduling round-trip).
    """

    kind: CmdKind
    src: int | str | None = None
    dsts: tuple[int | str, ...] = ()
    size: int = 0
    tag: Tag | None = None
    fused_tag: Tag | None = None
    fused_signal: bool = False
    on_cu: bool = False     # REDUCE only: run the reduction on the CU (§15)

    def __post_init__(self) -> None:
        if self.kind is CmdKind.COPY and len(self.dsts) != 1:
            raise ValueError("copy needs exactly one destination")
        if self.kind is CmdKind.BCST and len(self.dsts) != 2:
            raise ValueError("bcst needs exactly two destinations")
        if self.kind is CmdKind.SWAP and len(self.dsts) != 1:
            raise ValueError("swap needs exactly one partner")
        if self.kind in (CmdKind.WAIT, CmdKind.REDUCE) and self.tag is None:
            raise ValueError(f"{self.kind.value} needs a tag to block on")
        if self.size < 0:
            raise ValueError(f"negative size {self.size}")
        if self.size == 0 and (self.kind in DATA_KINDS
                               or self.kind is CmdKind.REDUCE
                               or self.kind is CmdKind.COMPUTE):
            raise ValueError(
                f"{self.kind.value} needs a positive size — a zero-byte "
                "transfer would time as a silent no-op")
        if self.fused_signal and self.kind not in DATA_KINDS:
            raise ValueError("only data commands can carry a fused signal")
        if self.fused_tag is not None and self.kind not in DATA_KINDS \
                and self.kind not in (CmdKind.REDUCE, CmdKind.COMPUTE):
            raise ValueError(
                "only data/reduce/compute commands can carry a fused tag")
        if self.on_cu and self.kind is not CmdKind.REDUCE:
            raise ValueError("on_cu selects the REDUCE placement only — "
                             "compute commands always run on the CU")

    # ---- traffic accounting (used by the engine model & power model) ----
    @property
    def n_copies(self) -> int:
        """Equivalent number of vanilla copy operations this command expresses."""
        if self.kind is CmdKind.COPY:
            return 1
        if self.kind is CmdKind.BCST:
            return 2
        if self.kind is CmdKind.SWAP:
            return 2          # one copy each direction
        return 0

    @property
    def local_read_bytes(self) -> int:
        """Bytes read from the issuing device's HBM.

        ``bcst`` reads the source ONCE for both destinations (paper §4.2) —
        this is where its memory-traffic/power saving comes from.  ``swap``
        reads locally and writes locally (in place), plus symmetric remote
        traffic.  A ``reduce_tag`` reads both operands (the arrived chunk
        and the local accumulator) from local HBM (DESIGN.md §10).
        """
        if self.kind in (CmdKind.COPY, CmdKind.BCST, CmdKind.SWAP):
            return self.size
        if self.kind is CmdKind.REDUCE:
            return 2 * self.size
        return 0

    @property
    def remote_write_bytes(self) -> int:
        if self.kind is CmdKind.COPY:
            return self.size
        if self.kind is CmdKind.BCST:
            return 2 * self.size
        if self.kind is CmdKind.SWAP:
            return self.size  # each direction carries `size`; per-link duplex
        return 0


def copy(src, dst, size) -> Command:
    return Command(CmdKind.COPY, src, (dst,), size)


def bcst(src, dst_a, dst_b, size) -> Command:
    return Command(CmdKind.BCST, src, (dst_a, dst_b), size)


def swap(a, b, size) -> Command:
    return Command(CmdKind.SWAP, a, (b,), size)


def poll() -> Command:
    return Command(CmdKind.POLL)


def signal(tag: Tag | None = None) -> Command:
    """Untagged: host-observed completion signal. Tagged: engine semaphore."""
    return Command(CmdKind.SIGNAL, tag=tag)


def wait(tag: Tag) -> Command:
    """Block the engine until the tagged signal has been raised."""
    return Command(CmdKind.WAIT, tag=tag)


def reduce_tag(tag: Tag, size: int, raise_tag: Tag | None = None, *,
               on_cu: bool = False) -> Command:
    """Per-chunk reduction (DESIGN.md §10): block on ``tag`` like a
    ``wait``, then reduce the ``size`` arrived bytes into the local
    accumulator on the consumer's engine timeline.  ``raise_tag`` raises a
    semaphore at reduction completion (how the all-reduce builder releases
    its all-gather phase chunk by chunk).  ``on_cu=True`` moves the
    reduction onto the device's CU timeline (§15's placement axis): same
    accumulate cost, but it contends with GEMM tiles instead of with the
    engine's forwarding copies."""
    return Command(CmdKind.REDUCE, size=size, tag=tag, fused_tag=raise_tag,
                   on_cu=on_cu)


def compute(flops: int, tag: Tag | None = None,
            raise_tag: Tag | None = None) -> Command:
    """One GEMM tile on the device's CU timeline (DESIGN.md §15):
    ``Calibration.cu_tile_setup + flops / cu_flops`` of CU occupancy.
    ``tag`` (optional) blocks the tile like a ``wait`` until the named
    chunk lands; ``raise_tag`` raises a semaphore at tile completion."""
    return Command(CmdKind.COMPUTE, size=flops, tag=tag, fused_tag=raise_tag)


DATA_KINDS = (CmdKind.COPY, CmdKind.BCST, CmdKind.SWAP)

#: Kinds that carry a per-command payload bounded by the sDMA packet ceiling
#: (DESIGN.md §8.1/§10): data commands AND consumer-side reductions — a
#: reduction is re-sliced at the same granularity as the copies feeding it,
#: which is what keeps reduction-work conservation chunk-invariant.
CHUNKABLE_KINDS = DATA_KINDS + (CmdKind.REDUCE,)


def chunk_command(c: Command, max_bytes: int) -> tuple[Command, ...]:
    """Split one data/reduce command into bounded-size chunk commands
    (DESIGN.md §8.1/§10).

    A copy/bcst/swap/reduce of more than ``max_bytes`` becomes ``ceil(size /
    max_bytes)`` commands of the same kind/source/destinations: full-size
    chunks followed by one remainder chunk.  The full-size chunks all share
    ONE ``Command`` instance — the simulator recognizes such identical runs
    by object identity and schedules them in closed form.  Any fused signal
    of the original command rides only the final chunk (the semaphore /
    completion may not be raised before the last byte landed / the last
    chunk was reduced).  A split ``reduce_tag`` keeps its wait tag on every
    chunk: transfer-granularity producers raise one tag for the whole
    transfer, so each chunk reduction blocks on the same semaphore.
    ``compute`` commands are never split — a GEMM tile is the unit the
    fused builders already sized to the chunk grain (DESIGN.md §15).

    Other commands and commands already within ``max_bytes`` are returned
    unchanged; ``max_bytes <= 0`` disables chunking.
    """
    if c.kind not in CHUNKABLE_KINDS or max_bytes <= 0 or c.size <= max_bytes:
        return (c,)
    n_full, rem = divmod(c.size, max_bytes)
    body = Command(c.kind, c.src, c.dsts, max_bytes, tag=c.tag)
    chunks: list[Command] = [body] * n_full
    if rem:
        chunks.append(Command(c.kind, c.src, c.dsts, rem, tag=c.tag))
    if c.fused_tag is not None or c.fused_signal:
        chunks[-1] = dataclasses.replace(
            chunks[-1], fused_tag=c.fused_tag, fused_signal=c.fused_signal)
    return tuple(chunks)


def chunk_tag(tag: Tag, chunk: int) -> Tag:
    """The chunk-granularity tag of chunk ``chunk`` of transfer ``tag``
    (DESIGN.md §9): the transfer tag with the chunk index appended."""
    return tuple(tag) + (chunk,)


def tag_name(tag: Tag) -> object:
    """The semantic name of a (possibly composition-namespaced) tag: the
    first string element — composed runs prefix the schedule index
    (DESIGN.md §12), so the name is not always element 0."""
    for e in tag:
        if isinstance(e, str):
            return e
    return tag[0] if tag else None


def tag_chunk(tag: Tag) -> int | None:
    """The chunk index of a chunk-granularity tag, or ``None``.

    Inverse of :func:`chunk_tag` under the tag convention
    ``(name, producer_device, step[, chunk])`` with an optional leading
    schedule-namespace prefix (§12): the element three past the name, when
    present and integral, is the chunk index."""
    for i, e in enumerate(tag):
        if isinstance(e, str):
            j = i + 3
            if len(tag) > j and isinstance(tag[j], int):
                return tag[j]
            return None
    return None


def chunk_sizes(size: int, granularity: int) -> tuple[int, ...]:
    """Byte sizes of the chunks a ``size``-byte transfer splits into:
    full ``granularity`` chunks followed by one remainder chunk.
    ``granularity <= 0`` (chunking disabled) yields the whole transfer."""
    if granularity <= 0 or size <= granularity:
        return (size,)
    n_full, rem = divmod(size, granularity)
    return (granularity,) * n_full + ((rem,) if rem else ())


def chunked_copies(kind: CmdKind, src, dsts, size: int, granularity: int,
                   tag: Tag | None = None, *,
                   per_chunk: bool = True) -> tuple[Command, ...]:
    """Chunk commands of one data transfer with chunk-granularity signaling
    (DESIGN.md §9).

    Splits a ``size``-byte transfer of ``kind`` into
    :func:`chunk_sizes`-many commands.  With ``per_chunk=True`` chunk ``i``
    carries ``fused_tag=chunk_tag(tag, i)`` — its semaphore rides the
    chunk's final write packet, so a consumer waiting on
    ``chunk_tag(tag, i)`` starts as soon as *that chunk* landed.  With
    ``per_chunk=False`` only the final chunk raises its (chunk-indexed)
    tag — the final-chunk-only signaling of :func:`chunk_command`, kept as
    the control arm of the pipelined-vs-serial claims.  ``tag=None`` emits
    untagged chunks.

    Per-chunk-tagged chunks are distinct ``Command`` instances (their tags
    differ); the simulator recognizes such *equivalent-modulo-tag* runs and
    still schedules them in closed form (DESIGN.md §9.2).  Untagged chunks
    of one size share a single instance, exactly like
    :func:`chunk_command`, so the final-chunk-only control arm keeps the
    §8.3 identity-run fast path.
    """
    if kind not in DATA_KINDS:
        raise ValueError("chunked_copies needs a data command kind")
    sizes = chunk_sizes(size, granularity)
    last = len(sizes) - 1
    out = []
    untagged: dict[int, Command] = {}
    for i, sz in enumerate(sizes):
        if tag is not None and (per_chunk or i == last):
            out.append(Command(kind, src, tuple(dsts), sz,
                               fused_tag=chunk_tag(tag, i)))
            continue
        c = untagged.get(sz)
        if c is None:
            c = untagged[sz] = Command(kind, src, tuple(dsts), sz)
        out.append(c)
    return tuple(out)


def chunked_reduces(src_tag: Tag, size: int, granularity: int, *,
                    per_chunk: bool = True,
                    raise_tag: Tag | None = None,
                    on_cu: bool = False) -> tuple[Command, ...]:
    """Per-chunk reductions consuming one chunk-tagged transfer (DESIGN.md
    §10).

    Emits one ``reduce_tag`` command per :func:`chunk_sizes` chunk of a
    ``size``-byte transfer.  With ``per_chunk=True`` chunk ``i``'s
    reduction blocks on ``chunk_tag(src_tag, i)`` — it starts the moment
    that chunk lands; with ``per_chunk=False`` every chunk reduction blocks
    on the producer's *final* chunk tag (the serialized control arm of the
    §10 claims).  Either arm performs the same reduction work — one
    reduce command per chunk — so reduction-work conservation is
    signaling-grain-invariant.  ``raise_tag`` tags each chunk's reduction
    completion with ``chunk_tag(raise_tag, i)`` (all-reduce chaining);
    ``on_cu`` selects the §15 CU placement for every chunk reduction.
    """
    sizes = chunk_sizes(size, granularity)
    last = len(sizes) - 1
    out = []
    for i, sz in enumerate(sizes):
        w = i if per_chunk else last
        rt = chunk_tag(raise_tag, i) if raise_tag is not None else None
        out.append(reduce_tag(chunk_tag(src_tag, w), sz, rt, on_cu=on_cu))
    return tuple(out)


def reduce_work(schedule: "Schedule") -> dict[int, tuple[int, int]]:
    """device -> (chunk reductions, total reduced bytes).

    The reduction-work conservation invariant (DESIGN.md §10): in an
    n-device reduce-scatter every device reduces exactly ``n - 1`` shards
    — ``(n - 1) * shard_chunks`` chunk reductions — whatever the variant,
    chunk granularity, pipeline depth or signaling grain.
    """
    out: dict[int, tuple[int, int]] = {}
    for q in schedule.queues:
        for c in q.commands:
            if c.kind is CmdKind.REDUCE:
                n, b = out.get(q.device, (0, 0))
                out[q.device] = (n + 1, b + c.size)
    return out


def chunk_schedule(schedule: "Schedule", max_chunk_bytes: int) -> "Schedule":
    """Chunk every oversized data/reduce command of a schedule (DESIGN.md
    §8.1/§10).

    Applied by the collective builders with the topology's calibrated
    ``max_chunk_bytes`` before the optimization transforms, so §7.1 batching
    amortizes the per-chunk packet creation, §7.2 slots overlap the chunks'
    front-end decode, and §7.3 fuses the trailing signal onto the final
    chunk.  Preserves the traffic multiset, command order, queue attributes
    and the ``symmetric`` marking (every device is rewritten identically).
    """
    if max_chunk_bytes <= 0:
        return schedule
    queues = []
    changed = False
    for q in schedule.queues:
        if all(c.size <= max_chunk_bytes for c in q.commands
               if c.kind in CHUNKABLE_KINDS):
            queues.append(q)
            continue
        cs: list[Command] = []
        for c in q.commands:
            cs.extend(chunk_command(c, max_chunk_bytes))
        queues.append(dataclasses.replace(q, commands=tuple(cs)))
        changed = True
    if not changed:
        return schedule
    return dataclasses.replace(schedule, queues=tuple(queues))


@dataclasses.dataclass(frozen=True)
class EngineQueue:
    """Ordered commands bound to one SDMA queue of one device.

    ``(engine, slot)`` identifies the hardware queue: every engine exposes
    several independent queue slots (DESIGN.md §7.2) that each keep their own
    doorbell and command decode/issue stage, while sharing the engine's
    queue-read port (fetches serialize on the engine) and its streaming
    bandwidth.  Baseline builders leave ``slot=0`` (one queue per engine);
    the multi-queue transform spreads a queue's data commands over
    additional slots of the *same* engine.

    ``batch`` is the host submission batch size (§7.1): the host creates this
    queue's command packets in groups of ``batch`` per scheduling event,
    paying the full per-command ``control`` cost once per group and the
    amortized ``control_batched`` cost for the rest.  ``batch=1`` is the
    baseline one-event-per-command behavior.
    """

    device: int
    engine: int
    commands: tuple[Command, ...]
    prelaunched: bool = False   # queue was enqueued ahead of time, gated by a poll
    slot: int = 0               # SDMA queue slot on the engine (§7.2)
    batch: int = 1              # host submission batch size (§7.1)

    def __post_init__(self) -> None:
        if self.prelaunched and (not self.commands or self.commands[0].kind is not CmdKind.POLL):
            raise ValueError("a prelaunched queue must start with a poll command")
        if self.batch < 1:
            raise ValueError("batch size must be >= 1")
        if self.slot < 0:
            raise ValueError("negative queue slot")

    @property
    def data_commands(self) -> tuple[Command, ...]:
        return tuple(c for c in self.commands if c.kind in DATA_KINDS)

    @property
    def n_signals(self) -> int:
        """Host-observed completion signals (tagged signals are engine-scope;
        fused completion signals count — they still notify the host)."""
        return sum(1 for c in self.commands
                   if (c.kind is CmdKind.SIGNAL and c.tag is None) or c.fused_signal)


def link_traffic(schedule: "Schedule") -> dict[tuple, int]:
    """(src, dst) -> total payload bytes over all data commands.

    The schedule-level traffic invariant: chunking (§8.1), per-chunk
    signaling and pipeline depth (§9) only re-slice commands, so this map
    is identical across granularities of one variant.  ``swap`` moves
    ``size`` bytes in each direction, so it contributes to both ordered
    pairs; ``bcst`` contributes ``size`` to each destination.
    """
    out: dict[tuple, int] = {}
    for q in schedule.queues:
        for c in q.data_commands:
            for dst in c.dsts:
                out[(c.src, dst)] = out.get((c.src, dst), 0) + c.size
            if c.kind is CmdKind.SWAP:
                key = (c.dsts[0], c.src)
                out[key] = out.get(key, 0) + c.size
    return out


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A full offload schedule: every engine queue across all devices.

    ``symmetric`` is the builder's promise that every device runs the same
    program modulo device relabeling AND that no two devices contend for the
    same directed link — which lets the simulator run one representative
    device and replicate the result (DESIGN.md §6).
    """

    name: str
    queues: tuple[EngineQueue, ...]
    symmetric: bool = False

    def queues_for(self, device: int) -> list[EngineQueue]:
        return [q for q in self.queues if q.device == device]

    @property
    def devices(self) -> list[int]:
        return sorted({q.device for q in self.queues})

    def total_commands(self, device: int | None = None) -> int:
        qs = self.queues if device is None else self.queues_for(device)
        return sum(len(q.commands) for q in qs)

    def engines_used(self, device: int) -> int:
        return len({q.engine for q in self.queues_for(device)})
