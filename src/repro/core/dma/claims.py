"""Paper-claim validation: derive every headline number of the paper from the
calibrated model.  Used by tests (assert bands) and benchmarks (report).
"""
from __future__ import annotations

import dataclasses
import math

from . import collectives as C
from .dispatch import (
    best_variant_for,
    candidate_variants,
    optimized_variants,
    paper_dispatch,
    pipelined_variants,
    variant_latency,
)
from .engine import simulate, single_copy_breakdown
from .power import cu_collective_power, dma_collective_power
from .rccl_model import rccl_collective_latency
from .topology import (
    Topology,
    mi300x_cluster,
    mi300x_platform,
    rccl_aa_calibration,
    rccl_ag_calibration,
    tpu_v5e_multislice,
    tpu_v5e_pod,
)

KB = 1024
MB = 1024 * 1024

SMALL_SIZES = [2 ** i for i in range(10, 26)]    # 1KB .. 32MB
LARGE_SIZES = [2 ** i for i in range(26, 33)]    # 64MB .. 4GB
ALL_SIZES = SMALL_SIZES + LARGE_SIZES


def geomean(xs) -> float:
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def dma_latency(topo: Topology, collective: str, size: int, variant: str) -> float:
    return variant_latency(topo, collective, size, variant)


def rccl_latency(topo: Topology, collective: str, size: int) -> float:
    calib = rccl_ag_calibration() if collective == "all_gather" else rccl_aa_calibration()
    return rccl_collective_latency(topo, size, calib)


def best_variant_latency(topo: Topology, collective: str, size: int) -> tuple[str, float]:
    v = paper_dispatch(collective, size)
    return v, dma_latency(topo, collective, size, v)


def best_optimized_latency(topo: Topology, collective: str, size: int) -> tuple[str, float]:
    """Best ``opt_`` command stream at one size (DESIGN.md §7): the argmin
    over the optimized candidate set — what the paper's Fig. 13/14
    "optimized" curves plot."""
    return best_variant_for(topo, collective, size,
                            optimized_variants(topo, collective))


@dataclasses.dataclass(frozen=True)
class Claim:
    name: str
    paper_value: float
    model_value: float
    lo: float
    hi: float
    description: str

    @property
    def ok(self) -> bool:
        return self.lo <= self.model_value <= self.hi


def evaluate_claims(topo: Topology | None = None) -> list[Claim]:
    topo = topo or mi300x_platform()
    sub1m = [s for s in SMALL_SIZES if s < 1 * MB]
    upto4m = [s for s in SMALL_SIZES if s <= 4 * MB]

    def g_ratio(coll, sizes, num_v, den_v):
        return geomean(
            dma_latency(topo, coll, s, num_v) / dma_latency(topo, coll, s, den_v)
            for s in sizes
        )

    ag_pcpy = geomean(dma_latency(topo, "all_gather", s, "pcpy") / rccl_latency(topo, "all_gather", s) for s in SMALL_SIZES)
    aa_pcpy = geomean(dma_latency(topo, "all_to_all", s, "pcpy") / rccl_latency(topo, "all_to_all", s) for s in SMALL_SIZES)
    ag_best = geomean(best_variant_latency(topo, "all_gather", s)[1] / rccl_latency(topo, "all_gather", s) for s in SMALL_SIZES)
    aa_best = geomean(best_variant_latency(topo, "all_to_all", s)[1] / rccl_latency(topo, "all_to_all", s) for s in SMALL_SIZES)
    ag_large = geomean(rccl_latency(topo, "all_gather", s) / dma_latency(topo, "all_gather", s, "pcpy") for s in LARGE_SIZES)
    aa_large = geomean(rccl_latency(topo, "all_to_all", s) / dma_latency(topo, "all_to_all", s, "pcpy") for s in LARGE_SIZES)
    fig1_max = max(dma_latency(topo, "all_gather", s, "pcpy") / rccl_latency(topo, "all_gather", s) for s in SMALL_SIZES)

    b4k = single_copy_breakdown(4 * KB, topo)
    b2m = single_copy_breakdown(2 * MB, topo)

    # Power: best DMA vs RCCL at a bandwidth-bound size (paper: ~32% less at >=64MB).
    s_bw = 256 * MB
    v, lat_dma = best_variant_latency(topo, "all_gather", s_bw)
    sim = simulate(C.allgather_schedule(topo, s_bw, v), topo)
    p_dma = dma_collective_power(topo, s_bw, sim).total
    p_cu = cu_collective_power(topo, s_bw, rccl_latency(topo, "all_gather", s_bw)).total
    power_saving_bw = 1 - p_dma / p_cu

    claims = [
        Claim("ag_pcpy_gap_small", 4.5, ag_pcpy, 3.4, 5.6,
              "AG pcpy geomean slowdown vs RCCL, sizes <32MB (paper ~4.5x)"),
        Claim("aa_pcpy_gap_small", 2.5, aa_pcpy, 1.9, 3.3,
              "AA pcpy geomean slowdown vs RCCL, sizes <32MB (paper ~2.5x)"),
        Claim("ag_optimized_small", 1.30, ag_best, 1.1, 1.55,
              "AG best-variant geomean vs RCCL <32MB (paper: 30% slower)"),
        Claim("aa_optimized_small", 0.83, aa_best, 0.70, 0.95,
              "AA best-variant geomean vs RCCL <32MB (paper: 20% faster)"),
        Claim("ag_pcpy_speedup_large", 1.14, ag_large, 1.05, 1.30,
              "AG pcpy geomean speedup vs RCCL >32MB (paper 14%)"),
        Claim("aa_pcpy_speedup_large", 1.18, aa_large, 1.05, 1.30,
              "AA pcpy geomean speedup vs RCCL >32MB (paper 18%)"),
        Claim("fig1_max_gap", 7.0, fig1_max, 5.0, 8.5,
              "Max AG pcpy slowdown across latency-bound sizes (paper: up to 7x)"),
        Claim("bcst_vs_pcpy", 1.7, g_ratio("all_gather", upto4m, "pcpy", "bcst"), 1.35, 2.05,
              "bcst speedup over pcpy, AG <=4MB (paper 1.7x geomean)"),
        Claim("swap_vs_pcpy", 1.7, g_ratio("all_to_all", upto4m, "pcpy", "swap"), 1.35, 2.05,
              "swap speedup over pcpy, AA <=4MB (paper 1.7x geomean)"),
        Claim("b2b_vs_pcpy_ag", 2.7, g_ratio("all_gather", sub1m, "pcpy", "b2b"), 2.1, 3.3,
              "b2b speedup over pcpy, AG <1MB (paper 2.7x geomean)"),
        Claim("b2b_vs_pcpy_aa", 2.5, g_ratio("all_to_all", sub1m, "pcpy", "b2b"), 2.0, 3.1,
              "b2b speedup over pcpy, AA <1MB (paper 2.5x geomean)"),
        Claim("b2b_vs_bcst", 1.5, g_ratio("all_gather", sub1m, "bcst", "b2b"), 1.25, 1.85,
              "b2b speedup over bcst, AG <1MB (paper 1.5x geomean)"),
        Claim("prelaunch_pcpy", 1.9, g_ratio("all_gather", ALL_SIZES, "pcpy", "prelaunch_pcpy"), 1.55, 2.25,
              "prelaunch speedup on pcpy across sizes (paper 1.9x)"),
        Claim("prelaunch_bcst", 1.5, g_ratio("all_gather", ALL_SIZES, "bcst", "prelaunch_bcst"), 1.25, 1.8,
              "prelaunch speedup on bcst across sizes (paper 1.5x)"),
        Claim("prelaunch_b2b", 1.2, g_ratio("all_gather", ALL_SIZES, "b2b", "prelaunch_b2b"), 1.08, 1.45,
              "prelaunch speedup on b2b across sizes (paper 1.2x)"),
        Claim("noncopy_fraction_4kb", 0.60, b4k.noncopy_fraction, 0.45, 0.75,
              "Non-copy phases of a 4KB DMA copy (paper: up to ~60%)"),
        Claim("noncopy_fraction_2mb", 0.15, b2m.noncopy_fraction, 0.03, 0.20,
              "Non-copy phases of a >1MB copy (paper: <20%)"),
        Claim("power_saving_bw_bound", 0.32, power_saving_bw, 0.20, 0.45,
              "DMA AG power saving vs RCCL at >=64MB (paper ~32%)"),
    ]
    claims += optimized_stream_claims(topo)
    claims += optimized_power_claims(topo)
    claims += pipelined_stream_claims()
    claims += reduce_stream_claims()
    claims += hierarchical_stream_claims()
    claims += fused_overlap_claims()
    return claims


#: Mid-size band of the pipelined-ring claims (DESIGN.md §9): large enough
#: that the rings' per-step stalls are shard-time-scale (pipelining has
#: something to overlap), small enough that the wire floor has not yet
#: crushed every stream onto the same bandwidth-bound latency.
PIPE_MID_SIZES = [1 * MB, 2 * MB, 4 * MB, 8 * MB, 16 * MB, 32 * MB]

#: Chunk-count sweep of the per-chunk-signaling claim: pipeline depths up to
#: the sweep ceiling ``collectives.PIPE_DEPTH`` (= 4), plus one deeper point
#: that must still beat final-chunk-only signaling even though per-chunk
#: packet/issue costs have passed the optimum (DESIGN.md §9.1).
PIPE_DEPTH_SWEEP = (1, 2, 4, 8)


def pipe_vs_final_chunk_ratio(topo: Topology, size: int, depth: int,
                              variant: str = "pipe_b2b",
                              collective: str = "all_gather") -> float:
    """Latency ratio of final-chunk-only over per-chunk signaling for one
    pipelined schedule shape (DESIGN.md §9.1).  Both arms build the *same*
    queues/chunks; only the wait/signal granularity differs — >1 means
    per-chunk signaling wins.  Depth 1 is structurally ≈1 (one chunk, one
    signal either way)."""
    builder = (C.allgather_schedule if collective == "all_gather"
               else C.alltoall_schedule)
    per_chunk = simulate(builder(topo, size, variant, pipe_depth=depth), topo)
    final_only = simulate(builder(topo, size, variant, pipe_depth=depth,
                                  per_chunk_signaling=False), topo)
    return final_only.latency / per_chunk.latency


def pipelined_stream_claims(
    topo: Topology | None = None,
    collectives: tuple[str, ...] = ("all_gather", "all_to_all"),
) -> list[Claim]:
    """Claim bands for the pipelined ring collectives (DESIGN.md §9).

    Pinned on the TPU v5e torus (16 devices) by default — the neighbor-link
    topology where ring renderings are the dispatch winners, so pipelining
    them moves the end-to-end policy (on the fully-connected MI300X the
    direct variants own the bandwidth-bound range and the ring family is
    only reachable by explicit request).  Three bands:

    * ``pipe_chunk_signaling_gain`` — per-chunk vs final-chunk-only
      signaling of the same ``pipe_b2b`` schedule at the sweep-ceiling
      depth (4 chunks/shard), 1MB: the consumer starts forwarding on the
      first arrived chunk instead of the whole shard (the §9 acceptance
      claim; monotonicity across ``PIPE_DEPTH_SWEEP`` is asserted in
      ``tests/test_sim.py``).
    * ``pipe_midsize_gain`` — best ``pipe_`` stream vs the best
      non-pipelined stream over the *full* candidate set (baseline,
      ``prelaunch_``, ``opt_``) across the mid-size band: pipelining beats
      both the baseline and the §7-optimized streams there (the winner is
      ``opt_prelaunch_pipe_bidir_ring`` — per-chunk signaling composes
      with batching, fusion and prelaunch).
    * ``pipe_aa_parity`` — rotation all-to-all gains almost nothing from
      per-chunk signaling (§9.3: the forwarded payload is the *tail* of the
      previous round's arrivals, so chunk dependencies degenerate toward
      final-chunk waits); the band documents parity rather than a win.
    """
    topo = topo or tpu_v5e_pod(16)

    claims: list[Claim] = []
    if "all_gather" in collectives:
        nonpipe = candidate_variants(topo, "all_gather", allow_optimized=True)
        pipe = pipelined_variants(topo, "all_gather")
        midsize = geomean(
            min(variant_latency(topo, "all_gather", s, v) for v in nonpipe)
            / min(variant_latency(topo, "all_gather", s, v) for v in pipe)
            for s in PIPE_MID_SIZES)
        chunk_gain = pipe_vs_final_chunk_ratio(topo, 1 * MB, depth=4)
        claims += [
            Claim("pipe_chunk_signaling_gain", 1.4, chunk_gain, 1.15, 1.7,
                  "pipe_b2b AG per-chunk vs final-chunk-only signaling, depth 4 "
                  "@1MB, TPU torus (arXiv:2512.10236 direction)"),
            Claim("pipe_midsize_gain", 1.08, midsize, 1.03, 1.25,
                  "best pipe_ stream over best baseline/opt_ stream, AG 1-32MB "
                  "geomean, TPU torus (DESIGN.md §9)"),
        ]
    if "all_to_all" in collectives:
        aa_parity = geomean(
            variant_latency(topo, "all_to_all", s, "ring")
            / variant_latency(topo, "all_to_all", s, "pipe_b2b")
            for s in PIPE_MID_SIZES)
        claims += [
            Claim("pipe_aa_parity", 1.01, aa_parity, 0.97, 1.08,
                  "rotation AA ring over pipe_b2b, 1-32MB geomean — per-chunk "
                  "signaling is ~parity for rotation all-to-all (§9.3)"),
        ]
    return claims


def rs_pipe_vs_final_chunk_ratio(topo: Topology, size: int, depth: int,
                                 variant: str = "pipe_bidir_ring_rs") -> float:
    """Latency ratio of final-chunk-only over per-chunk signaling for one
    pipelined reduce-scatter shape (DESIGN.md §10).  Both arms build the
    SAME queues, chunks and reductions — only the wait/signal granularity
    differs — so >1 means reducing each chunk as it lands wins.  Depth 1
    is structurally ≈1."""
    per_chunk = simulate(
        C.reduce_scatter_schedule(topo, size, variant, pipe_depth=depth), topo)
    final_only = simulate(
        C.reduce_scatter_schedule(topo, size, variant, pipe_depth=depth,
                                  per_chunk_signaling=False), topo)
    return final_only.latency / per_chunk.latency


def allreduce_decomposition_ratio(topo: Topology, size: int,
                                  variant: str = "pipe_bidir_ring_rs") -> float:
    """Sequential RS-then-AG latency over the composed all-reduce
    (DESIGN.md §10): the gather phase of the composed schedule is armed
    ahead of time and chained chunk-by-chunk off the terminal reductions,
    so the ratio is >= 1 — the decomposition never pays for the fusion."""
    ag_variant = C.AR_AG_VARIANT[variant]
    ar = simulate(C.allreduce_schedule(topo, size, variant), topo)
    rs = simulate(C.reduce_scatter_schedule(topo, size, variant), topo)
    ag = simulate(C.allgather_schedule(topo, size, ag_variant), topo)
    return (rs.latency + ag.latency) / ar.latency


def reduce_stream_claims(
    mi300x: Topology | None = None,
    tpu: Topology | None = None,
) -> list[Claim]:
    """Claim bands for the reduce collectives (DESIGN.md §10).

    * ``rs_pipe_chunk_signaling_gain`` — per-chunk vs final-chunk-only
      signaling of the same ``pipe_bidir_ring_rs`` schedule at the
      sweep-ceiling depth (4 chunks/shard), 1MB on the TPU torus: the
      consumer reduces (and forwards) chunk *i* the moment it lands
      instead of waiting for the whole partial — the §10 acceptance claim
      (>1 at >= 2 chunks is property-tested across the mid band).
    * ``allreduce_decomposition`` / ``allreduce_decomposition_mi300x`` —
      sequential RS-then-AG over the composed all-reduce, geomean across
      the mid-size band on BOTH modeled platforms: composing the phases
      (armed gather chained per-chunk off the terminal reductions) is
      never slower than running them back to back, with the gain coming
      from the gather phase's host work and fill leaving the critical
      path.
    """
    mi300x = mi300x or mi300x_platform()
    tpu = tpu or tpu_v5e_pod(16)
    chunk_gain = rs_pipe_vs_final_chunk_ratio(tpu, 1 * MB, depth=4)
    decomp_tpu = geomean(allreduce_decomposition_ratio(tpu, s)
                         for s in PIPE_MID_SIZES)
    decomp_mi = geomean(allreduce_decomposition_ratio(mi300x, s)
                        for s in PIPE_MID_SIZES)
    return [
        Claim("rs_pipe_chunk_signaling_gain", 1.45, chunk_gain, 1.15, 1.75,
              "pipe_bidir_ring_rs per-chunk vs final-chunk-only signaling, "
              "depth 4 @1MB, TPU torus (DESIGN.md §10, arXiv:2512.10236)"),
        Claim("allreduce_decomposition", 1.10, decomp_tpu, 1.0, 1.35,
              "sequential RS+AG over composed all-reduce, "
              "pipe_bidir_ring_rs 1-32MB geomean, TPU torus (§10)"),
        Claim("allreduce_decomposition_mi300x", 1.25, decomp_mi, 1.0, 1.55,
              "sequential RS+AG over composed all-reduce, "
              "pipe_bidir_ring_rs 1-32MB geomean, MI300X (§10)"),
    ]


#: Bandwidth-bound band of the fused-overlap claims (DESIGN.md §15): the
#: GEMM tile stream and the collective pipeline are both deep enough that
#: the steady-state overlap (not the fill/drain edges) sets the ratio.
FUSED_BW_SIZES = [64 * MB, 256 * MB, 1024 * MB]


def fused_overlap_gain(topo: Topology, collective: str, size: int,
                       variant: str) -> float:
    """Sequential GEMM-then-collective over the fused schedule.

    The ``seq`` arm is the control: the *identical* command stream with
    every gate coarsened to the final tile / final arrival (same host
    control cost, only the gating grain differs — the per-chunk idiom of
    §9/§10 applied to the compute boundary), so the ratio isolates what
    fine-grained tile/chunk signaling buys.
    """
    return (variant_latency(topo, collective, size, "seq")
            / variant_latency(topo, collective, size, variant))


def fused_exposed_comm_fraction(topo: Topology, size: int,
                                variant: str = "fused_engine_d4") -> float:
    """Fraction of the collective's standalone time still exposed after
    fusing, ``1 - (t_seq - t_fused) / t_collective_alone``.

    The sequential arm exposes the whole collective (fraction 1.0 by
    construction); the fused arm hides all but the fill/drain edges and
    whatever the CU timeline cannot absorb.  The standalone collective is
    the matching unfused pipeline (``pipe_ring_rs``) so numerator and
    denominator share the chunk/depth structure.
    """
    seq = variant_latency(topo, "fused_gemm_rs", size, "seq")
    fused = variant_latency(topo, "fused_gemm_rs", size, variant)
    alone = variant_latency(topo, "reduce_scatter", size, "pipe_ring_rs")
    return 1.0 - (seq - fused) / alone


def fused_overlap_claims(
    mi300x: Topology | None = None,
    tpu: Topology | None = None,
) -> list[Claim]:
    """Claim bands for fused compute-collective overlap (DESIGN.md §15).

    No direct paper counterpart — DMA-Latte measures standalone
    collectives — so the paper_value column carries the model's design
    point and the bands are empirical envelopes around the calibrated
    simulator (the fused-never-slower floor itself is property-tested
    across the whole swept grid in tests/test_fused.py).

    * ``fused_rs_overlap_gain`` / ``fused_ag_overlap_gain`` — sequential
      GEMM-then-collective over the fused pipeline at bandwidth-bound
      sizes on MI300X: with GEMM_FLOPS_PER_BYTE arithmetic intensity the
      tile stream is compute-bound, so nearly the whole collective hides
      under it (``_tpu`` twins on the v5e torus, where the slower MXU
      stream leaves less slack and the gain is thinner).
    * ``fused_exposed_comm_fraction`` — what is left of the standalone
      reduce-scatter time after fusing, at 256MB on MI300X.
    * ``fused_reduce_placement_cu_small`` — at latency-bound sizes the
      CU-side reduction wins: it skips the per-chunk descriptor dispatch
      (``reduce_setup``) while the CU timeline has slack, à la
      arXiv:2512.10236's fused-epilogue reductions.
    * ``fused_reduce_placement_engine_large`` — at bandwidth-bound sizes
      the engine-side reduction wins: the GEMM is compute-bound, so
      CU-placed accumulates extend the critical CU path while the SDMA
      engines have slack.
    """
    mi300x = mi300x or mi300x_platform()
    tpu = tpu or tpu_v5e_pod(16)
    gains = {
        (name, coll): geomean(
            fused_overlap_gain(topo, f"fused_{coll}", s, variant)
            for s in FUSED_BW_SIZES)
        for name, topo in (("mi300x", mi300x), ("tpu", tpu))
        for coll, variant in (("gemm_rs", "fused_engine_d4"),
                              ("ag_gemm", "fused_d4"))
    }
    exposed = fused_exposed_comm_fraction(mi300x, 256 * MB)
    cu_small = (variant_latency(mi300x, "fused_gemm_rs", 16 * KB,
                                "fused_engine_d4")
                / variant_latency(mi300x, "fused_gemm_rs", 16 * KB,
                                  "fused_cu_d4"))
    eng_large = (variant_latency(mi300x, "fused_gemm_rs", 256 * MB,
                                 "fused_cu_d4")
                 / variant_latency(mi300x, "fused_gemm_rs", 256 * MB,
                                   "fused_engine_d4"))
    return [
        Claim("fused_rs_overlap_gain", 1.55, gains[("mi300x", "gemm_rs")],
              1.30, 1.80, "seq GEMM-then-RS over fused_engine_d4, 64MB-1GB "
              "geomean, MI300X (DESIGN.md §15)"),
        Claim("fused_ag_overlap_gain", 1.55, gains[("mi300x", "ag_gemm")],
              1.30, 1.80, "seq AG-then-GEMM over fused_d4, 64MB-1GB "
              "geomean, MI300X (§15)"),
        Claim("fused_rs_overlap_gain_tpu", 1.12, gains[("tpu", "gemm_rs")],
              1.05, 1.25, "seq GEMM-then-RS over fused_engine_d4, 64MB-1GB "
              "geomean, TPU torus (§15)"),
        Claim("fused_ag_overlap_gain_tpu", 1.12, gains[("tpu", "ag_gemm")],
              1.05, 1.25, "seq AG-then-GEMM over fused_d4, 64MB-1GB "
              "geomean, TPU torus (§15)"),
        Claim("fused_exposed_comm_fraction", 0.05, exposed, 0.0, 0.12,
              "RS time still exposed after fusing @256MB, MI300X (§15)"),
        Claim("fused_reduce_placement_cu_small", 1.04, cu_small,
              1.005, 1.15, "engine- over CU-placed reduce @16KB, MI300X: "
              "CU epilogue skips the per-chunk descriptor dispatch (§15, "
              "arXiv:2512.10236)"),
        Claim("fused_reduce_placement_engine_large", 1.45, eng_large,
              1.20, 1.70, "CU- over engine-placed reduce @256MB, MI300X: "
              "compute-bound tile stream has no slack for accumulates (§15)"),
    ]


#: Bandwidth-bound band of the hierarchical claims (DESIGN.md §11): large
#: enough that per-message NIC latency is amortized and the tiers' wire
#: times dominate — where the intra/inter decomposition pays.
HIER_BW_SIZES = [16 * MB, 32 * MB, 64 * MB, 128 * MB]


def hierarchical_stream_claims(
    cluster: Topology | None = None,
    multislice: Topology | None = None,
) -> list[Claim]:
    """Claim bands for the hierarchical multi-node collectives (DESIGN.md
    §11).  No paper counterpart — DMA-Latte measures a single node — so the
    paper_value column carries the model's own design point and the bands
    are honest empirical envelopes around the calibrated simulator.

    * ``hier_ag_nic_gain`` — hierarchical AG over the *flat* ring AG on a
      2-node MI300X RDMA cluster, bandwidth-bound geomean: the flat ring
      drags every shard across the node boundary ``P`` extra times (its
      NIC bytes scale with total device count), the hier decomposition
      crosses once per remote node.  Deliberately vs the ring rendering:
      the direct fan-out (``pcpy``) still wins on a *2-node* fully
      connected cluster in the model — 7 parallel intra links against the
      ring tier's one — and the sweep docs say so; its NIC bytes scale
      with ``(M-1)·P·shard`` though, so the hier family is what survives
      at slice counts where fan-out saturates the NIC.
    * ``hier_pipe_overlap_gain`` — ``hier_pipe`` over ``hier_ring`` AG on
      a 64-device TPU multislice: gating each intra sub-round on its own
      block's DCN arrival overlaps the local gather with the inter tier
      instead of serializing behind it (§11.2).
    """
    cluster = cluster or mi300x_cluster(2)
    multislice = multislice or tpu_v5e_multislice(64)
    nic_gain = geomean(
        variant_latency(cluster, "all_gather", s, "ring")
        / variant_latency(cluster, "all_gather", s, "hier_ring")
        for s in HIER_BW_SIZES)
    pipe_gain = geomean(
        variant_latency(multislice, "all_gather", s, "hier_ring")
        / variant_latency(multislice, "all_gather", s, "hier_pipe")
        for s in HIER_BW_SIZES)
    return [
        Claim("hier_ag_nic_gain", 1.26, nic_gain, 1.10, 1.45,
              "hier_ring over flat ring AG, 16-128MB geomean, 2-node MI300X "
              "RDMA cluster (DESIGN.md §11; no paper counterpart)"),
        Claim("hier_pipe_overlap_gain", 1.15, pipe_gain, 1.05, 1.30,
              "hier_pipe over hier_ring AG, 16-128MB geomean, 64-device TPU "
              "multislice (DESIGN.md §11.2)"),
    ]


def optimized_power_claims(topo: Topology | None = None) -> list[Claim]:
    """Power saving of the optimized command streams (DESIGN.md §8.4).

    The paper reports a 3-10% *additional* GPU power saving for the §7
    streams on top of the DMA collectives' compute-side savings: batched
    submission collapses host scheduling wakeups and fused write+signal
    skips the engine's atomic round-trip.  Priced by
    :func:`repro.core.dma.power.dma_collective_power` from the simulator's
    event counts, compared baseline-vs-optimized on the same schedule family
    over the latency-bound range (where per-command overhead dominates).
    """
    topo = topo or mi300x_platform()
    # Latency-bound range (Fig. 7: non-copy phases dominate below ~1MB);
    # above it the optimized stream finishes sooner, which *raises* its
    # average power draw even as energy falls, washing out the comparison.
    sizes = [s for s in SMALL_SIZES if 16 * KB <= s <= 1 * MB]
    savings = []
    for s in sizes:
        base = simulate(C.allgather_schedule(topo, s, "pcpy"), topo)
        opt = simulate(C.allgather_schedule(topo, s, "opt_pcpy"), topo)
        p_base = dma_collective_power(topo, s, base).total
        p_opt = dma_collective_power(topo, s, opt).total
        savings.append(1 - p_opt / p_base)
    avg = sum(savings) / len(savings)
    return [
        Claim("opt_power_saving_small", 0.065, avg, 0.03, 0.10,
              "Additional AG power saving of opt_ streams, 16KB-1MB "
              "(paper: 3-10%)"),
    ]


def optimized_stream_claims(
    topo: Topology | None = None,
    collectives: tuple[str, ...] = ("all_gather", "all_to_all"),
) -> list[Claim]:
    """Claim bands for the optimized command streams (DESIGN.md §7).

    The paper's optimized implementations (batched scheduling, SDMA queue
    parallelism, fused write+signal) close the small-size gap to ~30% slower
    (all-gather) / ~20% faster (all-to-all) than RCCL and add ~7% at
    bandwidth-bound sizes.  With chunked command streams (DESIGN.md §8.1)
    the model lands on the large-size gain too: a GB-scale copy is hundreds
    of bounded-size sDMA commands whose per-chunk packet creation §7.1
    batching amortizes, so the large-size band is pinned at the paper's
    value (lower bound 1.05) rather than the pre-chunking conservative ~4%.

    ``collectives`` restricts which sweeps run — benchmarks that report a
    single collective pass just that one to skip the other's simulations.
    """
    topo = topo or mi300x_platform()

    def opt_small(coll):
        return geomean(
            best_optimized_latency(topo, coll, s)[1] / rccl_latency(topo, coll, s)
            for s in SMALL_SIZES)

    def opt_large_gain(coll):
        return geomean(
            dma_latency(topo, coll, s, "pcpy") / dma_latency(topo, coll, s, "opt_pcpy")
            for s in LARGE_SIZES)

    claims: list[Claim] = []
    if "all_gather" in collectives:
        claims += [
            Claim("opt_ag_small", 1.30, opt_small("all_gather"), 1.10, 1.55,
                  "Optimized-stream AG geomean vs RCCL <32MB (paper: 30% slower)"),
            Claim("opt_ag_large_gain", 1.07, opt_large_gain("all_gather"), 1.05, 1.15,
                  "opt_pcpy over pcpy, AG >=64MB (paper: ~7% large-size gain)"),
        ]
    if "all_to_all" in collectives:
        claims += [
            Claim("opt_aa_small", 0.83, opt_small("all_to_all"), 0.70, 0.95,
                  "Optimized-stream AA geomean vs RCCL <32MB (paper: 20% faster)"),
            Claim("opt_aa_large_gain", 1.07, opt_large_gain("all_to_all"), 1.05, 1.15,
                  "opt_pcpy over pcpy, AA >=64MB (paper: ~7% large-size gain)"),
        ]
    return claims


# ----------------------------------------------------------------------- #
# Concurrent-traffic serving claims (DESIGN.md §12)                       #
# ----------------------------------------------------------------------- #

#: Canonical offered-load sweep (requests/s) of ``fig_serving_load``: the
#: low end is unloaded (every TTFT at the Fig. 16 number), the high end is
#: past the host-link saturation knee of the canonical workload below.
SERVING_RATES = (250.0, 500.0, 1000.0, 1500.0, 2000.0, 3000.0)


def serving_workload(rate: float):
    """The canonical contention workload: 100 bursty requests (MMPP,
    burst_factor 10), 4096-token prompts (±25%), 4 output tokens, seed 7 —
    fetch-dominated serving where KV-fetch DMA traffic, not decode compute,
    is the bottleneck (the regime the paper's offload targets)."""
    from repro.serve.workload import synthetic_workload

    return synthetic_workload(100, rate, seed=7, kind="bursty",
                              prompt_tokens=4096, output_tokens=4,
                              burst_factor=10.0, p_enter=0.4, p_exit=0.1)


def serving_report(rate: float, admission: str):
    """One point of the serving sweep: the canonical workload through the
    §12 continuous-batching loop under ``admission`` ("fifo"/"defer")."""
    from repro.serve.engine import ServingConfig, ServingSimulator

    sim = ServingSimulator(ServingConfig(admission=admission))
    return sim.run(serving_workload(rate))


def serving_load_claims(reports=None) -> list[Claim]:
    """Claim bands for serving under concurrent traffic (DESIGN.md §12).

    * ``serving_ttft_knee`` — p99 TTFT under FIFO admission degrades ~8.5x
      between the unloaded low end and the post-knee high end of the
      canonical sweep: composed-schedule contention (host-link queueing of
      concurrent KV fetches + batch-slot head-of-line blocking) produces a
      saturation knee, not graceful linear growth.
    * ``serving_admission_gain`` — the contention-aware admission policy
      (defer a launch when the target host link's fetch queue is at depth)
      recovers ~1.75x goodput over FIFO past the knee by keeping bursts on
      a hot device from pinning batch slots and starving cool links, while
      staying neutral at low load.

    ``reports`` optionally supplies precomputed ``{(rate, admission):
    ServingReport}`` points (the benchmark passes its sweep) so the three
    endpoint runs are not simulated twice.  Values are model-derived (no
    paper counterpart figure — the paper measures one request at a time);
    the bands pin today's behavior against regressions.
    """
    reports = dict(reports or {})
    lo_rate, hi_rate = SERVING_RATES[0], SERVING_RATES[-1]
    for point in ((lo_rate, "fifo"), (hi_rate, "fifo"), (hi_rate, "defer")):
        if point not in reports:
            reports[point] = serving_report(*point)
    knee = (reports[(hi_rate, "fifo")].ttft_p99
            / reports[(lo_rate, "fifo")].ttft_p99)
    gain = (reports[(hi_rate, "defer")].goodput
            / reports[(hi_rate, "fifo")].goodput)
    return [
        Claim("serving_ttft_knee", 8.5, knee, 4.0, 15.0,
              "p99 TTFT degradation, FIFO, 3000 vs 250 req/s (model-derived "
              "saturation knee under composed contention)"),
        Claim("serving_admission_gain", 1.75, gain, 1.2, 2.4,
              "goodput of defer-admission over FIFO at 3000 req/s "
              "(model-derived contention-aware admission win)"),
    ]


# ----------------------------------------------------------------------- #
# Fault-injection & degraded-mode claims (DESIGN.md §13)                  #
# ----------------------------------------------------------------------- #

#: Canonical straggler scenario of the fault claims: device 0's engines
#: stream 4x slower (DESIGN.md §13).
FAULT_SLOWDOWN = 4.0

#: Size band of the graceful-degradation claim — bandwidth-bound pipelined
#: shapes where a straggler's slowdown lands on shard-time-scale stalls.
FAULT_SIZES = (8 * MB, 16 * MB, 32 * MB)

#: Pipeline depth of the fault claims (the sweep ceiling, DESIGN.md §9).
FAULT_DEPTH = 4

#: Drop rate of the bounded-retry-overhead claim: small enough that the
#: watchdog recovers every loss within ``max_attempts``, large enough that
#: an 8MB depth-4 run sees retries at all.
FAULT_DROP_RATE = 0.005


def fault_degradation_arms(topo: Topology | None = None, *,
                           slowdown: float = FAULT_SLOWDOWN,
                           sizes: tuple[int, ...] = FAULT_SIZES,
                           depth: int = FAULT_DEPTH) -> dict[int, dict[str, float]]:
    """Per-size latencies of the graceful-degradation comparison (§13):
    the SAME ``pipe_b2b`` AG queues under per-chunk vs final-chunk-only
    signaling, each run clean and under the canonical straggler.  Returns
    ``{size: {"pipe_clean", "pipe_faulted", "fco_clean", "fco_faulted"}}``
    — the benchmark passes this to :func:`fault_degradation_claims` so the
    eight simulations per size run once."""
    from .faults import straggler_plan

    topo = topo or tpu_v5e_pod(16)
    plan = straggler_plan(0, slowdown)
    arms: dict[int, dict[str, float]] = {}
    for size in sizes:
        per_chunk = C.allgather_schedule(topo, size, "pipe_b2b",
                                         pipe_depth=depth)
        final_only = C.allgather_schedule(topo, size, "pipe_b2b",
                                          pipe_depth=depth,
                                          per_chunk_signaling=False)
        arms[size] = {
            "pipe_clean": simulate(per_chunk, topo).latency,
            "pipe_faulted": simulate(per_chunk, topo, faults=plan).latency,
            "fco_clean": simulate(final_only, topo).latency,
            "fco_faulted": simulate(final_only, topo, faults=plan).latency,
        }
    return arms


def fault_degradation_claims(topo: Topology | None = None,
                             arms: dict | None = None) -> list[Claim]:
    """Claim bands for graceful degradation under a straggler (§13).

    * ``fault_pipe_grace`` — relative degradation of final-chunk-only over
      per-chunk signaling: ``(fco_faulted/fco_clean) /
      (pipe_faulted/pipe_clean)``, geomean over the size band.  >1 means
      per-chunk signaling degrades more gracefully — downstream devices
      keep consuming the straggler's early chunks while it grinds through
      the rest, where final-chunk-only waiters stall for the whole slowed
      shard.
    * ``fault_pipe_gap`` — the absolute faulted-latency gap
      ``fco_faulted / pipe_faulted``: under the straggler the per-chunk
      arm's win widens beyond its clean-run advantage.

    No paper counterpart (the paper measures healthy hardware); the bands
    pin the model's §13 behavior against regressions.
    """
    if arms is None:
        arms = fault_degradation_arms(topo)
    grace = geomean((a["fco_faulted"] / a["fco_clean"])
                    / (a["pipe_faulted"] / a["pipe_clean"])
                    for a in arms.values())
    gap = geomean(a["fco_faulted"] / a["pipe_faulted"] for a in arms.values())
    return [
        Claim("fault_pipe_grace", 1.03, grace, 1.005, 1.08,
              "relative straggler degradation, final-chunk-only over "
              "per-chunk signaling, pipe_b2b AG 8-32MB depth 4, TPU torus "
              "(DESIGN.md §13 — per-chunk degrades more gracefully)"),
        Claim("fault_pipe_gap", 1.08, gap, 1.02, 1.18,
              "faulted-latency gap, final-chunk-only over per-chunk "
              "signaling under a 4x straggler, pipe_b2b AG 8-32MB depth 4"),
    ]


def fault_retry_claims(topo: Topology | None = None, *,
                       size: int = 8 * MB,
                       drop_rate: float = FAULT_DROP_RATE,
                       seed: int = 0) -> list[Claim]:
    """Claim bands for watchdog/retry recovery (§13.2).

    * ``fault_retry_overhead`` — latency of an 8MB depth-4 ``pipe_b2b`` AG
      under a small random signal-drop rate over its clean run: every
      dropped doorbell costs roughly one watchdog expiry plus a re-issued
      command, so at ``drop_rate`` 0.5% the overhead is bounded well under
      the ~2x a 2% rate produces — losses are recovered, not amplified.
    * ``fault_retry_recovery`` — fraction of dropped raises the watchdog
      recovered (re-raise survived its own draw) within ``max_attempts``;
      at small drop rates this is 1.0 (re-drawing the same tag at the next
      attempt index decorrelates the loss).
    """
    from .faults import FaultPlan

    topo = topo or tpu_v5e_pod(16)
    sched = C.allgather_schedule(topo, size, "pipe_b2b",
                                 pipe_depth=FAULT_DEPTH)
    clean = simulate(sched, topo)
    faulted = simulate(sched, topo,
                       faults=FaultPlan(drop_rate=drop_rate, seed=seed))
    rep = faulted.fault_report
    overhead = faulted.latency / clean.latency
    recovery = rep.recovered / len(rep.dropped) if rep.dropped else 1.0
    return [
        Claim("fault_retry_overhead", 1.22, overhead, 1.0, 1.6,
              "latency overhead of 0.5% signal-drop rate on pipe_b2b AG "
              "8MB depth 4, TPU torus (DESIGN.md §13.2 — bounded retry "
              "cost at small drop rates)"),
        Claim("fault_retry_recovery", 1.0, recovery, 0.99, 1.0,
              "fraction of dropped signals recovered by the watchdog "
              "within max_attempts at 0.5% drop rate"),
    ]


#: Offered load of the serving fault claims: the unloaded low end of
#: ``SERVING_RATES``, so tail movement is attributable to the injected
#: fault rather than to the §12 saturation knee.
SERVING_FAULT_RATE = SERVING_RATES[0]


def serving_outage_plan(rate: float = SERVING_FAULT_RATE):
    """The canonical transient-outage scenario of the §13.4 serving claims:
    device 0's h2d host link derated to 5% of nominal for the first quarter
    of the workload's arrival span (window ends computed from the workload,
    not hardcoded — the span scales with ``rate``)."""
    from .faults import FaultPlan, LinkDerate

    reqs = serving_workload(rate)
    span = max(r.arrival for r in reqs)
    return FaultPlan(link_derates=(
        LinkDerate("hostlink:0:h2d", 0.05, 0.0, 0.25 * span),))


def serving_fault_report(rate: float, admission: str, faults=None):
    """One point of the degraded-mode serving comparison: the canonical
    workload through the §12 loop under ``admission``, with ``faults``
    threaded into every composed round (DESIGN.md §13.4)."""
    from repro.serve.engine import ServingConfig, ServingSimulator

    sim = ServingSimulator(ServingConfig(admission=admission), faults=faults)
    return sim.run(serving_workload(rate))


def serving_fault_claims(reports=None) -> list[Claim]:
    """Claim bands for degraded-mode serving (DESIGN.md §13.4).

    * ``serving_fault_tail`` — a permanent 4x straggler on device 0
      inflates unloaded-fleet p99 TTFT modestly (~1.2x): requests homed on
      the straggler fetch slower, everyone else is untouched.  The defer
      policy deliberately does NOT steer around it (KV homes are pinned —
      deferring would starve those requests), so FIFO is the right arm.
    * ``serving_outage_defer_gain`` — under a transient host-link outage
      (5% bandwidth for the first quarter of the trace), fault-aware defer
      admission pushes launches past the window instead of fetching at 5%
      rate, recovering ~1.5x p99 TTFT over FIFO.

    ``reports`` optionally supplies precomputed ``{arm: ServingReport}``
    points keyed by ``("clean"|"straggler"|"outage", admission)`` — the
    benchmark passes its table so the four runs are not simulated twice.
    Model-derived; no paper counterpart.
    """
    from .faults import straggler_plan

    rate = SERVING_FAULT_RATE
    reports = dict(reports or {})
    plans = {"clean": None,
             "straggler": straggler_plan(0, FAULT_SLOWDOWN),
             "outage": serving_outage_plan(rate)}
    for arm in (("clean", "fifo"), ("straggler", "fifo"),
                ("outage", "fifo"), ("outage", "defer")):
        if arm not in reports:
            reports[arm] = serving_fault_report(rate, arm[1], plans[arm[0]])
    tail = (reports[("straggler", "fifo")].ttft_p99
            / reports[("clean", "fifo")].ttft_p99)
    defer_gain = (reports[("outage", "fifo")].ttft_p99
                  / reports[("outage", "defer")].ttft_p99)
    return [
        Claim("serving_fault_tail", 1.18, tail, 1.05, 1.6,
              "p99 TTFT inflation of a 4x straggler on one device, FIFO "
              "admission, 250 req/s (DESIGN.md §13.4)"),
        Claim("serving_outage_defer_gain", 1.49, defer_gain, 1.15, 2.2,
              "p99 TTFT gain of fault-aware defer over FIFO under a "
              "transient host-link outage, 250 req/s (DESIGN.md §13.4)"),
    ]
