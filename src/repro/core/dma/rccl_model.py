"""CU-driven (RCCL) collective latency + kernel-copy model — the baseline.

The paper compares DMA collectives against RCCL (tuned: MSCCL/MSCCL++ and
hipGraphs enabled).  We model RCCL latency as a launch floor plus wire time
at a size-dependent protocol efficiency (LL -> LL128 -> Simple ramp), capped
below the DMA link efficiency because CU protocols carry per-packet metadata
(flags/sequence numbers) — which is exactly why the paper's pcpy beats RCCL
by 14–18% at bandwidth-bound sizes (§5.2.4).
"""
from __future__ import annotations

from .topology import RcclCalibration, Topology


def rccl_efficiency(shard: float, calib: RcclCalibration) -> float:
    return calib.wire_efficiency_max * shard / (shard + calib.half_size)


def rccl_collective_latency(
    topo: Topology,
    size: int,
    calib: RcclCalibration | None = None,
) -> float:
    """Latency of a CU-based all-gather/all-to-all of total ``size`` bytes.

    Both collectives move (n-1)/n of ``size`` in/out of every device over
    n-1 links simultaneously (fully-connected one-shot algorithm).
    """
    calib = calib or RcclCalibration()
    n = topo.n_devices
    shard = size / n
    wire_bytes = shard * (n - 1)
    eff = max(rccl_efficiency(shard, calib), 1e-3)
    wire = wire_bytes / (topo.aggregate_bw * eff)
    return max(calib.min_latency, calib.base_launch + wire)


def kernel_copy_latency(
    topo: Topology,
    total_bytes: int,
    *,
    n_launches: int = 1,
    contention_slowdown: float = 1.0,
    calib: RcclCalibration | None = None,
) -> float:
    """CU (load/store kernel) host<->device copy, e.g. kernel-based KV fetch.

    One kernel gathers all dispersed blocks (one workgroup per block), so a
    single launch; wire time over the host link at CU efficiency.  When the
    fetch overlaps model compute, ``contention_slowdown`` models CU/cache
    contention (§2.4 / §5.3.3) — the reason DMA fetch wins on throughput.
    """
    calib = calib or RcclCalibration()
    eff = 0.80
    wire = total_bytes / (topo.host_link_bw * eff)
    return (calib.base_launch * n_launches + wire) * contention_slowdown
