"""Optimized DMA command streams — composable schedule transforms (paper §6).

The paper's baseline DMA collectives lose the latency-bound range to command
scheduling and synchronization overheads (Fig. 7): every command costs a host
scheduling event, every engine runs one queue, and every transfer trails a
standalone signal command.  This module models the paper's three
optimizations as *pure transforms* over a built
:class:`~repro.core.dma.commands.Schedule` — each one rewrites the command
stream to relieve a specific contended resource of the event simulator, and
they compose (DESIGN.md §7):

* :func:`batch_commands` — batched doorbell/command scheduling (§7.1):
  relieves the **host CPU** timeline.
* :func:`split_queues` — SDMA queue-level parallelism (§7.2): relieves the
  **engine front end** (issue/decode) while streaming bandwidth stays
  contended.
* :func:`fuse_signals` — fused write+signal (§7.3): relieves the **engine
  scheduling round-trip** (one fewer command packet per step, ``sync_engine``
  becomes the posted-write delay ``fused_sync``).

:func:`optimize` applies all three in the canonical order (split, then fuse,
then batch).  The collective builders expose the result as ``opt_``-prefixed
variants (``opt_pcpy``, ``opt_prelaunch_b2b``, ...) so dispatch sweeps and
claims can compare baseline and optimized streams point-by-point.  Builders
chunk oversized copies (DESIGN.md §8.1) *before* these transforms run, so
batching amortizes per-chunk packet creation and fusion lands on the final
chunk — this is where the paper's large-size ~7% gain comes from.

Per-chunk signaling interaction (DESIGN.md §9): fusion operates at chunk
granularity.  A stream that signals after *every* chunk (``copy, signal(t0),
copy, signal(t1), ...``) fuses each semaphore onto its own chunk — exactly
the per-chunk-tagged commands the pipelined ring builders emit directly
(:func:`repro.core.dma.commands.chunked_copies`), which is asserted
bit-identical in ``tests/test_sim.py``.  On an already per-chunk-fused
``pipe_`` schedule the transforms compose conservatively: queues carrying
fused chunk tags or waits are never split across SDMA slots (the chunk
order *is* the dependency order), fusion only absorbs the trailing host
completion, and batching amortizes the per-chunk packet creation — the
``opt_pipe_*`` variants owe most of their mid-size win to §7.1 batching of
the per-chunk/per-wait control stream.  Reduce-scatter streams (DESIGN.md
§10) follow the same rule: a queue interleaving ``reduce_tag`` commands
with its forwarded copies is never slot-split (the reduction of chunk
``i`` must precede the copy that forwards it), fusion leaves reductions
alone (their raise tags are set by the builders), and batching amortizes
the reduce/copy packet stream like any other.

Transforms never change *what* is transferred: byte counts, sources and
destinations are preserved exactly (asserted in ``tests/test_sim.py``), only
the scheduling/synchronization envelope changes.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from . import commands as cmd
from .commands import CmdKind, Command, DATA_KINDS, EngineQueue, Schedule

#: Variant-name prefix that requests :func:`optimize` on top of a base
#: variant, e.g. ``"opt_pcpy"`` or ``"opt_prelaunch_b2b"``.
OPT_PREFIX = "opt_"


@dataclasses.dataclass(frozen=True)
class OptimizationConfig:
    """Knobs of the optimized command stream (DESIGN.md §7).

    ``batch``: commands created/submitted per host scheduling event (§7.1).
    ``queues_per_engine``: SDMA queue slots a single engine's command stream
    may be spread over (§7.2).  ``split_min_commands``: queues shorter than
    this are not split — per-slot decode overlap only beats the extra
    doorbells and completion fences when the front end is the bottleneck,
    i.e. for long issue-bound command streams (the empirical-threshold shape
    of the §5.3.1 KV-fetch fanout, but on command count: payload streaming
    hides the front end for big commands regardless of how many slots run).
    ``split_max_bytes`` is the payload side of the same gate: a queue whose
    data commands exceed it streams for far longer than a command decodes,
    so the front end is already hidden and splitting would only multiply
    doorbells and completion fences — chunked GB-scale streams (DESIGN.md
    §8.1, 1-4MB per command) therefore stay on one slot.
    ``fuse``: fuse trailing signals into their data command (§7.3).
    """

    batch: int = 8
    queues_per_engine: int = 4
    split_min_commands: int = 8
    split_max_bytes: int = 256 * 1024
    fuse: bool = True

    def __post_init__(self) -> None:
        if self.batch < 1:
            raise ValueError("batch must be >= 1")
        if self.queues_per_engine < 1:
            raise ValueError("queues_per_engine must be >= 1")


DEFAULT_CONFIG = OptimizationConfig()


def parse_optimized(variant: str) -> tuple[str, bool]:
    """Split an ``opt_``-prefixed variant name (DESIGN.md §7).

    ``"opt_prelaunch_b2b"`` -> ``("prelaunch_b2b", True)``;
    ``"pcpy"`` -> ``("pcpy", False)``.
    """
    if variant.startswith(OPT_PREFIX):
        return variant[len(OPT_PREFIX):], True
    return variant, False


# ------------------------------------------------------------------ §7.1 ----

def batch_commands(schedule: Schedule, batch: int = DEFAULT_CONFIG.batch) -> Schedule:
    """Batched doorbell/command scheduling (DESIGN.md §7.1).

    The host creates and submits ``batch`` commands per scheduling event
    instead of one: the first command of each event pays the full
    ``Calibration.control``, the rest the amortized ``control_batched``, and
    the doorbells of consecutively submitted queues ring back-to-back
    (``doorbell_batched``).  This relieves the serial host-CPU timeline — the
    dominant cost of latency-bound collectives (Fig. 7).

    Prelaunched queues are left untouched: their control/schedule work is
    already off the critical path (§4.5), so there is nothing to amortize.
    """
    if batch < 1:
        raise ValueError("batch must be >= 1")
    queues = tuple(
        q if q.prelaunched or q.batch == batch
        else dataclasses.replace(q, batch=batch)
        for q in schedule.queues)
    return dataclasses.replace(schedule, queues=queues)


# ------------------------------------------------------------------ §7.2 ----

def _splittable(q: EngineQueue, min_commands: int, max_bytes: int) -> bool:
    """A queue is eligible for multi-queue dispatch when it is an independent
    run of data commands (+ trailing untagged completion signals): no
    cross-device ordering (``wait``/tagged ``signal``), not poll-gated,
    long enough for per-slot decode overlap to pay for the extra doorbells
    and completion fences, and issue-bound (small payloads — large commands
    stream long enough to hide the front end on one slot)."""
    if q.prelaunched or q.slot != 0:
        return False
    data = q.data_commands
    if len(data) < max(2, min_commands):
        return False
    if any(c.size > max_bytes for c in data):
        return False
    seen_signal = False
    for c in q.commands:
        if c.kind in (CmdKind.WAIT, CmdKind.POLL, CmdKind.REDUCE,
                      CmdKind.COMPUTE):
            # Reductions order-depend on their interleaved copies: the
            # reduced partial must be forwarded by the NEXT data command,
            # so a reduce stream never slot-splits across the chunk
            # boundary (DESIGN.md §10).  Compute tiles occupy the CU, not
            # an SDMA slot — slot-splitting them is meaningless (§15).
            return False
        if c.kind is CmdKind.SIGNAL:
            if c.tag is not None:
                return False
            seen_signal = True
        elif seen_signal:      # interleaved copy/signal stream: keep as-is
            return False
        if c.fused_tag is not None or c.fused_signal:
            # Already-fused queues are left alone: splitting would add a
            # standalone completion per slot ON TOP of the fused one,
            # inflating the sync phase.  Canonical order is split -> fuse.
            return False
    return True


def split_queues(
    schedule: Schedule,
    queues_per_engine: int = DEFAULT_CONFIG.queues_per_engine,
    *,
    min_commands: int = DEFAULT_CONFIG.split_min_commands,
    max_bytes: int = DEFAULT_CONFIG.split_max_bytes,
) -> Schedule:
    """SDMA queue-level parallelism (DESIGN.md §7.2).

    Spread an engine's data commands round-robin over up to
    ``queues_per_engine`` queue *slots* of the **same** engine.  Each slot
    has its own front end — doorbell, fetch, per-command decode
    (``copy_setup``) — so issue overlaps across slots, while every slot
    still streams through the one shared ``engine:<dev>.<e>`` resource: the
    engine's aggregate bandwidth is never exceeded (asserted in
    ``tests/test_sim.py``).

    Each resulting slot completes independently, so each carries its own
    trailing completion signal when the original queue signaled the host —
    multi-queue dispatch *multiplies* completion signals and doorbells, a
    real cost the dispatch argmin weighs against the front-end overlap (and
    why ``min_commands``/``max_bytes`` gate the transform).  Queues with
    cross-device ordering (``wait``/tagged signals), poll-gated queues,
    queues shorter than ``min_commands`` data commands, and queues carrying
    commands above ``max_bytes`` (stream-bound: the front end is already
    hidden, DESIGN.md §8.1) are left untouched.
    """
    if queues_per_engine < 1:
        raise ValueError("queues_per_engine must be >= 1")
    if queues_per_engine == 1:
        return schedule
    by_hw: dict[tuple, int] = defaultdict(int)
    for q in schedule.queues:
        by_hw[(q.device, q.engine)] += 1

    out: list[EngineQueue] = []
    for q in schedule.queues:
        if by_hw[(q.device, q.engine)] != 1 or not _splittable(q, min_commands, max_bytes):
            out.append(q)
            continue
        data = q.data_commands
        signaled = q.n_signals > 0
        n_slots = min(queues_per_engine, len(data))
        for s in range(n_slots):
            slot_cmds: tuple[Command, ...] = tuple(data[s::n_slots])
            if signaled:
                slot_cmds = slot_cmds + (cmd.signal(),)
            out.append(dataclasses.replace(q, commands=slot_cmds, slot=s))
    return dataclasses.replace(schedule, queues=tuple(out))


# ------------------------------------------------------------------ §7.3 ----

def _fuse_queue(q: EngineQueue) -> EngineQueue:
    fused: list[Command] = []
    for c in q.commands:
        prev = fused[-1] if fused else None
        if c.kind is CmdKind.SIGNAL and prev is not None and prev.kind in DATA_KINDS:
            if c.tag is not None and prev.fused_tag is None:
                fused[-1] = dataclasses.replace(prev, fused_tag=c.tag)
                continue
            if c.tag is None and not prev.fused_signal:
                fused[-1] = dataclasses.replace(prev, fused_signal=True)
                continue
        fused.append(c)
    return dataclasses.replace(q, commands=tuple(fused))


def fuse_signals(schedule: Schedule) -> Schedule:
    """Fused write+signal (DESIGN.md §7.3).

    Collapse every ``signal`` that directly trails a data command into that
    command: the signal payload rides the transfer's final write packet.
    This removes one host scheduling event (one command packet) per step and
    replaces the engine's ``sync_engine`` scheduling round-trip with the
    posted-write delay ``fused_sync``.  Fused *tagged* signals raise their
    semaphore at write completion — ring steps chain without an extra engine
    round.  Fused *untagged* (host-observed) signals still cost the host one
    ``sync_obs`` each; only the engine side gets cheaper.

    Fusion is chunk-granular (DESIGN.md §9): in a chunked stream each
    signal fuses onto the chunk command directly before it, so a
    per-chunk-signaled stream (``copy, signal(tag+chunk), ...``) fuses into
    exactly the per-chunk-tagged commands the pipelined ring builders emit.
    A data command that already carries a fused tag keeps it — a following
    *tagged* signal then stays standalone; a following untagged completion
    still fuses (the two ride different fields of the final write packet).

    Signals that do not directly follow a data command (e.g. the standalone
    completion signal of a wait-only queue) are kept as-is.  The transform is
    idempotent.
    """
    return dataclasses.replace(
        schedule, queues=tuple(_fuse_queue(q) for q in schedule.queues))


# ------------------------------------------------------------- composition ----

def optimize(schedule: Schedule, config: OptimizationConfig | None = None) -> Schedule:
    """Apply the full optimized command stream (DESIGN.md §7).

    Canonical composition order: :func:`split_queues` first (slots must exist
    before their trailing signals can fuse), then :func:`fuse_signals`, then
    :func:`batch_commands`.  The result keeps the schedule's name and its
    ``symmetric`` marking — all three transforms rewrite every device
    identically and never move traffic onto a different directed link, so a
    symmetric schedule stays symmetric (asserted bit-identical in
    ``tests/test_sim.py``).
    """
    cfg = config or DEFAULT_CONFIG
    out = split_queues(schedule, cfg.queues_per_engine,
                       min_commands=cfg.split_min_commands,
                       max_bytes=cfg.split_max_bytes)
    if cfg.fuse:
        out = fuse_signals(out)
    return batch_commands(out, cfg.batch)
