"""Platform topology + calibration constants for the DMA engine model.

Two topologies are modeled:

* ``mi300x_platform()`` — the paper's system (§2.2, Fig. 4): 8 AMD Instinct
  MI300X GPUs, fully connected with xGMI links of 64 GB/s per direction
  (128 GB/s bidirectional), 16 sDMA engines per GPU, PCIe Gen5 host links
  (64 GB/s per direction).

* ``tpu_v5e_pod()`` — the lowering target of the rest of this repo: a 2D ICI
  torus with ~50 GB/s links, used to re-derive the size-dispatch thresholds
  for the TPU-native collectives (DESIGN.md §4).

Routing (DESIGN.md §3): a topology exposes ``route(src, dst)`` returning the
directed links a transfer traverses.  The fully-connected MI300X box routes
everything over the single direct xGMI link; the TPU torus routes
dimension-ordered (rows first, then columns) with wraparound, so non-neighbor
transfers are multi-hop and the simulator charges every link on the path plus
a per-hop router latency (``Calibration.hop_latency``).

Inter-node tier (DESIGN.md §11): multi-node topologies (``n_nodes > 1``)
split the device range into equal nodes.  Intra-node routing is unchanged
(per-node torus or fully-connected box); a cross-node transfer traverses the
*sender's NIC* — one serial injection resource per device
(``nic:{src}``) with its own latency (``Calibration.nic_latency``) and
bandwidth (``Calibration.nic_bytes_per_s``), both far worse than the
intra-node DMA links.  The NIC is deliberately sender-side only: a shared
receiver-side resource would put two devices on one timeline and break the
translation invariance the symmetric fast path (§6) relies on.  The
per-hop view the simulator consumes is :meth:`Topology.wire_path`.

* ``tpu_v5e_multislice()`` / ``mi300x_cluster()`` — the multi-node builders:
  N×(4×4 ICI torus) slices over DCN, and N×8-GPU MI300X boxes over RDMA.

Phase constants live in :class:`Calibration` and are fit once (see
``benchmarks/calibration.py``) so that the model reproduces the paper's
measured figures.
"""
from __future__ import annotations

import dataclasses
import functools


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-phase latency constants (seconds) of a single DMA offload (Fig. 6/7).

    control  : CPU command-packet creation, per command.
    control_batched: marginal packet-creation cost for the 2nd..Nth command of
               one batched submission event (DESIGN.md §7.1): the descriptor
               template, queue pointers and cache lines are already hot, so
               only the per-command payload fields are written.
    doorbell : CPU MMIO doorbell write, per engine (serialized on the CPU).
    doorbell_batched: marginal doorbell cost for the 2nd..Nth hardware queue
               rung within one batched submission event (§7.1) — the MMIO
               writes are posted back-to-back without an intervening
               scheduling round-trip.
    fetch    : engine wake + command fetch from the system-memory queue.
    copy_setup: per data-command decode + address translation on the engine.
    b2b_issue: incremental issue cost of an overlapped back-to-back copy
               (subsequent loads issued before prior stores complete, §4.4).
    sync_engine: engine-side atomic signal update.
    fused_sync: latency of a *fused* write+signal (DESIGN.md §7.3): the
               signal payload rides the final write packet of the transfer,
               so only the fabric's posted-write completion delay remains
               instead of a full engine scheduling round (``sync_engine``).
    sync_obs : CPU-side completion observation, per signal (serialized).
    sync_obs_batched: marginal observation cost for the 2nd..Nth *fused*
               completion of one device (§7.3): fused signals write adjacent
               slots of a contiguous completion record, so the host's drain
               loop sweeps them in one pass instead of polling scattered
               per-queue signal addresses.
    poll_trigger: latency from the triggering memory write until a polling
               engine observes it (prelaunch, §4.5); also the latency for a
               remote engine to observe a tagged semaphore signal (wait).
    hop_latency: per-router forwarding latency charged for every hop beyond
               the first on a multi-hop route (0 on fully-connected fabrics).
    max_chunk_bytes: largest payload one sDMA command can carry
               (DESIGN.md §8.1).  The runtime splits bigger copies into
               bounded-size chunk commands, each paying its own packet
               creation (host) and issue (engine front end); the MI300X
               value is the sDMA linear-copy packet ceiling (22-bit byte
               count, ~4MB).  ``0`` disables chunking.
    reduce_setup: constant per-chunk reduction launch latency (DESIGN.md
               §10): dispatching the accumulate over an arrived chunk on
               the consumer (descriptor + address setup on MI300X, vector
               loop launch on the TPU scalar core).
    reduce_bytes_per_s: consumer-side reduction throughput (DESIGN.md §10).
               The accumulate streams both operands from local HBM and
               writes the partial back, so it runs at roughly a third of
               HBM bandwidth — far above link bandwidth on both platforms,
               which is why per-chunk reductions hide under the wire once
               the pipeline is primed.
    nic_latency: one-way injection latency of a cross-node message through
               the sender's NIC (DESIGN.md §11) — RDMA/DCN software + fabric
               latency, orders of magnitude above the intra-node hop cost.
               Unused on single-node topologies.
    nic_bytes_per_s: per-device NIC injection bandwidth (one direction).
               The MI300X default models a 400G RDMA NIC (~50 GB/s); the TPU
               multislice builder overrides it with a DCN-class value.  The
               NIC serializes a device's cross-node traffic regardless of
               how many intra-node DMA links it owns.
    cu_tile_setup: per-tile launch overhead on the compute-unit timeline
               (DESIGN.md §15): wavefront dispatch + LDS staging for one
               output tile of a fused compute-collective schedule.
    cu_flops : aggregate per-device matrix throughput (FLOP/s) pricing
               ``compute`` commands on the ``cu:{dev}`` timeline
               (DESIGN.md §15).  The MI300X default is the peak bf16
               roofline; the v5e builder overrides it with the TPU value.
    """

    # Values fit by benchmarks/calibration.py so the model lands on the
    # paper's measured claims.
    control: float = 0.5987e-6
    control_batched: float = 0.1497e-6
    doorbell: float = 2.436e-6
    doorbell_batched: float = 0.406e-6
    fetch: float = 0.5014e-6
    copy_setup: float = 3.146e-6
    b2b_issue: float = 0.2919e-6
    sync_engine: float = 0.9165e-6
    fused_sync: float = 0.1833e-6
    sync_obs: float = 1.596e-6
    sync_obs_batched: float = 1.041e-6
    poll_trigger: float = 0.5838e-6
    hop_latency: float = 0.0
    max_chunk_bytes: int = 4 * 1024 * 1024
    # Per-chunk reduction cost on the consumer (DESIGN.md §10): MI300X
    # accumulates at ~1/3 of HBM3 bandwidth (read chunk + read/write acc).
    reduce_setup: float = 0.45e-6
    reduce_bytes_per_s: float = 1.6e12
    # Inter-node NIC tier (DESIGN.md §11): 400G RDMA-class defaults; only
    # consulted when ``Topology.n_nodes > 1``.
    nic_latency: float = 2.0e-6
    nic_bytes_per_s: float = 50e9
    # Effective per-engine streaming bandwidth (one engine saturates roughly
    # one xGMI link; pcpy engages one engine per link).
    engine_bw: float = 64e9
    # DMA transfers carry less metadata than CU-based protocols -> higher
    # achievable link efficiency (paper §5.2.4: pcpy beats RCCL by 14-18%
    # at bandwidth-bound sizes).
    dma_link_efficiency: float = 0.9616
    # Compute-unit timeline (DESIGN.md §15): one GEMM tile occupies the
    # ``cu:{dev}`` resource for ``cu_tile_setup + flops / cu_flops``.
    # MI300X peak bf16 matrix throughput; tile setup ~= a persistent
    # kernel's workgroup grabbing the next tile off its work queue (NOT a
    # kernel launch — the fused builders stream tiles from one kernel).
    cu_tile_setup: float = 0.2e-6
    cu_flops: float = 1.3e15

    def __post_init__(self) -> None:
        # A mistyped calibration (negative latency, zero bandwidth) times as
        # silent nonsense — instant transfers, negative phases — so reject it
        # at construction.  Latency constants may be 0 (hop_latency is, on
        # fully-connected fabrics); divisors must be strictly positive.
        for f in ("control", "control_batched", "doorbell", "doorbell_batched",
                  "fetch", "copy_setup", "b2b_issue", "sync_engine",
                  "fused_sync", "sync_obs", "sync_obs_batched", "poll_trigger",
                  "hop_latency", "reduce_setup", "nic_latency",
                  "cu_tile_setup"):
            v = getattr(self, f)
            if not v >= 0.0:
                raise ValueError(f"Calibration.{f} must be >= 0, got {v}")
        for f in ("engine_bw", "nic_bytes_per_s", "reduce_bytes_per_s",
                  "cu_flops"):
            v = getattr(self, f)
            if not v > 0.0:
                raise ValueError(f"Calibration.{f} must be > 0, got {v}")
        if not 0.0 < self.dma_link_efficiency <= 1.0:
            raise ValueError(
                "Calibration.dma_link_efficiency must be in (0, 1], got "
                f"{self.dma_link_efficiency}")
        if self.max_chunk_bytes < 0:
            raise ValueError(
                "Calibration.max_chunk_bytes must be >= 0 (0 disables "
                f"chunking), got {self.max_chunk_bytes}")


@dataclasses.dataclass(frozen=True)
class RcclCalibration:
    """CU-driven collective (RCCL) latency model, tuned per paper's baseline.

    latency = base_launch + size-dependent protocol overhead + wire time at
    an efficiency that ramps with message size (LL -> LL128 -> Simple).
    """

    base_launch: float = 4.506e-6      # kernel launch + graph-amortized setup
    wire_efficiency_max: float = 0.7851  # CU protocol metadata caps efficiency
    # Efficiency half-point: eff(size) = max_eff * size/(size + half_size),
    # per destination-shard size.
    half_size: float = 1.038e5
    min_latency: float = 4.771e-6      # floor for tiny collectives


# All-to-all is harder for CU-based libraries (no ring reuse; per-peer
# staging): the paper's RCCL AA baseline sits ~2.1x above its AG baseline at
# latency-bound sizes, which is why pcpy's AA gap (2.5x) is smaller than its
# AG gap (4.5x).
RCCL_AA_SCALE = 2.103


def rccl_ag_calibration() -> "RcclCalibration":
    return RcclCalibration()


def rccl_aa_calibration() -> "RcclCalibration":
    b = RcclCalibration()
    return RcclCalibration(
        base_launch=b.base_launch * RCCL_AA_SCALE,
        wire_efficiency_max=b.wire_efficiency_max,
        half_size=b.half_size,
        min_latency=b.min_latency * RCCL_AA_SCALE,
    )


@dataclasses.dataclass(frozen=True)
class PowerCalibration:
    """Component power (Watts) for the Fig. 15 reproduction.

    MI300X OAM is a ~750W part.  We model GPU power as
    idle + XCD (compute dies) + IOD (infinity cache/links/DMA) + HBM, with
    activity factors depending on who executes the collective.
    """

    idle: float = 140.0
    xcd_cu_collective: float = 300.0   # CUs spinning on copies (BW-bound)
    xcd_dma_collective: float = 80.0   # paper: ~3.7x less XCD power
    xcd_latency_scale: float = 0.35    # CU stress lower at latency-bound sizes
    iod_per_engine: float = 2.5        # per active DMA engine
    iod_cu: float = 55.0
    hbm_per_gbps: float = 0.12         # HBM power tracks streamed traffic
    hbm_static: float = 60.0
    cu_traffic_multiplier: float = 1.6  # CU protocol staging vs pure payload
    link_per_busy_gbps: float = 0.04   # per-link power tracks actual busy traffic
    # Host/sync energy (DESIGN.md §8.4): every host scheduling event (command
    # creation pass, doorbell ring, completion observation) wakes a CPU core
    # for a few microseconds; every standalone engine signal is an atomic
    # round-trip over the fabric.  Batched submission and fused write+signal
    # (§7.1/§7.3) remove most of both — the paper's 3-10% *additional* power
    # saving for optimized streams.
    host_wakeup_j: float = 4.5e-5      # J per host scheduling event
    atomic_j: float = 6.0e-6           # J per engine atomic signal round-trip


# ---------------------------------------------------------------- routing ----

def _torus_axis_hops(a: int, b: int, n: int) -> list[int]:
    """Signed unit steps (+1/-1) to travel a->b on a ring of size n, shortest way."""
    fwd = (b - a) % n
    bwd = (a - b) % n
    if fwd == 0:
        return []
    return [1] * fwd if fwd <= bwd else [-1] * bwd


@functools.lru_cache(maxsize=4096)
def _torus_route(grid: tuple[int, int], src: int, dst: int) -> tuple[tuple[int, int], ...]:
    """Dimension-ordered (row-first) shortest route on a 2D torus."""
    rows, cols = grid
    r, c = divmod(src, cols)
    rd, cd = divmod(dst, cols)
    hops: list[tuple[int, int]] = []
    cur = src
    for step in _torus_axis_hops(c, cd, cols):        # row links first
        nxt_c = (cur % cols + step) % cols
        nxt = (cur // cols) * cols + nxt_c
        hops.append((cur, nxt))
        cur = nxt
    for step in _torus_axis_hops(r, rd, rows):        # then column links
        nxt = ((cur // cols + step) % rows) * cols + cur % cols
        hops.append((cur, nxt))
        cur = nxt
    return tuple(hops)


@functools.lru_cache(maxsize=64)
def _snake_ring(grid: tuple[int, int]) -> tuple[int, ...]:
    """A Hamiltonian ring over the torus: boustrophedon rows; the wraparound
    column link closes last->first (requires an even number of rows, which
    every supported pod shape satisfies)."""
    rows, cols = grid
    order: list[int] = []
    for r in range(rows):
        cs = range(cols) if r % 2 == 0 else range(cols - 1, -1, -1)
        order.extend(r * cols + c for c in cs)
    return tuple(order)


@dataclasses.dataclass(frozen=True)
class Topology:
    name: str
    n_devices: int
    link_bw: float                     # bytes/s, per direction, per link
    links_per_device: int              # simultaneously usable peer links
    n_engines: int                     # DMA engines per device
    host_link_bw: float                # bytes/s per direction (PCIe for MI300X)
    fully_connected: bool
    calib: Calibration = Calibration()
    grid: tuple[int, int] | None = None  # per-node 2D torus (rows, cols) if not FC
    n_nodes: int = 1                     # inter-node tier (DESIGN.md §11)

    def __post_init__(self) -> None:
        if self.n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {self.n_devices}")
        if not self.link_bw > 0.0:
            raise ValueError(f"link_bw must be > 0, got {self.link_bw}")
        if not self.host_link_bw > 0.0:
            raise ValueError(
                f"host_link_bw must be > 0, got {self.host_link_bw}")
        if self.links_per_device < 1 or self.n_engines < 1:
            raise ValueError(
                f"links_per_device/n_engines must be >= 1, got "
                f"{self.links_per_device}/{self.n_engines}")
        if self.n_nodes < 1 or self.n_devices % self.n_nodes:
            raise ValueError(
                f"n_nodes ({self.n_nodes}) must divide n_devices "
                f"({self.n_devices})")

    def peer_links(self, device: int) -> int:
        return self.links_per_device

    @property
    def aggregate_bw(self) -> float:
        """Total per-device injection bandwidth (bytes/s, one direction)."""
        return self.link_bw * self.links_per_device

    # ---- node structure (DESIGN.md §11) ----
    @property
    def node_devices(self) -> int:
        """Devices per node (the device range splits into equal nodes)."""
        return self.n_devices // self.n_nodes

    def node_of(self, device: int) -> int:
        return device // self.node_devices

    def local_rank(self, device: int) -> int:
        return device % self.node_devices

    def node_base(self, node: int) -> int:
        return node * self.node_devices

    # ---- routing (DESIGN.md §3, §11) ----
    def route(self, src: int, dst: int) -> tuple[tuple[int, int], ...]:
        """Directed links a src->dst transfer traverses, in traversal order.

        A cross-node transfer is one logical hop — the sender's NIC
        (DESIGN.md §11); :meth:`wire_path` maps it onto the ``nic:{src}``
        resource.  Intra-node routing is per-node: the torus runs over
        local ranks, offset back to global device ids.
        """
        if src == dst:
            return ()
        if self.n_nodes > 1 and self.node_of(src) != self.node_of(dst):
            return ((src, dst),)
        if self.fully_connected or self.grid is None:
            return ((src, dst),)
        base = self.node_base(self.node_of(src))
        local = _torus_route(self.grid, src - base, dst - base)
        if base:
            return tuple((a + base, b + base) for a, b in local)
        return local

    def hops(self, src: int, dst: int) -> int:
        return len(self.route(src, dst))

    def wire_path(self, src: int, dst: int) -> tuple[tuple[tuple[str, float], ...], float]:
        """Per-hop ``(timeline key, added latency)`` pairs + path bandwidth.

        The simulator's view of a route (DESIGN.md §11): intra-node hops run
        over directed DMA links (``link:{a}>{b}``) at the effective link
        bandwidth, the first hop adding no latency and each further hop the
        router's ``hop_latency`` (cut-through).  A cross-node transfer is a
        single hop through the sender's NIC (``nic:{src}``) at NIC bandwidth,
        charged ``nic_latency`` up front.
        """
        c = self.calib
        if self.n_nodes > 1 and self.node_of(src) != self.node_of(dst):
            return ((f"nic:{src}", c.nic_latency),), c.nic_bytes_per_s
        hop = c.hop_latency
        path = tuple(
            (f"link:{a}>{b}", 0.0 if h == 0 else hop)
            for h, (a, b) in enumerate(self.route(src, dst)))
        return path, self.link_bw * c.dma_link_efficiency

    def neighbors(self, device: int) -> tuple[int, ...]:
        """Directly linked peers — intra-node only (the NIC is not a link)."""
        if self.fully_connected or self.grid is None:
            base = self.node_base(self.node_of(device))
            return tuple(d for d in range(base, base + self.node_devices)
                         if d != device)
        base = self.node_base(self.node_of(device))
        rows, cols = self.grid
        r, c = divmod(device - base, cols)
        out = []
        for dr, dc in ((0, 1), (0, -1), (1, 0), (-1, 0)):
            n = base + ((r + dr) % rows) * cols + (c + dc) % cols
            if n != device and n not in out:
                out.append(n)
        return tuple(out)

    def is_neighbor(self, a: int, b: int) -> bool:
        if self.n_nodes > 1 and self.node_of(a) != self.node_of(b):
            return False
        return a != b and len(self.route(a, b)) == 1

    def ring_order(self) -> tuple[int, ...]:
        """A device order in which consecutive (and wraparound) devices are
        physically adjacent — the embedding used by ring collectives.  On a
        multi-node topology the order is node-major (each node's local ring
        concatenated), so consecutive devices are adjacent *within* a node;
        the node boundaries are NIC hops and flat rings over them fail the
        builders' adjacency check (they fall back to the full event loop)."""
        if self.fully_connected or self.grid is None:
            return tuple(range(self.n_devices))
        local = _snake_ring(self.grid)
        if self.n_nodes == 1:
            return local
        return tuple(self.node_base(n) + d
                     for n in range(self.n_nodes) for d in local)

    def node_ring_order(self, node: int) -> tuple[int, ...]:
        """The intra-node ring (global device ids) for one node."""
        base = self.node_base(node)
        if self.fully_connected or self.grid is None:
            return tuple(range(base, base + self.node_devices))
        return tuple(base + d for d in _snake_ring(self.grid))


def mi300x_platform(calib: Calibration | None = None) -> Topology:
    return Topology(
        name="mi300x-8",
        n_devices=8,
        link_bw=64e9,
        links_per_device=7,
        n_engines=16,
        host_link_bw=64e9,
        fully_connected=True,
        calib=calib or Calibration(),
    )


def _near_square_grid(n: int) -> tuple[int, int]:
    """Factor n into the most square (rows, cols) with rows even when possible
    (an even row count closes the snake ring over the column wraparound)."""
    best = (1, n)
    for r in range(1, int(n ** 0.5) + 1):
        if n % r == 0:
            best = (r, n // r)
    r, c = best
    if r % 2 and c % 2 == 0:   # prefer the even side as rows
        r, c = c, r
    return (r, c)


def tpu_v5e_pod(n_devices: int = 256, calib: Calibration | None = None) -> Topology:
    """TPU v5e slice: 2D torus, 4 ICI ports/chip, ~50 GB/s per link/direction.

    Used for re-deriving latte dispatch thresholds on the TPU target.  Command
    issue constants are re-interpreted as scalar-core DMA-descriptor issue
    latencies inside a Pallas kernel (DESIGN.md §4); they are much smaller
    than host-driven doorbells.
    """
    c = calib or Calibration(
        control=0.05e-6,
        control_batched=0.0125e-6,  # descriptor template reuse on-chip
        doorbell=0.0,          # no host doorbell: descriptors issue on-chip
        doorbell_batched=0.0,
        fetch=0.10e-6,
        copy_setup=0.80e-6,    # DMA descriptor + route setup
        b2b_issue=0.05e-6,
        sync_engine=0.40e-6,   # semaphore signal
        fused_sync=0.08e-6,    # semaphore rides the final write packet
        sync_obs=0.20e-6,      # semaphore wait observe
        sync_obs_batched=0.05e-6,
        poll_trigger=0.20e-6,
        hop_latency=0.40e-6,   # ICI router forward per extra hop
        reduce_setup=0.12e-6,  # vector accumulate launch on the scalar core
        reduce_bytes_per_s=260e9,   # ~1/3 of the v5e HBM bandwidth (819 GB/s)
        engine_bw=50e9,
        dma_link_efficiency=0.95,
        cu_tile_setup=0.05e-6,  # MXU tile grab from the resident loop
        cu_flops=197e12,        # TPU_V5E_PEAK_BF16_FLOPS
    )
    return Topology(
        name=f"tpu-v5e-{n_devices}",
        n_devices=n_devices,
        link_bw=50e9,
        links_per_device=4,
        n_engines=8,
        host_link_bw=32e9,
        fully_connected=False,
        calib=c,
        grid=_near_square_grid(n_devices),
    )


def tpu_v5e_multislice(n_devices: int = 64, node_devices: int = 16,
                       calib: Calibration | None = None) -> Topology:
    """Multi-node TPU v5e: ``n_devices / node_devices`` ICI-torus slices
    joined over DCN (DESIGN.md §11).

    Each node is a ``node_devices``-chip 2D ICI torus (the same fabric as
    :func:`tpu_v5e_pod`); cross-node traffic serializes through the sender's
    DCN NIC at ~12.5 GB/s with ~5 µs injection latency — a 4× bandwidth and
    ~12× latency step down from an ICI link, which is what makes the
    hierarchical collective builders win (``collectives.py`` ``hier_``).
    """
    if n_devices % node_devices:
        raise ValueError(
            f"n_devices={n_devices} not divisible by node_devices={node_devices}")
    base = tpu_v5e_pod(node_devices)
    c = calib or dataclasses.replace(
        base.calib,
        nic_latency=5.0e-6,        # DCN injection (software + fabric)
        nic_bytes_per_s=12.5e9,    # ~100G DCN per chip
    )
    return Topology(
        name=f"tpu-v5e-{n_devices}x{node_devices}",
        n_devices=n_devices,
        link_bw=base.link_bw,
        links_per_device=base.links_per_device,
        n_engines=base.n_engines,
        host_link_bw=base.host_link_bw,
        fully_connected=False,
        calib=c,
        grid=_near_square_grid(node_devices),
        n_nodes=n_devices // node_devices,
    )


def mi300x_cluster(n_nodes: int = 2, calib: Calibration | None = None) -> Topology:
    """N fully-connected 8-GPU MI300X boxes joined over RDMA (DESIGN.md §11).

    Intra-node routing is the single direct xGMI link exactly as on
    :func:`mi300x_platform`; cross-node transfers serialize through the
    sender's 400G NIC (``Calibration.nic_latency`` / ``nic_bytes_per_s``
    defaults).  ``fully_connected`` is False because the *global* fabric is
    not — same-node pairs still route direct (``grid is None``).
    """
    if n_nodes < 1:
        raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
    return Topology(
        name=f"mi300x-8x{n_nodes}",
        n_devices=8 * n_nodes,
        link_bw=64e9,
        links_per_device=7,
        n_engines=16,
        host_link_bw=64e9,
        fully_connected=False,
        calib=calib or Calibration(),
        grid=None,
        n_nodes=n_nodes,
    )


# TPU v5e roofline constants (system prompt / public spec).
TPU_V5E_PEAK_BF16_FLOPS = 197e12
TPU_V5E_HBM_BW = 819e9
TPU_V5E_ICI_BW_PER_LINK = 50e9
