"""Platform topology + calibration constants for the DMA engine model.

Two topologies are modeled:

* ``mi300x_platform()`` — the paper's system (§2.2, Fig. 4): 8 AMD Instinct
  MI300X GPUs, fully connected with xGMI links of 64 GB/s per direction
  (128 GB/s bidirectional), 16 sDMA engines per GPU, PCIe Gen5 host links
  (64 GB/s per direction).

* ``tpu_v5e_pod()`` — the lowering target of the rest of this repo: a 2D ICI
  torus with ~50 GB/s links, used to re-derive the size-dispatch thresholds
  for the TPU-native collectives (DESIGN.md §4).

Phase constants live in :class:`Calibration` and are fit once (see
``benchmarks/calibration.py`` and EXPERIMENTS.md) so that the model reproduces
the paper's measured figures.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-phase latency constants (seconds) of a single DMA offload (Fig. 6/7).

    control  : CPU command-packet creation, per command.
    doorbell : CPU MMIO doorbell write, per engine (serialized on the CPU).
    fetch    : engine wake + command fetch from the system-memory queue.
    copy_setup: per data-command decode + address translation on the engine.
    b2b_issue: incremental issue cost of an overlapped back-to-back copy
               (subsequent loads issued before prior stores complete, §4.4).
    sync_engine: engine-side atomic signal update.
    sync_obs : CPU-side completion observation, per signal (serialized).
    poll_trigger: latency from the triggering memory write until a polling
               engine observes it (prelaunch, §4.5).
    """

    # Values fit by benchmarks/calibration.py so the model lands on the
    # paper's measured claims (see EXPERIMENTS.md §Calibration).
    control: float = 0.5987e-6
    doorbell: float = 2.436e-6
    fetch: float = 0.5014e-6
    copy_setup: float = 3.146e-6
    b2b_issue: float = 0.2919e-6
    sync_engine: float = 0.9165e-6
    sync_obs: float = 1.596e-6
    poll_trigger: float = 0.5838e-6
    # Effective per-engine streaming bandwidth (one engine saturates roughly
    # one xGMI link; pcpy engages one engine per link).
    engine_bw: float = 64e9
    # DMA transfers carry less metadata than CU-based protocols -> higher
    # achievable link efficiency (paper §5.2.4: pcpy beats RCCL by 14-18%
    # at bandwidth-bound sizes).
    dma_link_efficiency: float = 0.9616


@dataclasses.dataclass(frozen=True)
class RcclCalibration:
    """CU-driven collective (RCCL) latency model, tuned per paper's baseline.

    latency = base_launch + size-dependent protocol overhead + wire time at
    an efficiency that ramps with message size (LL -> LL128 -> Simple).
    """

    base_launch: float = 4.506e-6      # kernel launch + graph-amortized setup
    wire_efficiency_max: float = 0.7851  # CU protocol metadata caps efficiency
    # Efficiency half-point: eff(size) = max_eff * size/(size + half_size),
    # per destination-shard size.
    half_size: float = 1.038e5
    min_latency: float = 4.771e-6      # floor for tiny collectives


# All-to-all is harder for CU-based libraries (no ring reuse; per-peer
# staging): the paper's RCCL AA baseline sits ~2.1x above its AG baseline at
# latency-bound sizes, which is why pcpy's AA gap (2.5x) is smaller than its
# AG gap (4.5x).
RCCL_AA_SCALE = 2.103


def rccl_ag_calibration() -> "RcclCalibration":
    return RcclCalibration()


def rccl_aa_calibration() -> "RcclCalibration":
    b = RcclCalibration()
    return RcclCalibration(
        base_launch=b.base_launch * RCCL_AA_SCALE,
        wire_efficiency_max=b.wire_efficiency_max,
        half_size=b.half_size,
        min_latency=b.min_latency * RCCL_AA_SCALE,
    )


@dataclasses.dataclass(frozen=True)
class PowerCalibration:
    """Component power (Watts) for the Fig. 15 reproduction.

    MI300X OAM is a ~750W part.  We model GPU power as
    idle + XCD (compute dies) + IOD (infinity cache/links/DMA) + HBM, with
    activity factors depending on who executes the collective.
    """

    idle: float = 140.0
    xcd_cu_collective: float = 300.0   # CUs spinning on copies (BW-bound)
    xcd_dma_collective: float = 80.0   # paper: ~3.7x less XCD power
    xcd_latency_scale: float = 0.35    # CU stress lower at latency-bound sizes
    iod_per_engine: float = 2.5        # per active DMA engine
    iod_cu: float = 55.0
    hbm_per_gbps: float = 0.12         # HBM power tracks streamed traffic
    hbm_static: float = 60.0
    cu_traffic_multiplier: float = 1.6  # CU protocol staging vs pure payload


@dataclasses.dataclass(frozen=True)
class Topology:
    name: str
    n_devices: int
    link_bw: float                     # bytes/s, per direction, per link
    links_per_device: int              # simultaneously usable peer links
    n_engines: int                     # DMA engines per device
    host_link_bw: float                # bytes/s per direction (PCIe for MI300X)
    fully_connected: bool
    calib: Calibration = Calibration()

    def peer_links(self, device: int) -> int:
        return self.links_per_device

    @property
    def aggregate_bw(self) -> float:
        """Total per-device injection bandwidth (bytes/s, one direction)."""
        return self.link_bw * self.links_per_device


def mi300x_platform(calib: Calibration | None = None) -> Topology:
    return Topology(
        name="mi300x-8",
        n_devices=8,
        link_bw=64e9,
        links_per_device=7,
        n_engines=16,
        host_link_bw=64e9,
        fully_connected=True,
        calib=calib or Calibration(),
    )


def tpu_v5e_pod(n_devices: int = 256, calib: Calibration | None = None) -> Topology:
    """TPU v5e slice: 2D torus, 4 ICI ports/chip, ~50 GB/s per link/direction.

    Used for re-deriving latte dispatch thresholds on the TPU target.  Command
    issue constants are re-interpreted as scalar-core DMA-descriptor issue
    latencies inside a Pallas kernel (DESIGN.md §4); they are much smaller
    than host-driven doorbells.
    """
    c = calib or Calibration(
        control=0.05e-6,
        doorbell=0.0,          # no host doorbell: descriptors issue on-chip
        fetch=0.10e-6,
        copy_setup=0.80e-6,    # DMA descriptor + route setup
        b2b_issue=0.05e-6,
        sync_engine=0.40e-6,   # semaphore signal
        sync_obs=0.20e-6,      # semaphore wait observe
        poll_trigger=0.20e-6,
        engine_bw=50e9,
        dma_link_efficiency=0.95,
    )
    return Topology(
        name=f"tpu-v5e-{n_devices}",
        n_devices=n_devices,
        link_bw=50e9,
        links_per_device=4,
        n_engines=8,
        host_link_bw=32e9,
        fully_connected=False,
        calib=c,
    )


# TPU v5e roofline constants (system prompt / public spec).
TPU_V5E_PEAK_BF16_FLOPS = 197e12
TPU_V5E_HBM_BW = 819e9
TPU_V5E_ICI_BW_PER_LINK = 50e9
