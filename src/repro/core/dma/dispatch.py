"""Size-range dispatch policy — the paper's Tables 2 & 3, plus a derived
policy that re-discovers the thresholds from the timing model (used both to
validate the model against the paper and to re-derive thresholds for the TPU
topology used by the JAX-level latte collectives, DESIGN.md §4/§5).

Optimized command streams (DESIGN.md §7): passing ``allow_optimized=True``
to :func:`candidate_variants` / :func:`derive_dispatch` adds the ``opt_``
variants (batched submission + SDMA queue slots + fused write+signal) to the
argmin, re-deriving the thresholds with the optimization layer available —
the baseline-vs-optimized sweep behind ``benchmarks/fig13*/fig14*
--optimized``.  The default sweeps stay baseline-only so the paper's
Tables 2/3 structure remains reproducible as published.

Chunked command streams (DESIGN.md §8): every schedule is built with the
topology's calibrated ``max_chunk_bytes`` by default, and the sweep can
additionally treat the chunk granularity as a policy dimension —
``derive_dispatch(..., chunk_sizes=...)`` runs the argmin over
(variant, chunk) pairs and records the winning chunk size per range.

Pipelined ring collectives (DESIGN.md §9): ``allow_pipelined=True`` adds the
per-chunk-signaled ``pipe_`` family (``pipe_b2b``, ``pipe_bidir_ring`` and
their ``prelaunch_``/``opt_`` compositions) to the argmin on neighbor-link
topologies — the sweep behind ``benchmarks/fig13*/fig14* --pipelined`` and
the v4 bundled TPU tables.

Reduce collectives (DESIGN.md §10): ``allow_reduce=True`` unlocks the
``reduce_scatter`` / ``all_reduce`` collectives — the ring reduce family
(``ring_rs``, ``bidir_ring_rs``; with ``allow_pipelined`` also the
per-chunk ``pipe_ring_rs`` / ``pipe_bidir_ring_rs``) on every topology (the
ring embedding is the only modeled reduce schedule shape, so unlike the
``pipe_`` all-gather family it is offered on fully-connected fabrics too).
The explicit opt-in keeps pre-§10 sweeps byte-identical and makes an
accidental ``reduce_scatter`` request against an old call site fail loudly
instead of silently sweeping an empty candidate set.

Fused compute-collective overlap (DESIGN.md §15): ``allow_fused=True``
unlocks the ``fused_gemm_rs`` / ``fused_ag_gemm`` pseudo-collectives — the
argmin sweeps overlap depth (``d2/d4/d8``) x reduction placement
(``cu``/``engine``, GEMM+reduce-scatter only) against the sequential
GEMM-then-collective baseline (``seq``).  Like ``allow_reduce`` the opt-in
keeps earlier sweeps byte-identical; the fused builders have no
hierarchical multi-node rendering and raise on ``n_nodes > 1``.

Hierarchical multi-node collectives (DESIGN.md §11): on a multi-node
topology (``topo.n_nodes > 1``) the candidate set is the ``hier_`` family —
intra-node ring tier composed with an inter-node NIC tier, the only modeled
schedule shape that keeps per-device work translation-invariant across the
node boundary.  ``all_to_all`` has no hierarchical rendering and raises.

Simulation results are memoized: :func:`variant_latency` caches every
(topology, collective, size, variant, chunk) point and
:func:`derive_dispatch` caches whole argmin sweeps, so repeated claim
evaluations and dispatch-table derivations in one process pay for each
simulation once.  Sweeps run on the vectorized fast path (DESIGN.md
§11.3): symmetric candidates evaluate over the whole size grid with
representative-only builds (:mod:`repro.core.dma.sweep`), bit-identical to
the per-point ``simulate()`` loop, which is what makes the 64/256-device
multi-node tables derivable inside CI budgets.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import numpy as np

from .collectives import (FUSED_AG_VARIANTS, FUSED_RS_VARIANTS,
                          allgather_schedule, allreduce_schedule,
                          alltoall_schedule, fused_ag_gemm_schedule,
                          fused_gemm_rs_schedule, reduce_scatter_schedule)
from .engine import simulate
from .faults import straggler_plan
from .sweep import argmin_grid, sweep_variant_latencies
from .topology import Topology

#: Schedule builder per collective name (the dispatch/claims vocabulary).
COLLECTIVE_BUILDERS = {
    "all_gather": allgather_schedule,
    "all_to_all": alltoall_schedule,
    "reduce_scatter": reduce_scatter_schedule,
    "all_reduce": allreduce_schedule,
    "fused_gemm_rs": fused_gemm_rs_schedule,
    "fused_ag_gemm": fused_ag_gemm_schedule,
}

#: The fused pseudo-collectives (DESIGN.md §15) — gated by ``allow_fused``.
FUSED_COLLECTIVES = ("fused_gemm_rs", "fused_ag_gemm")

KB = 1024
MB = 1024 * 1024
GB = 1024 * 1024 * 1024

# Paper Table 2 — best implementation per all-gather size range.
PAPER_AG_DISPATCH: tuple[tuple[int, int | None, str], ...] = (
    (1 * KB, 256 * KB, "prelaunch_b2b"),
    (256 * KB, 1 * MB, "prelaunch_bcst"),
    (1 * MB, 512 * MB, "prelaunch_pcpy"),
    (512 * MB, None, "pcpy"),
)

# Paper Table 3 — best implementation per all-to-all size range.
PAPER_AA_DISPATCH: tuple[tuple[int, int | None, str], ...] = (
    (1 * KB, 64 * KB, "prelaunch_b2b"),
    (64 * KB, 4 * MB, "prelaunch_swap"),
    (4 * MB, 1 * GB, "prelaunch_pcpy"),
    (1 * GB, None, "pcpy"),
)


def paper_dispatch(collective: str, size: int) -> str:
    table = PAPER_AG_DISPATCH if collective == "all_gather" else PAPER_AA_DISPATCH
    for lo, hi, variant in table:
        if size >= lo and (hi is None or size < hi):
            return variant
    return table[0][2] if size < table[0][0] else table[-1][2]


@dataclasses.dataclass(frozen=True)
class DispatchEntry:
    lo: int
    hi: int | None
    variant: str
    # Winning sDMA chunk granularity for the range (DESIGN.md §8.1);
    # None = the topology's calibrated default max_chunk_bytes.
    chunk: int | None = None


def variant_latency(topo: Topology, collective: str, size: int, variant: str,
                    chunk_bytes: int | None = None) -> float:
    """Memoized latency of one (collective, size, variant, chunk) point.

    ``chunk_bytes=None`` uses the topology's calibrated ``max_chunk_bytes``
    (schedules are always chunked, DESIGN.md §8.1); an explicit value
    overrides the chunk granularity and is part of the memo key.  The thin
    wrapper normalizes the default so 4-arg callers (claims) and explicit
    ``chunk_bytes=None`` callers (sweeps) share one cache entry.
    """
    return _variant_latency_cached(topo, collective, size, variant, chunk_bytes)


@functools.lru_cache(maxsize=65536)
def _variant_latency_cached(topo: Topology, collective: str, size: int,
                            variant: str, chunk_bytes: int | None) -> float:
    builder: Callable = COLLECTIVE_BUILDERS[collective]
    return simulate(builder(topo, size, variant, max_chunk_bytes=chunk_bytes),
                    topo).latency


def candidate_variants(
    topo: Topology,
    collective: str,
    *,
    allow_prelaunch: bool = True,
    allow_optimized: bool = False,
    allow_pipelined: bool = False,
    allow_reduce: bool = False,
    allow_fused: bool = False,
) -> list[str]:
    """Variants an argmin sweep should consider on this topology.

    ``allow_optimized`` additionally offers every candidate with the
    optimized command-stream transforms applied (``opt_`` prefix,
    DESIGN.md §7).  ``allow_pipelined`` adds the per-chunk-signaled
    pipelined rings (``pipe_`` family, DESIGN.md §9) on neighbor-link
    topologies — like the chained rings they only make sense where the
    torus embedding is the native route, so fully-connected fabrics skip
    them.  ``allow_reduce`` unlocks the ``reduce_scatter`` / ``all_reduce``
    collectives (ring reduce family, DESIGN.md §10; offered on every
    topology — the ring embedding is the only modeled reduce shape, and
    ``allow_pipelined`` adds the per-chunk ``pipe_*_rs`` renderings).
    Prefixes compose: with all flags set the sweep also offers
    ``prelaunch_pipe_*`` and ``opt_[prelaunch_]pipe_*``.

    Multi-node topologies (``topo.n_nodes > 1``, DESIGN.md §11) sweep the
    hierarchical family instead: ``hier_ring`` (+ ``hier_pipe`` under
    ``allow_pipelined``) for all-gather, ``hier_ring_rs`` (+
    ``hier_pipe_rs``) for the reduce collectives.  The flat variants still
    *build* on multi-node topologies (the claims compare against them) but
    are excluded from the sweep: none are translation invariant across the
    node boundary, so every flat candidate would force the full
    multi-device event loop — unaffordable at 64/256 devices — and their
    NIC traffic scales with total device count instead of node count (the
    flat ring loses outright, ``hier_ag_nic_gain``; the direct fan-outs
    stay competitive at 2 nodes in the model but saturate the NIC at the
    slice counts the tables target).  ``all_to_all`` has no hierarchical
    rendering (every pair exchanges distinct data, so there is no
    intra/inter decomposition that reduces NIC bytes) and raises.

    ``allow_fused`` unlocks the fused compute-collective pseudo-collectives
    (``fused_gemm_rs`` / ``fused_ag_gemm``, DESIGN.md §15): the candidate
    set is the overlap-depth x reduction-placement grid plus the ``seq``
    control arm.  They are ring renderings, so — like the reduce family —
    they are offered on every single-node topology, but have no
    hierarchical multi-node shape and raise on ``n_nodes > 1``.
    """
    if collective in FUSED_COLLECTIVES:
        if not allow_fused:
            raise ValueError(
                f"collective {collective!r} needs allow_fused=True "
                "(DESIGN.md §15)")
        if topo.n_nodes > 1:
            raise ValueError(
                "the fused compute-collective builders have no "
                "hierarchical multi-node rendering (DESIGN.md §15); "
                "derive fused tables on single-node topologies only")
        variants = list(FUSED_RS_VARIANTS if collective == "fused_gemm_rs"
                        else FUSED_AG_VARIANTS)
        if allow_prelaunch:
            variants += [f"prelaunch_{v}" for v in list(variants)]
        if allow_optimized:
            variants += [f"opt_{v}" for v in list(variants)]
        return variants
    if topo.n_nodes > 1:
        if collective == "all_to_all":
            raise ValueError(
                "all_to_all has no hierarchical multi-node rendering "
                "(DESIGN.md §11); derive multi-node tables for "
                "all_gather/reduce_scatter/all_reduce only")
        if collective in ("reduce_scatter", "all_reduce"):
            if not allow_reduce:
                raise ValueError(
                    f"collective {collective!r} needs allow_reduce=True "
                    "(DESIGN.md §10)")
            variants = ["hier_ring_rs"]
            if allow_pipelined:
                variants.append("hier_pipe_rs")
        else:
            variants = ["hier_ring"]
            if allow_pipelined:
                variants.append("hier_pipe")
        if allow_prelaunch:
            variants += [f"prelaunch_{v}" for v in list(variants)]
        if allow_optimized:
            variants += [f"opt_{v}" for v in list(variants)]
        return variants
    if collective in ("reduce_scatter", "all_reduce"):
        if not allow_reduce:
            raise ValueError(
                f"collective {collective!r} needs allow_reduce=True "
                "(DESIGN.md §10)")
        variants = ["ring_rs", "bidir_ring_rs"]
        if allow_pipelined:
            variants += ["pipe_ring_rs", "pipe_bidir_ring_rs"]
    else:
        variants = ["pcpy", "b2b", "bcst" if collective == "all_gather" else "swap"]
        if not topo.fully_connected:
            variants.append("ring")
            if collective == "all_gather":
                variants.append("bidir_ring")
            if allow_pipelined:
                variants.append("pipe_b2b")
                if collective == "all_gather":
                    variants.append("pipe_bidir_ring")
    if allow_prelaunch:
        variants += [f"prelaunch_{v}" for v in list(variants)]
    if allow_optimized:
        variants += [f"opt_{v}" for v in list(variants)]
    return variants


def optimized_variants(topo: Topology, collective: str) -> list[str]:
    """The ``opt_`` candidate set alone (DESIGN.md §7) — what the optimized
    claim bands and the ``--optimized`` benchmark curves sweep over."""
    return [v for v in candidate_variants(topo, collective, allow_optimized=True)
            if v.startswith("opt_")]


def pipelined_variants(topo: Topology, collective: str) -> list[str]:
    """The ``pipe_`` candidate set alone (DESIGN.md §9) — every pipelined
    ring rendering including its ``prelaunch_``/``opt_`` compositions; what
    the pipelined claim bands and ``--pipelined`` benchmark curves sweep."""
    return [v for v in candidate_variants(topo, collective, allow_optimized=True,
                                          allow_pipelined=True,
                                          allow_reduce=True)
            if "pipe_" in v]


def reduce_variants(topo: Topology, collective: str = "reduce_scatter") -> list[str]:
    """The full reduce candidate set (DESIGN.md §10): the ring reduce
    family with every ``prelaunch_``/``opt_``/``pipe_`` composition — what
    the §10 claim bands and ``benchmarks/fig_allreduce.py`` sweep."""
    return candidate_variants(topo, collective, allow_optimized=True,
                              allow_pipelined=True, allow_reduce=True)


def fused_variants(topo: Topology, collective: str = "fused_gemm_rs") -> list[str]:
    """The bare fused candidate set (DESIGN.md §15): overlap depth x
    reduction placement plus the ``seq`` control arm, without the
    ``prelaunch_``/``opt_`` compositions — what the §15 claim bands and
    ``benchmarks/fig_fused_overlap.py`` sweep."""
    return candidate_variants(topo, collective, allow_prelaunch=False,
                              allow_fused=True)


def sweep_candidate_latencies(topo: Topology, collective: str,
                              sizes: tuple[int, ...], variant: str,
                              chunk_bytes: int | None) -> list[float]:
    """One (variant, chunk) candidate's latency over the whole size grid.

    Symmetric candidates take the vectorized fast path (representative-only
    builds + single-device event loop, DESIGN.md §11.3); everything else
    falls back to the memoized per-point ``simulate()`` loop.  Either way
    the values are bit-identical to calling :func:`variant_latency` per
    size — asserted over every bundled table entry in tests/test_hier.py —
    so callers never need to know which path ran.
    """
    fast = sweep_variant_latencies(topo, collective, tuple(sizes), variant,
                                   chunk_bytes)
    if fast is not None:
        return fast
    return [variant_latency(topo, collective, size, variant, chunk_bytes)
            for size in sizes]


@functools.lru_cache(maxsize=256)
def _derive_dispatch_cached(
    topo: Topology,
    collective: str,
    sizes: tuple[int, ...],
    allow_prelaunch: bool,
    allow_optimized: bool,
    chunk_sizes: tuple[int | None, ...],
    allow_pipelined: bool = False,
    allow_reduce: bool = False,
    allow_fused: bool = False,
) -> tuple[DispatchEntry, ...]:
    variants = candidate_variants(topo, collective, allow_prelaunch=allow_prelaunch,
                                  allow_optimized=allow_optimized,
                                  allow_pipelined=allow_pipelined,
                                  allow_reduce=allow_reduce,
                                  allow_fused=allow_fused)

    # Candidate axis in the historical sweep order (variant-major, the
    # calibrated default chunk first) so the vectorized argmin's earlier-
    # candidate tie-breaking reproduces the per-point loop exactly.
    candidates = [(v, ch) for v in variants for ch in chunk_sizes]
    lat = [sweep_candidate_latencies(topo, collective, sizes, v, ch)
           for v, ch in candidates]
    # Strict-improvement-with-tolerance argmin, one numpy pass per
    # candidate over the size axis (DESIGN.md §11.3): prelaunched variants
    # are chunk-flat (the per-chunk host cost is off the critical path), so
    # without the epsilon the chunk winner would be picked on float noise
    # and churn the derived ranges.  Earlier candidates (the calibrated
    # default chunk first) win ties.
    best_i, _ = argmin_grid(lat)
    winners = [(size, *candidates[i]) for size, i in zip(sizes, best_i)]

    entries: list[DispatchEntry] = []
    for size, v, ch in winners:
        if entries and entries[-1].variant == v and entries[-1].chunk == ch:
            entries[-1] = DispatchEntry(entries[-1].lo, None, v, ch)
        else:
            if entries:
                entries[-1] = dataclasses.replace(entries[-1], hi=size)
            entries.append(DispatchEntry(size, None, v, ch))
    return tuple(entries)


def derive_dispatch(
    topo: Topology,
    collective: str,
    sizes: list[int],
    *,
    allow_prelaunch: bool = True,
    allow_optimized: bool = False,
    allow_pipelined: bool = False,
    allow_reduce: bool = False,
    allow_fused: bool = False,
    chunk_sizes=None,
) -> list[DispatchEntry]:
    """Re-derive the best variant per size from the timing model (argmin).

    Adjacent sizes with the same winner are merged into ranges, which should
    approximately reproduce Tables 2/3 on the MI300X topology (validated in
    tests/benchmarks) and gives the policy for the TPU topology.  With
    ``allow_optimized`` the sweep also offers the ``opt_`` command streams
    (DESIGN.md §7); ``allow_pipelined`` adds the per-chunk-signaled
    pipelined rings (DESIGN.md §9) on neighbor-link topologies.
    ``chunk_sizes`` adds the sDMA chunk granularity as a policy dimension
    (DESIGN.md §8.1): the argmin runs over (variant, chunk) pairs and each
    entry records its winning ``chunk`` (``None`` = the topology's
    calibrated default; for ``pipe_`` variants the chunk granularity also
    bounds the pipeline depth).  ``allow_reduce`` unlocks the
    ``reduce_scatter``/``all_reduce`` collectives (DESIGN.md §10) and
    ``allow_fused`` the fused compute-collective pseudo-collectives
    (DESIGN.md §15).  Sweeps are memoized per (topology, collective,
    sizes, allow_prelaunch, allow_optimized, allow_pipelined,
    allow_reduce, allow_fused, chunk_sizes).
    """
    chunks = (None,) if chunk_sizes is None else tuple(chunk_sizes)
    return list(_derive_dispatch_cached(topo, collective, tuple(sizes),
                                        allow_prelaunch, allow_optimized,
                                        chunks, allow_pipelined, allow_reduce,
                                        allow_fused))


def best_variant_for(topo: Topology, collective: str, size: int,
                     variants) -> tuple[str, float]:
    """Argmin over an explicit variant list at one size (memoized points)."""
    best, best_t = None, float("inf")
    for v in variants:
        t = variant_latency(topo, collective, size, v)
        if t < best_t:
            best, best_t = v, t
    return best, best_t


def pick_variant(entries: list[DispatchEntry], size: int) -> str:
    for e in entries:
        if size >= e.lo and (e.hi is None or size < e.hi):
            return e.variant
    return entries[-1].variant if size >= entries[-1].lo else entries[0].variant


# ---------------------------------------------------------------------------
# Dispatch robustness (DESIGN.md §13.5): which bundled-table winners survive
# calibration drift and straggler engines?
# ---------------------------------------------------------------------------

#: Named calibration perturbations (field -> multiplicative scale).  The
#: scales bracket realistic drift: host-side costs vary with CPU load and
#: kernel version (+50%), link efficiency with cable/firmware degradation
#: (-20%), engine bandwidth with thermal throttling (-30%).  All scales keep
#: every Calibration field inside its validated domain.
PERTURB_SCENARIOS: tuple[tuple[str, dict[str, float]], ...] = (
    ("control+50%", {"control": 1.5, "control_batched": 1.5}),
    ("doorbell+50%", {"doorbell": 1.5, "doorbell_batched": 1.5}),
    ("sync+50%", {"sync_engine": 1.5, "fused_sync": 1.5,
                  "sync_obs": 1.5, "sync_obs_batched": 1.5}),
    ("link_eff-20%", {"dma_link_efficiency": 0.8}),
    ("engine_bw-30%", {"engine_bw": 0.7}),
)


def perturbed_topology(topo: Topology, scales: dict[str, float]) -> Topology:
    """``topo`` with each named Calibration field scaled multiplicatively.

    The perturbed topology is a distinct frozen value, so the
    :func:`variant_latency` memo and the sweep fast path treat it as a
    fresh calibration — no cache invalidation needed."""
    calib = dataclasses.replace(
        topo.calib,
        **{f: getattr(topo.calib, f) * s for f, s in scales.items()})
    return dataclasses.replace(topo, calib=calib)


@dataclasses.dataclass(frozen=True)
class FragileEntry:
    """One (size, scenario) point whose dispatch winner flipped.

    ``regret`` is what shipping the base winner costs under the scenario:
    base winner's latency there / the scenario's best latency (>= 1; 1.0
    means the flip is a tie and the table entry is effectively robust)."""

    size: int
    scenario: str
    base_variant: str
    new_variant: str
    regret: float


@dataclasses.dataclass(frozen=True)
class RobustnessReport:
    """Winner-stability audit of one dispatch sweep (DESIGN.md §13.5)."""

    collective: str
    scenarios: tuple[str, ...]
    n_points: int                        # len(sizes) x len(scenarios)
    fragile: tuple[FragileEntry, ...]    # sorted by (size, scenario)

    @property
    def n_fragile(self) -> int:
        return len(self.fragile)

    @property
    def fragile_fraction(self) -> float:
        return self.n_fragile / self.n_points if self.n_points else 0.0

    @property
    def max_regret(self) -> float:
        return max((f.regret for f in self.fragile), default=1.0)


def dispatch_robustness(
    topo: Topology,
    collective: str,
    sizes: list[int],
    *,
    allow_prelaunch: bool = True,
    allow_optimized: bool = False,
    allow_pipelined: bool = False,
    allow_reduce: bool = False,
    chunk_bytes: int | None = None,
    scenarios: tuple[tuple[str, dict[str, float]], ...] = PERTURB_SCENARIOS,
    straggler_slowdown: float | None = 4.0,
    variants: list[str] | None = None,
) -> RobustnessReport:
    """Re-run winner selection under perturbed calibrations and a straggler
    scenario; flag fragile entries whose winners flip (DESIGN.md §13.5).

    The base sweep is the same (variants x sizes) argmin
    :func:`derive_dispatch` runs.  Each named calibration scenario rebuilds
    the latency matrix on a :func:`perturbed_topology` (vectorized fast path
    where symmetric); ``straggler_slowdown`` adds a full-event-loop scenario
    (``straggler_x<s>``) where device 0's engines stream that much slower —
    the one fault the symmetric fast path cannot express, so it costs
    len(variants) x len(sizes) full simulations; pass ``None`` to skip.
    ``variants`` overrides the candidate set (the claims use this to probe a
    deliberately fragile pair).  Deterministic throughout: the matrices
    replay the same argmin, and ``fragile`` is sorted by (size, scenario).
    """
    variants = list(variants) if variants is not None else candidate_variants(
        topo, collective, allow_prelaunch=allow_prelaunch,
        allow_optimized=allow_optimized, allow_pipelined=allow_pipelined,
        allow_reduce=allow_reduce)
    sizes = list(sizes)
    base = [sweep_candidate_latencies(topo, collective, tuple(sizes), v,
                                      chunk_bytes)
            for v in variants]
    base_i, _ = argmin_grid(base)

    named: list[tuple[str, list[list[float]]]] = []
    for name, scales in scenarios:
        ptopo = perturbed_topology(topo, scales)
        named.append((name, [sweep_candidate_latencies(
            ptopo, collective, tuple(sizes), v, chunk_bytes)
            for v in variants]))
    if straggler_slowdown is not None:
        plan = straggler_plan(0, straggler_slowdown)
        builder = COLLECTIVE_BUILDERS[collective]
        named.append((f"straggler_x{straggler_slowdown:g}", [
            [simulate(builder(topo, size, v, max_chunk_bytes=chunk_bytes),
                      topo, faults=plan).latency for size in sizes]
            for v in variants]))

    fragile: list[FragileEntry] = []
    for name, lat in named:
        alt = np.asarray(lat, dtype=float)
        alt_i, alt_t = argmin_grid(alt)
        for j in np.flatnonzero(alt_i != base_i):
            fragile.append(FragileEntry(
                size=sizes[j], scenario=name,
                base_variant=variants[base_i[j]],
                new_variant=variants[alt_i[j]],
                regret=float(alt[base_i[j], j] / alt_t[j])))
    fragile.sort(key=lambda f: (f.size, f.scenario))
    return RobustnessReport(
        collective=collective,
        scenarios=tuple(name for name, _ in named),
        n_points=len(sizes) * len(named),
        fragile=tuple(fragile))
