"""Size-range dispatch policy — the paper's Tables 2 & 3, plus a derived
policy that re-discovers the thresholds from the timing model (used both to
validate the model against the paper and to re-derive thresholds for the TPU
topology used by the JAX-level latte collectives, DESIGN.md §4/§5).

Optimized command streams (DESIGN.md §7): passing ``allow_optimized=True``
to :func:`candidate_variants` / :func:`derive_dispatch` adds the ``opt_``
variants (batched submission + SDMA queue slots + fused write+signal) to the
argmin, re-deriving the thresholds with the optimization layer available —
the baseline-vs-optimized sweep behind ``benchmarks/fig13*/fig14*
--optimized``.  The default sweeps stay baseline-only so the paper's
Tables 2/3 structure remains reproducible as published.

Simulation results are memoized: :func:`variant_latency` caches every
(topology, collective, size, variant) point and :func:`derive_dispatch`
caches whole argmin sweeps, so repeated claim evaluations and dispatch-table
derivations in one process pay for each simulation once.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

from .collectives import allgather_schedule, alltoall_schedule
from .engine import simulate
from .topology import Topology

KB = 1024
MB = 1024 * 1024
GB = 1024 * 1024 * 1024

# Paper Table 2 — best implementation per all-gather size range.
PAPER_AG_DISPATCH: tuple[tuple[int, int | None, str], ...] = (
    (1 * KB, 256 * KB, "prelaunch_b2b"),
    (256 * KB, 1 * MB, "prelaunch_bcst"),
    (1 * MB, 512 * MB, "prelaunch_pcpy"),
    (512 * MB, None, "pcpy"),
)

# Paper Table 3 — best implementation per all-to-all size range.
PAPER_AA_DISPATCH: tuple[tuple[int, int | None, str], ...] = (
    (1 * KB, 64 * KB, "prelaunch_b2b"),
    (64 * KB, 4 * MB, "prelaunch_swap"),
    (4 * MB, 1 * GB, "prelaunch_pcpy"),
    (1 * GB, None, "pcpy"),
)


def paper_dispatch(collective: str, size: int) -> str:
    table = PAPER_AG_DISPATCH if collective == "all_gather" else PAPER_AA_DISPATCH
    for lo, hi, variant in table:
        if size >= lo and (hi is None or size < hi):
            return variant
    return table[0][2] if size < table[0][0] else table[-1][2]


@dataclasses.dataclass(frozen=True)
class DispatchEntry:
    lo: int
    hi: int | None
    variant: str


@functools.lru_cache(maxsize=65536)
def variant_latency(topo: Topology, collective: str, size: int, variant: str) -> float:
    """Memoized end-to-end latency of one (collective, size, variant) point."""
    builder: Callable = allgather_schedule if collective == "all_gather" else alltoall_schedule
    return simulate(builder(topo, size, variant), topo).latency


def candidate_variants(
    topo: Topology,
    collective: str,
    *,
    allow_prelaunch: bool = True,
    allow_optimized: bool = False,
) -> list[str]:
    """Variants an argmin sweep should consider on this topology.

    ``allow_optimized`` additionally offers every candidate with the
    optimized command-stream transforms applied (``opt_`` prefix,
    DESIGN.md §7).
    """
    variants = ["pcpy", "b2b", "bcst" if collective == "all_gather" else "swap"]
    if not topo.fully_connected:
        variants.append("ring")
        if collective == "all_gather":
            variants.append("bidir_ring")
    if allow_prelaunch:
        variants += [f"prelaunch_{v}" for v in list(variants)]
    if allow_optimized:
        variants += [f"opt_{v}" for v in list(variants)]
    return variants


def optimized_variants(topo: Topology, collective: str) -> list[str]:
    """The ``opt_`` candidate set alone (DESIGN.md §7) — what the optimized
    claim bands and the ``--optimized`` benchmark curves sweep over."""
    return [v for v in candidate_variants(topo, collective, allow_optimized=True)
            if v.startswith("opt_")]


@functools.lru_cache(maxsize=256)
def _derive_dispatch_cached(
    topo: Topology,
    collective: str,
    sizes: tuple[int, ...],
    allow_prelaunch: bool,
    allow_optimized: bool,
) -> tuple[DispatchEntry, ...]:
    variants = candidate_variants(topo, collective, allow_prelaunch=allow_prelaunch,
                                  allow_optimized=allow_optimized)

    winners: list[tuple[int, str]] = []
    for size in sizes:
        best, best_t = None, float("inf")
        for v in variants:
            t = variant_latency(topo, collective, size, v)
            if t < best_t:
                best, best_t = v, t
        winners.append((size, best))

    entries: list[DispatchEntry] = []
    for i, (size, v) in enumerate(winners):
        if entries and entries[-1].variant == v:
            entries[-1] = DispatchEntry(entries[-1].lo, None, v)
        else:
            if entries:
                entries[-1] = DispatchEntry(entries[-1].lo, size, entries[-1].variant)
            entries.append(DispatchEntry(size, None, v))
    return tuple(entries)


def derive_dispatch(
    topo: Topology,
    collective: str,
    sizes: list[int],
    *,
    allow_prelaunch: bool = True,
    allow_optimized: bool = False,
) -> list[DispatchEntry]:
    """Re-derive the best variant per size from the timing model (argmin).

    Adjacent sizes with the same winner are merged into ranges, which should
    approximately reproduce Tables 2/3 on the MI300X topology (validated in
    tests/benchmarks) and gives the policy for the TPU topology.  With
    ``allow_optimized`` the sweep also offers the ``opt_`` command streams
    (DESIGN.md §7), yielding the re-derived thresholds for optimized
    collectives.  Sweeps are memoized per (topology, collective, sizes,
    allow_prelaunch, allow_optimized).
    """
    return list(_derive_dispatch_cached(topo, collective, tuple(sizes),
                                        allow_prelaunch, allow_optimized))


def best_variant_for(topo: Topology, collective: str, size: int,
                     variants) -> tuple[str, float]:
    """Argmin over an explicit variant list at one size (memoized points)."""
    best, best_t = None, float("inf")
    for v in variants:
        t = variant_latency(topo, collective, size, v)
        if t < best_t:
            best, best_t = v, t
    return best, best_t


def pick_variant(entries: list[DispatchEntry], size: int) -> str:
    for e in entries:
        if size >= e.lo and (e.hi is None or size < e.hi):
            return e.variant
    return entries[-1].variant if size >= entries[-1].lo else entries[0].variant
