"""CommBackend: the paper's size-dispatched collective policy as a
first-class framework feature.

``CommBackend('latte')`` picks the implementation per message size using
thresholds re-derived from the DMA timing model on the TPU topology
(DESIGN.md §5); ``CommBackend('reference')`` always uses the XLA one-shot
collectives.  The serving engine's KV-fetch path consumes ``kv_fetch_plan``.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
import tempfile
import warnings

from . import collectives as coll
from .dma.dispatch import DispatchEntry, derive_dispatch
from .dma.topology import (Topology, mi300x_cluster, tpu_v5e_multislice,
                           tpu_v5e_pod)

KB = 1024
MB = 1024 * 1024

# Bump when the simulator/calibration changes in a way that invalidates
# previously derived dispatch tables.
# v2: optimized command streams (DESIGN.md §7) — new Calibration constants
# (control_batched/doorbell_batched/fused_sync/sync_obs_batched).
# v3: chunked command streams (DESIGN.md §8) — Calibration.max_chunk_bytes
# and the swept chunk granularities join the fingerprint, entries carry a
# per-range ``chunk``; stale v2 tables must never serve chunked sweeps.
# v4: pipelined ring collectives (DESIGN.md §9) — the sweep offers the
# per-chunk-signaled ``pipe_`` family (allow_pipelined), so v3 tables that
# never saw those candidates must miss and re-derive (regression-tested in
# tests/test_dispatch_cache.py).
# v5: reduce collectives (DESIGN.md §10) — bundled tables grow reduce_scatter
# and all_reduce sweeps (allow_reduce) and the reduce calibration
# (Calibration.reduce_setup / reduce_bytes_per_s, embedded via topo!r) joins
# the fingerprint; v4 tables carry neither, so they must miss and re-derive
# (regression-tested in tests/test_dispatch_cache.py).
# v6: hierarchical multi-node collectives (DESIGN.md §11) — bundled tables
# grow the tpu64/tpu256/mi300x-2node hier sweeps and the NIC calibration
# (Calibration.nic_latency / nic_bytes_per_s, embedded via topo!r) joins the
# fingerprint; v5 tables never saw the hier candidates or the NIC tier, so
# they must miss and re-derive (regression-tested in
# tests/test_dispatch_cache.py).
# v7: fused compute-collective overlap (DESIGN.md §15) — the CU calibration
# (Calibration.cu_tile_setup / cu_flops, embedded via topo!r) joins the
# fingerprint, and the single-node latte sweeps re-derive with the
# optimized/prelaunch command streams offered (allow_optimized), retiring
# the unconditional StaleTablesWarning; v6 baseline-only tables never saw
# the opt_ candidates, so they must miss and re-derive (regression-tested
# in tests/test_dispatch_cache.py).
_TABLE_CACHE_VERSION = 7
# The size sweep behind every cached/bundled table; part of the cache key.
_SWEEP_SIZES = [2 ** i for i in range(10, 31)]
# Chunk granularities the table sweep offers the argmin (DESIGN.md §8.1):
# the calibrated default (None) plus a finer split; part of the cache key.
_SWEEP_CHUNKS = (None, 1 * MB)
_TABLE_CACHE_DIR = os.environ.get(
    "REPRO_DISPATCH_CACHE",
    os.path.join(tempfile.gettempdir(), "repro-dma-dispatch"))


# Pre-derived tables shipped with the package (regenerate with
# `python -m repro.core.backend`); keyed by the same fingerprint as the disk
# cache, so any simulator/calibration change simply misses and re-derives.
_BUNDLED_TABLES = os.path.join(os.path.dirname(__file__), "dma",
                               "_dispatch_tables.json")


def _table_key(topo: Topology, sizes: list[int]) -> str:
    # topo!r embeds the full Calibration (including max_chunk_bytes and the
    # chunking-relevant issue constants), so any recalibration — not just a
    # version bump — misses the cache and re-derives.
    return hashlib.sha1(
        f"v{_TABLE_CACHE_VERSION}|{topo!r}|{sizes!r}|{_SWEEP_CHUNKS!r}"
        .encode()).hexdigest()[:16]


def _table_cache_path(topo: Topology, sizes: list[int]) -> str:
    return os.path.join(_TABLE_CACHE_DIR,
                        f"tables_{topo.name}_{_table_key(topo, sizes)}.json")


def _parse_tables(raw):
    return tuple(
        tuple(DispatchEntry(e["lo"], e["hi"], e["variant"], e.get("chunk"))
              for e in tbl)
        for tbl in raw)


def _load_table_cache(topo: Topology, sizes: list[int]):
    """Cross-process memo of the derived tables: subprocesses (tests, dry
    runs, serving workers) skip the argmin sweep entirely on a warm cache.
    The bundled package copy serves cold starts."""
    try:
        with open(_BUNDLED_TABLES) as f:
            bundled = json.load(f)
        raw = bundled.get(_table_key(topo, sizes))
        if raw is not None:
            return _parse_tables(raw)
    except (OSError, ValueError, KeyError):
        pass
    try:
        with open(_table_cache_path(topo, sizes)) as f:
            return _parse_tables(json.load(f))
    except (OSError, ValueError, KeyError):
        return None


def _serialize_tables(tables):
    return [[{"lo": e.lo, "hi": e.hi, "variant": e.variant, "chunk": e.chunk}
             for e in tbl] for tbl in tables]


def _store_table_cache(topo: Topology, sizes: list[int], tables) -> None:
    try:
        os.makedirs(_TABLE_CACHE_DIR, exist_ok=True)
        path = _table_cache_path(topo, sizes)
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            json.dump(_serialize_tables(tables), f)
        os.replace(tmp, path)
    except OSError:
        pass

# Variant names (paper + torus ring renderings) -> JAX implementations here.
# The pipe_ winners (DESIGN.md §9) map onto the matching JAX ring renderings:
# XLA already software-pipelines the lowered ring loop, so the per-chunk
# simulator variant and the JAX collective share one implementation.
_AG_IMPL = {
    "pcpy": coll.reference_all_gather,
    "b2b": coll.ring_all_gather,
    "bcst": coll.bidir_ring_all_gather,
    "ring": coll.ring_all_gather,
    "bidir_ring": coll.bidir_ring_all_gather,
    "pipe_b2b": coll.ring_all_gather,
    "pipe_bidir_ring": coll.bidir_ring_all_gather,
    # Hierarchical winners (DESIGN.md §11): XLA lowers a multislice
    # all-gather to exactly the two-tier decomposition the hier_ variants
    # model (intra-slice ring + DCN exchange), so both map onto the ring
    # rendering — the dispatch *threshold* is what the table contributes.
    "hier_ring": coll.ring_all_gather,
    "hier_pipe": coll.ring_all_gather,
}
_AA_IMPL = {
    "pcpy": coll.reference_all_to_all,
    "b2b": coll.pairwise_all_to_all,
    "swap": coll.pairwise_all_to_all,
    "ring": coll.pairwise_all_to_all,
    "pipe_b2b": coll.pairwise_all_to_all,
}
# Reduce winners (DESIGN.md §10): every ring reduce variant — including the
# bidir and per-chunk-pipelined renderings — lowers to the ppermute ring
# reduce-scatter (XLA fuses the per-step accumulate into the loop); the
# all-reduce composition lowers to its RS + ring-AG decomposition.
_RS_IMPL = {
    "ring_rs": coll.ring_reduce_scatter,
    "bidir_ring_rs": coll.ring_reduce_scatter,
    "pipe_ring_rs": coll.ring_reduce_scatter,
    "pipe_bidir_ring_rs": coll.ring_reduce_scatter,
    "hier_ring_rs": coll.ring_reduce_scatter,
    "hier_pipe_rs": coll.ring_reduce_scatter,
}
_AR_IMPL = {
    "ring_rs": coll.ring_all_reduce,
    "bidir_ring_rs": coll.ring_all_reduce,
    "pipe_ring_rs": coll.ring_all_reduce,
    "pipe_bidir_ring_rs": coll.ring_all_reduce,
    "hier_ring_rs": coll.ring_all_reduce,
    "hier_pipe_rs": coll.ring_all_reduce,
}


def _derive_single_node(topo: Topology):
    """Derive the (ag, aa, rs, ar) latte tables for one single-node topology.

    Since v7 the sweep offers the full ``opt_``/``prelaunch_`` composition
    alongside the pipelined rings, so ``CommBackend('latte')`` dispatches on
    current winners instead of the baseline-only published thresholds (the
    paper's as-published Tables 2/3 remain reproducible through the default
    ``derive_dispatch`` flags — this is the *deployment* table).
    """
    sizes = _SWEEP_SIZES
    kw = dict(allow_pipelined=True, allow_optimized=True,
              chunk_sizes=_SWEEP_CHUNKS)
    ag = tuple(derive_dispatch(topo, "all_gather", sizes, **kw))
    aa = tuple(derive_dispatch(topo, "all_to_all", sizes, **kw))
    rs = tuple(derive_dispatch(topo, "reduce_scatter", sizes,
                               allow_reduce=True, **kw))
    ar = tuple(derive_dispatch(topo, "all_reduce", sizes,
                               allow_reduce=True, **kw))
    return ag, aa, rs, ar


@functools.lru_cache(maxsize=8)
def tpu_dispatch_tables(n_devices: int = 16):
    """Re-derive Tables 2/3 for the TPU torus from the timing model
    (DESIGN.md §4), plus the reduce_scatter/all_reduce tables (§10): the
    event simulator routes every variant over real ICI neighbor links, so
    the argmin picks between direct multi-hop one-shot schedules and the
    ring/bidir-ring renderings with true per-step dependencies — since v7
    with the ``opt_``/``prelaunch_`` command streams offered too.  Returns
    ``(ag, aa, rs, ar)`` entry tuples.  The sweep is memoized in-process
    (dispatch.derive_dispatch) and on disk (seconds per fresh process
    otherwise)."""
    topo = tpu_v5e_pod(n_devices)
    sizes = _SWEEP_SIZES
    cached = _load_table_cache(topo, sizes)
    if cached is not None:
        return cached
    tables = _derive_single_node(topo)
    _store_table_cache(topo, sizes, tables)
    return tables


#: Multi-node topology builders the bundled v6 tables cover (DESIGN.md §11):
#: 4- and 16-slice TPU v5e multislices plus a 2-node MI300X RDMA cluster.
MULTINODE_TOPOS = {
    "tpu64": lambda: tpu_v5e_multislice(64),
    "tpu256": lambda: tpu_v5e_multislice(256),
    "mi300x-2node": lambda: mi300x_cluster(2),
}


def _derive_multinode(topo: Topology):
    """Derive the (ag, rs, ar) tables for one multi-node topology.

    No all_to_all sweep — it has no hierarchical rendering and raises
    (DESIGN.md §11).  The hier sweep offers the full ``opt_``/``prelaunch_``
    composition: unlike the single-node paper tables (kept baseline-only so
    Tables 2/3 stay reproducible as published) there is no published
    multi-node baseline to preserve, so the table should simply be the best
    modeled stream.  Only derivable in CI budgets because every hier
    candidate runs the vectorized sweep fast path (DESIGN.md §11.3).
    """
    sizes = _SWEEP_SIZES
    kw = dict(allow_pipelined=True, allow_optimized=True,
              chunk_sizes=_SWEEP_CHUNKS)
    ag = tuple(derive_dispatch(topo, "all_gather", sizes, **kw))
    rs = tuple(derive_dispatch(topo, "reduce_scatter", sizes,
                               allow_reduce=True, **kw))
    ar = tuple(derive_dispatch(topo, "all_reduce", sizes,
                               allow_reduce=True, **kw))
    return ag, rs, ar


@functools.lru_cache(maxsize=8)
def multinode_dispatch_tables(spec: str = "tpu64"):
    """Hierarchical dispatch tables for a multi-node topology (DESIGN.md
    §11): ``(ag, rs, ar)`` entry tuples for a :data:`MULTINODE_TOPOS` spec.
    Same cache discipline as :func:`tpu_dispatch_tables` — in-process memo,
    disk cache, bundled package copy keyed by the v6 fingerprint."""
    topo = MULTINODE_TOPOS[spec]()
    sizes = _SWEEP_SIZES
    cached = _load_table_cache(topo, sizes)
    if cached is not None:
        return cached
    tables = _derive_multinode(topo)
    _store_table_cache(topo, sizes, tables)
    return tables


def _pick(entries, size: int) -> str:
    for e in entries:
        if size >= e.lo and (e.hi is None or size < e.hi):
            return e.variant
    return entries[-1].variant


class StaleTablesWarning(UserWarning):
    """The bundled dispatch tables predate this simulator/calibration.

    The bundled ``_dispatch_tables.json`` is keyed by a fingerprint of the
    table-cache version, the topology's full calibration, and the sweep
    grid.  When the key for the current simulator is absent — a calibration
    changed, the cache version was bumped, or the bundled copy was never
    regenerated — the latte backend still dispatches on *correct* tables
    (it re-derives on the fly, paying the argmin sweep once per process),
    but the shipped thresholds are genuinely stale and the package should
    be regenerated with ``python -m repro.core.backend``.  Pass
    ``CommBackend(allow_stale_tables=True)`` to acknowledge and silence.
    """


@functools.lru_cache(maxsize=32)
def _bundled_current(topo: Topology, sizes: tuple[int, ...]) -> bool:
    """True when the bundled package tables carry this fingerprint —
    i.e. they were regenerated against the current simulator/calibration."""
    try:
        with open(_BUNDLED_TABLES) as f:
            return _table_key(topo, list(sizes)) in json.load(f)
    except (OSError, ValueError):
        return False


@dataclasses.dataclass(frozen=True)
class CommBackend:
    kind: str = "latte"            # latte | reference
    axis_devices: int = 16
    b2b_fanout_threshold: int = 4 * MB   # paper §5.3.1 empirical threshold
    # Dispatching against a bundled-tables fingerprint mismatch (simulator
    # or calibration drifted since `python -m repro.core.backend` last ran)
    # warns (StaleTablesWarning) unless explicitly acknowledged here.
    allow_stale_tables: bool = False

    def _strip(self, v: str) -> str:
        # opt_/prelaunch_ change the command stream's scheduling envelope,
        # not which JAX collective implements the winner.
        for prefix in ("opt_", "prelaunch_"):
            if v.startswith(prefix):
                v = v[len(prefix):]
        return v

    def _tables(self, collective: str):
        topo = tpu_v5e_pod(self.axis_devices)
        if not self.allow_stale_tables and \
                not _bundled_current(topo, tuple(_SWEEP_SIZES)):
            warnings.warn(
                f"CommBackend('latte').{collective}: the bundled dispatch "
                "tables do not match this simulator/calibration fingerprint "
                f"(v{_TABLE_CACHE_VERSION}) — re-deriving on the fly; "
                "regenerate with `python -m repro.core.backend` or pass "
                "allow_stale_tables=True to acknowledge",
                StaleTablesWarning, stacklevel=3)
        return tpu_dispatch_tables(self.axis_devices)

    def all_gather(self, x, axis_name: str):
        """Called inside shard_map.  Returns stacked [n, *x.shape]."""
        if self.kind == "reference":
            return coll.reference_all_gather(x, axis_name)
        size = x.size * x.dtype.itemsize * self.axis_devices
        ag = self._tables("all_gather")[0]
        variant = self._strip(_pick(ag, size))
        return _AG_IMPL.get(variant, coll.reference_all_gather)(x, axis_name)

    def all_to_all(self, x, axis_name: str):
        """Called inside shard_map with x: [n, ...] chunks."""
        if self.kind == "reference":
            return coll.reference_all_to_all(x, axis_name)
        size = x.size * x.dtype.itemsize
        aa = self._tables("all_to_all")[1]
        variant = self._strip(_pick(aa, size))
        return _AA_IMPL.get(variant, coll.reference_all_to_all)(x, axis_name)

    def reduce_scatter(self, x, axis_name: str):
        """Called inside shard_map with x: [n, ...] addend chunks; returns
        this device's reduced chunk (DESIGN.md §10)."""
        if self.kind == "reference":
            return coll.reference_reduce_scatter(x, axis_name)
        size = x.size * x.dtype.itemsize
        rs = self._tables("reduce_scatter")[2]
        variant = self._strip(_pick(rs, size))
        return _RS_IMPL.get(variant, coll.reference_reduce_scatter)(x, axis_name)

    def all_reduce(self, x, axis_name: str):
        """Called inside shard_map with x: [n, ...] chunks; returns the
        elementwise sum across devices (DESIGN.md §10)."""
        if self.kind == "reference":
            return coll.reference_all_reduce(x, axis_name)
        size = x.size * x.dtype.itemsize
        ar = self._tables("all_reduce")[3]
        variant = self._strip(_pick(ar, size))
        return _AR_IMPL.get(variant, coll.reference_all_reduce)(x, axis_name)

    def kv_fetch_plan(self, n_blocks: int, block_bytes: int) -> dict:
        """How the serving engine should fetch dispersed KV blocks (§5.3).

        The latte plan additionally requests the optimized command stream
        (``optimized: True`` — batched submission + fused write+signal on
        the batch's chunk commands, DESIGN.md §7/§8); the serving engine
        maps it to the ``opt_b2b`` fetch backend.
        """
        total = n_blocks * block_bytes
        if self.kind == "reference":
            return {"mode": "pcpy", "fanout": min(n_blocks, 16),
                    "optimized": False}
        if total < self.b2b_fanout_threshold:
            return {"mode": "b2b", "fanout": 1, "optimized": True}
        return {"mode": "b2b", "fanout": 4, "optimized": True}


def regenerate_bundled_tables(device_counts=(16,),
                              multinode=tuple(MULTINODE_TOPOS)) -> str:
    """Derive the standard TPU dispatch tables plus the multi-node hier
    tables (DESIGN.md §11) and write the bundled package copy
    (`python -m repro.core.backend`).  Run after any simulator or
    calibration change (and bump _TABLE_CACHE_VERSION if the key inputs did
    not change but the semantics did).  Also writes through to the disk
    cache ($REPRO_DISPATCH_CACHE) so CI can upload the sweep artifact."""
    out = {}
    for spec in multinode:
        topo = MULTINODE_TOPOS[spec]()
        tables = _derive_multinode(topo)
        _store_table_cache(topo, _SWEEP_SIZES, tables)
        out[_table_key(topo, _SWEEP_SIZES)] = _serialize_tables(tables)
    for n in device_counts:
        topo = tpu_v5e_pod(n)
        tables = _derive_single_node(topo)
        _store_table_cache(topo, _SWEEP_SIZES, tables)
        out[_table_key(topo, _SWEEP_SIZES)] = _serialize_tables(tables)
    with open(_BUNDLED_TABLES, "w") as f:
        json.dump(out, f, indent=1)
    return _BUNDLED_TABLES


if __name__ == "__main__":
    print(f"wrote {regenerate_bundled_tables()}")
