"""CommBackend: the paper's size-dispatched collective policy as a
first-class framework feature.

``CommBackend('latte')`` picks the implementation per message size using
thresholds re-derived from the DMA timing model on the TPU topology
(DESIGN.md §5); ``CommBackend('reference')`` always uses the XLA one-shot
collectives.  The serving engine's KV-fetch path consumes ``kv_fetch_plan``.
"""
from __future__ import annotations

import dataclasses
import functools

from . import collectives as coll
from .dma.dispatch import DispatchEntry, derive_dispatch
from .dma.topology import Topology, tpu_v5e_pod

KB = 1024
MB = 1024 * 1024

# Variant names (paper) -> JAX implementations here.
_AG_IMPL = {
    "pcpy": coll.reference_all_gather,
    "b2b": coll.ring_all_gather,
    "bcst": coll.bidir_ring_all_gather,
}
_AA_IMPL = {
    "pcpy": coll.reference_all_to_all,
    "b2b": coll.pairwise_all_to_all,
    "swap": coll.pairwise_all_to_all,
}


@functools.lru_cache(maxsize=8)
def tpu_dispatch_tables(n_devices: int = 16):
    """Re-derive Tables 2/3 for the TPU topology from the timing model."""
    topo = tpu_v5e_pod(n_devices)
    sizes = [2 ** i for i in range(10, 31)]
    ag = derive_dispatch(topo, "all_gather", sizes)
    aa = derive_dispatch(topo, "all_to_all", sizes)
    return tuple(ag), tuple(aa)


def _pick(entries, size: int) -> str:
    for e in entries:
        if size >= e.lo and (e.hi is None or size < e.hi):
            return e.variant
    return entries[-1].variant


@dataclasses.dataclass(frozen=True)
class CommBackend:
    kind: str = "latte"            # latte | reference
    axis_devices: int = 16
    b2b_fanout_threshold: int = 4 * MB   # paper §5.3.1 empirical threshold

    def _strip(self, v: str) -> str:
        return v[len("prelaunch_"):] if v.startswith("prelaunch_") else v

    def all_gather(self, x, axis_name: str):
        """Called inside shard_map.  Returns stacked [n, *x.shape]."""
        if self.kind == "reference":
            return coll.reference_all_gather(x, axis_name)
        size = x.size * x.dtype.itemsize * self.axis_devices
        ag, _ = tpu_dispatch_tables(self.axis_devices)
        variant = self._strip(_pick(ag, size))
        return _AG_IMPL.get(variant, coll.reference_all_gather)(x, axis_name)

    def all_to_all(self, x, axis_name: str):
        """Called inside shard_map with x: [n, ...] chunks."""
        if self.kind == "reference":
            return coll.reference_all_to_all(x, axis_name)
        size = x.size * x.dtype.itemsize
        _, aa = tpu_dispatch_tables(self.axis_devices)
        variant = self._strip(_pick(aa, size))
        return _AA_IMPL.get(variant, coll.reference_all_to_all)(x, axis_name)

    def kv_fetch_plan(self, n_blocks: int, block_bytes: int) -> dict:
        """How the serving engine should fetch dispersed KV blocks (§5.3)."""
        total = n_blocks * block_bytes
        if self.kind == "reference":
            return {"mode": "pcpy", "fanout": min(n_blocks, 16)}
        if total < self.b2b_fanout_threshold:
            return {"mode": "b2b", "fanout": 1}
        return {"mode": "b2b", "fanout": 4}
