"""Hierarchical (latte) MoE dispatch: shard_map + explicit expert all-to-all.

EXPERIMENTS.md §Perf found that GSPMD-transparent MoE dispatch dead-ends:
the global-argsort scatter is opaque to the partitioner, which replicates
the capacity buffer and all-reduces it per layer (4.2 TB/device/step on
mixtral train_4k).  This module is the identified fix, and it is the
paper's own story one level up — an EXPLICIT schedule (local pack + expert
all-to-all, the exact collective §4.3 optimizes with swap/b2b) replacing a
transparent runtime decision:

  1. shard_map over the expert-parallel axis: tokens arrive sharded.
  2. LOCAL top-k + LOCAL capacity pack (argsort never crosses devices).
  3. expert all-to-all (CommBackend: pairwise-swap/b2b/reference by size).
  4. local expert FFNs on owned experts.
  5. all-to-all back + local weighted combine.

Requires n_experts % axis_size == 0 (true expert parallelism).  Validated
against a no-drop dense oracle in tests/test_latte_moe.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.compat import axis_size
import numpy as np

from repro.configs.base import ArchConfig
from . import collectives as coll


def _local_capacity(cfg: ArchConfig, t_local: int) -> int:
    m = cfg.moe
    cap = int(np.ceil(t_local * m.top_k / m.n_experts * m.capacity_factor))
    return max(4, cap)


def latte_moe_local(cfg: ArchConfig, p: dict, xf: jax.Array, axis_name: str,
                    *, all_to_all=None):
    """Per-shard body (call inside shard_map over ``axis_name``).

    xf: [T_local, D] local tokens.  Expert weights in ``p`` are the LOCAL
    expert shards: router [D, E] (replicated), wg/wu/wd [E_local, ...].
    Returns ([T_local, D], aux).
    """
    a2a = all_to_all or coll.pairwise_all_to_all
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    T, D = xf.shape
    C = _local_capacity(cfg, T)
    n_shards = axis_size(axis_name)
    e_local = E // n_shards
    cd = xf.dtype

    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, K)
    topk_p = topk_p / jnp.sum(topk_p, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=0)
    assign = jnp.zeros((E,), jnp.float32).at[topk_e.reshape(-1)].add(1.0)
    aux = E * jnp.sum(me * (assign / (T * K))) * m.router_aux_weight

    # ---- LOCAL pack: argsort over local assignments only ----
    flat_e = topk_e.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    pos = jnp.arange(T * K, dtype=jnp.int32) - group_start[sorted_e].astype(jnp.int32)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)
    token_of = (order // K).astype(jnp.int32)

    send = jnp.zeros((E, C, D), cd).at[sorted_e, pos_c].set(
        xf[token_of] * keep[:, None].astype(cd), mode="drop")

    # ---- expert all-to-all: [n_shards, e_local, C, D] chunks ----
    send = send.reshape(n_shards, e_local, C, D)
    recv = a2a(send, axis_name)              # [n_shards(src), e_local, C, D]

    # ---- local expert FFNs over owned experts ----
    buf = jnp.moveaxis(recv, 0, 1).reshape(e_local, n_shards * C, D)
    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(cd))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["wd"].astype(cd))

    # ---- return trip + local combine ----
    back = jnp.moveaxis(y.reshape(e_local, n_shards, C, D), 1, 0)
    mine = a2a(back, axis_name).reshape(E, C, D)   # my tokens' outputs

    contrib = mine[sorted_e, pos_c] * keep[:, None].astype(cd)
    weights = topk_p.reshape(-1)[order].astype(cd)
    out = jnp.zeros((T, D), cd).at[token_of].add(contrib * weights[:, None])
    return out, aux


def make_latte_moe(cfg: ArchConfig, mesh, axis_name: str, *, all_to_all=None):
    """Returns fn(params, x [B,S,D]) -> (out, aux) running the hierarchical
    dispatch under shard_map: tokens sharded on batch over ``axis_name``,
    expert weights sharded on the expert dim."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    assert cfg.moe and cfg.moe.n_experts % mesh.shape[axis_name] == 0

    def fn(p, x):
        B, S, D = x.shape

        def body(router, wg, wu, wd, xl):
            b, s, d = xl.shape
            out, aux = latte_moe_local(
                cfg, {"router": router, "wg": wg, "wu": wu, "wd": wd},
                xl.reshape(b * s, d), axis_name, all_to_all=all_to_all)
            return out.reshape(b, s, d), jax.lax.pmean(aux, axis_name)

        mapped = shard_map(
            body, mesh=mesh,
            in_specs=(P(None, None), P(axis_name, None, None),
                      P(axis_name, None, None), P(axis_name, None, None),
                      P(axis_name, None, None)),
            out_specs=(P(axis_name, None, None), P()),
            check_vma=False)
        return mapped(p["router"], p["wg"], p["wu"], p["wd"], x)

    return fn
