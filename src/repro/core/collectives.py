"""JAX-level latte collectives: shard_map/ppermute implementations of the
paper's schedule shapes, plus a reference (XLA one-shot) backend.

These are the *jit-composable* renderings used inside model code (the Pallas
kernels in ``repro/kernels`` are the explicit-DMA renderings).  Mapping:

* ``reference``   — ``jax.lax.all_gather`` / ``all_to_all`` (XLA chooses;
                    the analogue of the tuned CU library).
* ``ring``        — unidirectional ppermute ring: one chained transfer in
                    flight per step = the b2b single-engine queue.
* ``bidir_ring``  — every step forwards two chunks (to left AND right): one
                    local read feeding two destinations = bcst; halves steps.
* ``pairwise``    — XOR-partner exchange rounds for all-to-all = swap.

All functions are called INSIDE shard_map with ``axis_name`` bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import axis_size


def ring_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """b2b analogue.  x: local shard -> [n, *x.shape] gathered (stacked)."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    chunks = [x]
    send = x
    for _ in range(n - 1):
        send = jax.lax.ppermute(send, axis_name, perm)
        chunks.append(send)
    stacked = jnp.stack(chunks)              # stacked[k] = x from device (idx-k)%n
    order = jnp.mod(idx - jnp.arange(n), n)  # out[j] = stacked[(idx-j)%n]
    return jnp.take(stacked, order, axis=0)


def bidir_ring_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """bcst analogue: both directions each step, ceil((n-1)/2) steps."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    fwd_perm = [(i, (i + 1) % n) for i in range(n)]
    bwd_perm = [(i, (i - 1) % n) for i in range(n)]
    n_fwd = (n - 1 + 1) // 2
    n_bwd = (n - 1) - n_fwd
    out = {0: x}
    send_f, send_b = x, x
    for k in range(1, n_fwd + 1):
        send_f = jax.lax.ppermute(send_f, axis_name, fwd_perm)
        out[k] = send_f                      # chunk from device idx-k (offset k)
        if k <= n_bwd:
            send_b = jax.lax.ppermute(send_b, axis_name, bwd_perm)
            out[(n - k) % n] = send_b        # chunk from device idx+k
    stacked = jnp.stack([out[o] for o in range(n)])   # stacked[o] = x_{(idx-o)%n}
    order = jnp.mod(idx - jnp.arange(n), n)
    return jnp.take(stacked, order, axis=0)


def pairwise_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """swap analogue.  x: [n, ...] local chunks -> out[j] = x_j[idx].

    Round r exchanges chunk x[idx^r] with partner idx^r (n power of two), a
    symmetric in-place pairwise swap; falls back to rotation pairing else.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    assert x.shape[0] == n
    power_of_two = (n & (n - 1)) == 0
    out = jnp.zeros_like(x)
    # own chunk stays
    own = jnp.take(x, idx, axis=0)
    out = jax.lax.dynamic_update_index_in_dim(out, own, idx, 0)
    for r in range(1, n):
        if power_of_two:
            perm = [(i, i ^ r) for i in range(n)]
            partner = idx ^ r
        else:
            perm = [(i, (i + r) % n) for i in range(n)]
            partner = jnp.mod(idx + r, n)
        send = jnp.take(x, partner, axis=0)
        recv = jax.lax.ppermute(send, axis_name, perm)
        src = jnp.mod(idx - r, n) if not power_of_two else partner
        out = jax.lax.dynamic_update_index_in_dim(out, recv, src, 0)
    return out


def reference_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    return jax.lax.all_gather(x, axis_name)


def reference_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)


def ring_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """Ring reduce-scatter (DESIGN.md §10).  x: [n, ...] per-device addend
    chunks -> this device's fully reduced chunk (``sum_e x_e[idx]``).

    The partial destined for device *o* starts at its successor ``o+1``,
    travels the ring forward for n-1 hops, and each visited device folds
    in its own contribution — at step *r* device *i* is holding (and
    sending) the partial destined for ``(i - r - 1) % n``.  This is the
    ppermute rendering of the ``ring_rs`` DMA schedule.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    acc = jnp.take(x, jnp.mod(idx - 1, n), axis=0)
    for r in range(n - 1):
        recv = jax.lax.ppermute(acc, axis_name, perm)
        acc = recv + jnp.take(x, jnp.mod(idx - r - 2, n), axis=0)
    return acc


def reference_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """XLA analogue: full psum, then keep this device's chunk."""
    return jnp.take(jax.lax.psum(x, axis_name),
                    jax.lax.axis_index(axis_name), axis=0)


def ring_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce as ring reduce-scatter + ring all-gather (DESIGN.md §10).
    x: [n, ...] chunks -> [n, ...] with out[j] = ``sum_e x_e[j]``."""
    return ring_all_gather(ring_reduce_scatter(x, axis_name), axis_name)


def reference_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    return jax.lax.psum(x, axis_name)
