"""Jit'd wrapper for the Pallas all-to-all kernel."""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from .ring_all_to_all import make_all_to_all


def pallas_all_to_all(
    x: jax.Array,          # [n, n, chunk, F]: dim0 = device, dim1 = dest chunk
    mesh,
    axis_name: str,
    *,
    variant: str = "b2b",   # b2b | per_round
    interpret: bool = False,
) -> jax.Array:
    n = mesh.shape[axis_name]
    assert x.shape[0] == n and x.shape[1] == n
    fn = make_all_to_all(axis_name, n, b2b=(variant == "b2b"), interpret=interpret)

    def local(xl):
        return fn(xl[0])[None]

    mapped = shard_map(local, mesh=mesh,
                       in_specs=P(axis_name, None, None, None),
                       out_specs=P(axis_name, None, None, None),
                       check_vma=False)
    return jax.jit(mapped)(x)
