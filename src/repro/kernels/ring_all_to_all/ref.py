"""Pure-jnp oracle for all-to-all: out[i, j] = x[j, i] (chunk transpose)."""
from __future__ import annotations

import jax.numpy as jnp


def all_to_all_ref(global_x: jnp.ndarray) -> jnp.ndarray:
    """global_x: [n_devices, n_chunks=n_devices, chunk, F] -> transposed."""
    return jnp.swapaxes(global_x, 0, 1)
