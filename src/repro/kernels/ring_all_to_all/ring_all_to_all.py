"""Pallas TPU all-to-all over remote DMA — the swap/b2b analogue (paper §4.3/4.4).

Device i holds input chunks x_i[0..n-1] (chunk j destined to device j) and
must end with out_i[j] = x_j[i].

Schedules:
* ``swap`` (XOR pairing, n a power of two): round r exchanges chunks with
  partner ``my ^ r`` — a symmetric in-place pairwise exchange: both
  directions of a pair travel the same (full-duplex) link simultaneously and
  land DIRECTLY in their final output slot, no staging buffer.  This is the
  TPU rendering of the paper's in-place ``swap`` command (Fig. 10).
* rotation pairing for other n.

Sync variants:
* ``per_round`` (pcpy-like): wait send+recv every round.
* ``b2b``: ALL rounds' sends are issued back-to-back up front — legal
  because every send reads the INPUT ref while receives land in the OUTPUT
  ref (no data hazard) — then one trailing drain of recvs/sends.  This is
  simultaneously the paper's b2b (single sync for a chain of copies) and
  prelaunch (issue off the critical path) applied to all-to-all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pltpu_compiler_params, pltpu_interpret_mode


def all_to_all_kernel(
    x_ref,          # [n, chunk, F] input chunks (ANY)
    out_ref,        # [n, chunk, F] output (ANY)
    local_sem,
    send_sems,      # DMA sem array [n-1]
    recv_sems,      # DMA sem array [n-1]
    *,
    axis_name: str,
    num_devices: int,
    xor_pairing: bool,
    b2b: bool,
):
    n = num_devices
    my = jax.lax.axis_index(axis_name)

    barrier = pltpu.get_barrier_semaphore()
    for d in (jax.lax.rem(my + 1, n), jax.lax.rem(my + n - 1, n)):
        pltpu.semaphore_signal(barrier, 1, device_id=d)
    pltpu.semaphore_wait(barrier, 2)

    local = pltpu.make_async_copy(x_ref.at[my], out_ref.at[my], local_sem)
    local.start()
    local.wait()

    def send_copy(r):
        partner = (my ^ r) if xor_pairing else jax.lax.rem(my + r, n)
        # my chunk `partner` lands in partner's out slot `my`
        return pltpu.make_async_remote_copy(
            src_ref=x_ref.at[partner], dst_ref=out_ref.at[my],
            send_sem=send_sems.at[r - 1], recv_sem=recv_sems.at[r - 1],
            device_id=partner)

    if b2b:
        def issue(r, _):
            send_copy(r).start()       # back-to-back issue, no intervening sync
            return 0
        jax.lax.fori_loop(1, n, issue, 0)

        def drain(r, _):
            c = send_copy(r)
            c.wait_send()
            c.wait_recv()
            return 0
        jax.lax.fori_loop(1, n, drain, 0)
    else:
        def round_(r, _):
            c = send_copy(r)
            c.start()
            c.wait()
            return 0
        jax.lax.fori_loop(1, n, round_, 0)


def make_all_to_all(
    axis_name: str,
    num_devices: int,
    *,
    b2b: bool = True,
    interpret: bool = False,
    collective_id: int = 1,
):
    """Returns fn(x [n, chunk, F]) -> [n, chunk, F] with out[j] = x_j[my];
    call inside shard_map over ``axis_name``."""
    xor_pairing = (num_devices & (num_devices - 1)) == 0
    kernel = functools.partial(
        all_to_all_kernel,
        axis_name=axis_name,
        num_devices=num_devices,
        xor_pairing=xor_pairing,
        b2b=b2b,
    )
    n_steps = max(num_devices - 1, 1)

    def fn(x: jax.Array) -> jax.Array:
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
            scratch_shapes=[pltpu.SemaphoreType.DMA,
                            pltpu.SemaphoreType.DMA((n_steps,)),
                            pltpu.SemaphoreType.DMA((n_steps,))],
            compiler_params=pltpu_compiler_params(collective_id=collective_id),
            interpret=pltpu_interpret_mode() if interpret else False,
        )(x)

    return fn
