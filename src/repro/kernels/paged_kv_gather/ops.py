"""Jit'd wrapper for the paged KV gather kernel."""
from __future__ import annotations

from functools import partial

import jax

from .paged_kv_gather import paged_kv_gather


@partial(jax.jit, static_argnames=("interpret",))
def gather_blocks(pool, block_table, *, interpret: bool = False):
    return paged_kv_gather(pool, block_table, interpret=interpret)
