"""Paged KV-cache gather kernel — the workload-level offload of §5.3.

Fetches ``n_blocks`` dispersed KV blocks (PagedAttention layout, 16 tokens
per block by default, as in vLLM) from a large pool into a contiguous
buffer.  The block table is a scalar-prefetch operand, so the Pallas
pipeline issues the per-block HBM DMAs back-to-back with double buffering —
the kernel-level rendering of the paper's b2b batched copies (one logical
launch + one completion, instead of one hipMemcpyAsync per block).

BlockSpec tiling: one (block_tokens x d_kv) block per grid step in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(tbl_ref, pool_ref, out_ref):
    out_ref[...] = pool_ref[...]


def paged_kv_gather(
    pool: jax.Array,          # [n_pool_blocks, block_tokens, d_kv]
    block_table: jax.Array,   # [n_blocks] int32 indices into pool
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns [n_blocks, block_tokens, d_kv] contiguous KV."""
    n_blocks = block_table.shape[0]
    _, bt, dkv = pool.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, bt, dkv), lambda i, tbl: (tbl[i], 0, 0))],
        out_specs=pl.BlockSpec((1, bt, dkv), lambda i, tbl: (i, 0, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks, bt, dkv), pool.dtype),
        interpret=interpret,
    )(block_table, pool)
