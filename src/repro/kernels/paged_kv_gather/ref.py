"""Pure-jnp oracle: gather = take along the pool axis."""
from __future__ import annotations

import jax.numpy as jnp


def paged_kv_gather_ref(pool: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(pool, block_table, axis=0)
