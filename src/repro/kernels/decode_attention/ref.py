"""Pure-jnp oracle for paged flash-decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                               *, softcap=None):
    """q [B,KV,G,hd]; pools [n,bt,KV,hd]; tables [B,max_blocks]; lengths [B]."""
    B, KV, G, hd = q.shape
    _, bt, _, _ = k_pool.shape
    max_blocks = block_tables.shape[1]
    scale = 1.0 / (hd ** 0.5)
    outs = []
    for b in range(B):
        k = jnp.take(k_pool, block_tables[b], axis=0)   # [mb, bt, KV, hd]
        v = jnp.take(v_pool, block_tables[b], axis=0)
        k = k.reshape(max_blocks * bt, KV, hd)
        v = v.reshape(max_blocks * bt, KV, hd)
        s = jnp.einsum("kgd,skd->kgs", q[b].astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        pos = jnp.arange(max_blocks * bt)
        s = jnp.where(pos[None, None, :] < lengths[b], s, -1e30)
        w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        outs.append(jnp.einsum("kgs,skd->kgd", w, v.astype(jnp.float32)))
    return jnp.stack(outs).astype(q.dtype)
