"""Paged flash-decode attention kernel: one query token per sequence against
a paged KV cache (block table indirection), online-softmax accumulation.

This is the CU/"kernel-based" side of the paper's KV-fetch comparison
(§5.3.1): instead of DMA-fetching blocks into a contiguous buffer first, a
single kernel walks the dispersed blocks directly (one grid step per block —
the analogue of one workgroup per KV block).

Grid: (batch, kv_heads, max_blocks); scalar-prefetch operands are the block
table and per-sequence lengths.  VMEM scratch carries the running max /
normalizer / accumulator across the block axis (grid iterates row-major, so
the block axis is innermost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    tbl_ref,      # [B, max_blocks] int32 (scalar prefetch)
    len_ref,      # [B] int32 (scalar prefetch)
    q_ref,        # [1, 1, G, hd]
    k_ref,        # [1, bt, 1, hd]
    v_ref,        # [1, bt, 1, hd]
    o_ref,        # [1, 1, G, hd]
    m_scr,        # [G, 1] f32
    l_scr,        # [G, 1] f32
    acc_scr,      # [G, hd] f32
    *,
    block_tokens: int,
    scale: float,
    softcap: float | None,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_blocks = pl.num_programs(2)

    @pl.when(j == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    base = j * block_tokens

    @pl.when(base < length)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)                    # [G, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)                 # [bt, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)                 # [bt, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [G, bt]
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_scr[...]                                    # [G, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                                 # [G, bt]
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,             # [B, KV, G, hd] (grouped query heads)
    k_pool: jax.Array,        # [n_pool, bt, KV, hd]
    v_pool: jax.Array,        # [n_pool, bt, KV, hd]
    block_tables: jax.Array,  # [B, max_blocks] int32
    lengths: jax.Array,       # [B] int32
    *,
    softcap: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Returns attention output [B, KV, G, hd]."""
    B, KV, G, hd = q.shape
    _, bt, _, _ = k_pool.shape
    max_blocks = block_tables.shape[1]
    scale = 1.0 / (hd ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, h, j, tbl, ln: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel, block_tokens=bt, scale=scale,
                               softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q, k_pool, v_pool)
