"""Jit'd wrapper for the paged flash-decode kernel."""
from __future__ import annotations

from functools import partial

import jax

from .decode_attention import paged_decode_attention


@partial(jax.jit, static_argnames=("softcap", "interpret"))
def decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                     softcap=None, interpret: bool = False):
    return paged_decode_attention(q, k_pool, v_pool, block_tables, lengths,
                                  softcap=softcap, interpret=interpret)
