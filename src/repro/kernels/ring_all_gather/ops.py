"""Jit'd wrapper: shard_map-wrapped ring all-gather usable on any mesh axis."""
from __future__ import annotations

from functools import partial

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from .ring_all_gather import make_ring_all_gather


def ring_all_gather(
    x: jax.Array,
    mesh,
    axis_name: str,
    *,
    variant: str = "b2b",        # pcpy | b2b | bcst | bcst_b2b
    interpret: bool = False,
) -> jax.Array:
    """All-gather a [N, F] array sharded on dim 0 over ``axis_name``."""
    n = mesh.shape[axis_name]
    defer = variant in ("b2b", "bcst_b2b")
    bidir = variant.startswith("bcst")
    fn = make_ring_all_gather(axis_name, n, defer_send_sync=defer,
                              bidirectional=bidir, interpret=interpret)
    mapped = shard_map(fn, mesh=mesh, in_specs=P(axis_name, None),
                       out_specs=P(None, None), check_vma=False)
    return jax.jit(mapped)(x)
