"""Pallas TPU ring all-gather over remote DMA — the TPU-native analogue of
the paper's DMA-offloaded all-gather (DESIGN.md §4).

Feature mapping (paper -> kernel flag):
* pcpy  -> per-step full sync (``defer_send_sync=False``): every RDMA waits
           both its send and recv semaphores before the next is issued —
           one "signal" per copy, like one sync command per DMA engine.
* b2b   -> deferred send sync (``defer_send_sync=True``): steps chain on the
           data dependency only (recv); all send completions are drained by
           ONE trailing wait sequence — the single-signal back-to-back
           queue of §4.4.
* bcst  -> bidirectional ring (``bidirectional=True``): each step reads one
           local chunk and issues it to BOTH neighbours (one source read,
           two destinations, §4.2), halving the number of ring steps.
* prelaunch -> send descriptors are issued as soon as their data dependency
           (previous recv) is met, before prior sends complete — issue-ahead
           is inherent to the deferred-sync chain.

Synchronization uses PER-STEP DMA semaphore arrays: a count-based shared
semaphore lets a later arrival satisfy an earlier wait (observed data race
in interpret mode — see tests), per-step semaphores make every wait match
exactly its transfer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pltpu_compiler_params, pltpu_interpret_mode


def _neighbors(axis_name: str, n: int):
    my = jax.lax.axis_index(axis_name)
    right = jax.lax.rem(my + 1, n)
    left = jax.lax.rem(my + n - 1, n)
    return my, left, right


def ring_all_gather_kernel(
    chunk_ref,        # [chunk, F]    local shard (ANY)
    out_ref,          # [n, chunk, F] gathered output (ANY)
    local_sem,        # DMA sem for the local HBM->HBM copy
    send_r, recv_r,   # DMA sem arrays [n-1], rightward stream
    send_l, recv_l,   # DMA sem arrays [n-1], leftward stream
    *,
    axis_name: str,
    num_devices: int,
    defer_send_sync: bool,
    bidirectional: bool,
):
    n = num_devices
    my, left, right = _neighbors(axis_name, n)

    # Neighbour-ready barrier (buffers allocated before anyone writes into
    # them remotely) — the analogue of the doorbell/queue handshake.
    barrier = pltpu.get_barrier_semaphore()
    pltpu.semaphore_signal(barrier, 1, device_id=left)
    pltpu.semaphore_signal(barrier, 1, device_id=right)
    pltpu.semaphore_wait(barrier, 2)

    local = pltpu.make_async_copy(chunk_ref, out_ref.at[my], local_sem)
    local.start()
    local.wait()

    def copy_right(k):    # step k (1-based): forward slot (my-k+1) rightward
        slot = jax.lax.rem(my - k + 1 + n, n)
        return pltpu.make_async_remote_copy(
            src_ref=out_ref.at[slot], dst_ref=out_ref.at[slot],
            send_sem=send_r.at[k - 1], recv_sem=recv_r.at[k - 1], device_id=right)

    def copy_left(k):     # step k: forward slot (my+k-1) leftward
        slot = jax.lax.rem(my + k - 1, n)
        return pltpu.make_async_remote_copy(
            src_ref=out_ref.at[slot], dst_ref=out_ref.at[slot],
            send_sem=send_l.at[k - 1], recv_sem=recv_l.at[k - 1], device_id=left)

    if not bidirectional:
        def body(k, _):
            copy = copy_right(k)
            copy.start()
            if defer_send_sync:
                copy.wait_recv()
            else:
                copy.wait()
            return 0

        jax.lax.fori_loop(1, n, body, 0)
        if defer_send_sync:
            def drain(k, _):
                copy_right(k).wait_send()
                return 0
            jax.lax.fori_loop(1, n, drain, 0)
        return

    # Bidirectional ("bcst"): two streams, half the steps.
    n_right = (n - 1 + 1) // 2     # chunks arriving from the left stream
    n_left = (n - 1) - n_right     # chunks arriving from the right stream

    def body(k, _):
        cr = copy_right(k)
        cl = copy_left(k)

        @pl.when(k <= n_right)
        def _():
            cr.start()

        @pl.when(k <= n_left)
        def _():
            cl.start()

        @pl.when(k <= n_right)
        def _():
            if defer_send_sync:
                cr.wait_recv()
            else:
                cr.wait()

        @pl.when(k <= n_left)
        def _():
            if defer_send_sync:
                cl.wait_recv()
            else:
                cl.wait()
        return 0

    jax.lax.fori_loop(1, n_right + 1, body, 0)
    if defer_send_sync:
        def drain(k, _):
            @pl.when(k <= n_right)
            def _():
                copy_right(k).wait_send()

            @pl.when(k <= n_left)
            def _():
                copy_left(k).wait_send()
            return 0
        jax.lax.fori_loop(1, n_right + 1, drain, 0)


def make_ring_all_gather(
    axis_name: str,
    num_devices: int,
    *,
    defer_send_sync: bool = True,
    bidirectional: bool = False,
    interpret: bool = False,
    collective_id: int = 0,
):
    """Returns fn(local_chunk [chunk, F]) -> [num_devices*chunk, F]; call it
    inside shard_map over ``axis_name``."""
    kernel = functools.partial(
        ring_all_gather_kernel,
        axis_name=axis_name,
        num_devices=num_devices,
        defer_send_sync=defer_send_sync,
        bidirectional=bidirectional,
    )
    n_steps = max(num_devices - 1, 1)

    def fn(chunk: jax.Array) -> jax.Array:
        c, f = chunk.shape
        out = pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((num_devices, c, f), chunk.dtype),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec(memory_space=pl.ANY),
            scratch_shapes=[pltpu.SemaphoreType.DMA]
            + [pltpu.SemaphoreType.DMA((n_steps,))] * 4,
            compiler_params=pltpu_compiler_params(collective_id=collective_id),
            interpret=pltpu_interpret_mode() if interpret else False,
        )(chunk)
        return out.reshape(num_devices * c, f)

    return fn
