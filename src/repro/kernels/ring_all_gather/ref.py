"""Pure-jnp oracle for ring all-gather: given the GLOBAL array [n*chunk, F],
every device's gathered result is simply the global array."""
from __future__ import annotations

import jax.numpy as jnp


def all_gather_ref(global_x: jnp.ndarray, num_devices: int) -> jnp.ndarray:
    """What every device must hold after the collective."""
    assert global_x.shape[0] % num_devices == 0
    return global_x
