"""Deterministic synthetic LM data pipeline.

Generates structured token streams (a mixture of Zipf-distributed unigrams
and copy/induction patterns so a model can actually reduce loss), packs them
into fixed-length sequences, and shards by host.  Deterministic per
(seed, shard, step): resumable without state files.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    shard: int = 0
    n_shards: int = 1
    induction_prob: float = 0.3   # fraction of sequence that is a repeated motif


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, cfg.shard, cfg.n_shards, step]))


def synth_batch(cfg: DataConfig, step: int) -> dict:
    """One packed batch: {'tokens': [B, S] int32}."""
    rng = _batch_rng(cfg, step)
    B, S, V = cfg.batch, cfg.seq_len, cfg.vocab
    # Zipf-ish unigram distribution over a capped working vocab.
    work_v = min(V, 4096)
    ranks = np.arange(1, work_v + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(work_v, size=(B, S), p=probs).astype(np.int32)
    # Induction motifs: copy a random early span later in the sequence.
    motif_len = max(4, S // 16)
    for b in range(B):
        if rng.random() < cfg.induction_prob and S >= 4 * motif_len:
            src = rng.integers(0, S // 2 - motif_len)
            dst = rng.integers(S // 2, S - motif_len)
            toks[b, dst:dst + motif_len] = toks[b, src:src + motif_len]
    return {"tokens": jnp.asarray(toks)}


def data_iterator(cfg: DataConfig, start_step: int = 0):
    step = start_step
    while True:
        yield synth_batch(cfg, step)
        step += 1
