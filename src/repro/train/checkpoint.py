"""Checkpointing: flat-key .npz with pytree structure manifest.

Works for any params/opt-state pytree (dicts/tuples/arrays).  Sharded arrays
are gathered to host before save (single-host container); restore rebuilds
the exact tree and validates shapes/dtypes.
"""
from __future__ import annotations

import json
import os
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, state: Any, *, step: int | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(state)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    manifest = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "step": step,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    np.savez(path, __manifest__=json.dumps(manifest), **arrays)


def restore_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (an abstract or concrete tree)."""
    with np.load(path, allow_pickle=False) as f:
        manifest = json.loads(str(f["__manifest__"]))
        leaves_like, treedef = _flatten(like)
        if manifest["n_leaves"] != len(leaves_like):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves_like)}")
        leaves = []
        for i, ref in enumerate(leaves_like):
            arr = f[f"leaf_{i}"]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != expected {ref.shape}")
            leaves.append(jnp.asarray(arr, dtype=ref.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)
