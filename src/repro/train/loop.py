"""Training step + loop.  ``make_train_step`` builds the pure step function
that the launcher jits under the production mesh; ``train_loop`` is the
single-host driver used by examples/tests (runs real steps on CPU)."""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.transformer import Model, _identity_ac
from .optimizer import AdamWConfig, adamw_update, init_opt_state


def init_train_state(model: Model, rng: jax.Array) -> dict:
    params = model.init(rng)
    return {"params": params, "opt": init_opt_state(params)}


def cast_params_for_compute(params, dtype=jnp.bfloat16):
    """Cast >=2D float32 params to the compute dtype shard-local, so FSDP
    all-gathers move bf16 instead of fp32 (§Perf: halves weight-gather wire
    bytes).  The fp32 master copy stays in the optimizer state."""
    def cast(p):
        if p.dtype == jnp.float32 and p.ndim >= 2:
            return p.astype(dtype)
        return p
    return jax.tree.map(cast, params)


def make_train_step(model: Model, opt_cfg: AdamWConfig, ac: Callable = _identity_ac,
                    unroll: bool = False, cast_params: bool = True):
    def train_step(state: dict, batch: dict):
        def loss_fn(params):
            p = cast_params_for_compute(params) if cast_params else params
            return model.loss(p, batch, ac=ac, unroll=unroll)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        params, opt, metrics = adamw_update(opt_cfg, state["params"], grads, state["opt"])
        metrics = dict(metrics, loss=loss)
        return {"params": params, "opt": opt}, metrics

    return train_step


def train_loop(model: Model, data_iter, *, steps: int, opt_cfg: AdamWConfig | None = None,
               rng: jax.Array | None = None, log_every: int = 10,
               callback: Callable[[int, dict], None] | None = None) -> tuple[dict, list]:
    opt_cfg = opt_cfg or AdamWConfig()
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    state = init_train_state(model, rng)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(i, m)
    return state, history
