"""Hand-rolled AdamW (+ global-norm clipping, cosine LR schedule).

No optax dependency: the optimizer state is a plain pytree (m, v, step) that
inherits the parameters' sharding (ZeRO-style: fully sharded with the FSDP
param specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params: Any) -> dict:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, opt_state: dict):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.beta1, cfg.beta2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt_state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), opt_state["v"], grads)
    sf = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** sf
    c2 = 1.0 - b2 ** sf
    lr = lr_at(cfg, step)

    def upd(p, m_, v_):
        mhat = m_ / c1
        vhat = v_ / c2
        return (p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {"grad_norm": gnorm, "lr": lr}
