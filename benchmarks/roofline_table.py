import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

__doc__ = """Roofline baseline table: per (arch x shape) on the single-pod
mesh, derive the three roofline terms from scan-exact costing lowerings.

    PYTHONPATH=src python -m benchmarks.roofline_table [--arch A --shape S] [--out f.json]
"""

import argparse
import json
import traceback

from repro.sharding.rules import PerfOptions

from repro.configs import ARCH_IDS, INPUT_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.input_specs import skip_reason
from repro.configs import get_config, get_shape
from repro.roofline.analysis import format_table, make_row
from repro.roofline.costing import total_cost


def run(pairs, out=None, baseline=False):
    perf = PerfOptions.baseline() if baseline else PerfOptions()
    mesh = make_production_mesh(multi_pod=False)
    chips = 256
    rows, failures = [], []
    for arch_id, shape_id in pairs:
        if skip_reason(get_config(arch_id), get_shape(shape_id)):
            continue
        try:
            res = total_cost(arch_id, shape_id, mesh, dp_size=16, perf=perf)
            row = make_row(arch_id, shape_id, "16x16", chips, res["total"])
            rows.append(row)
            print(f"[ok] {arch_id} x {shape_id}: comp={row.compute_s*1e3:.3f}ms "
                  f"mem={row.memory_s*1e3:.3f}ms coll={row.collective_s*1e3:.3f}ms "
                  f"dom={row.dominant} useful={row.useful_ratio:.2f}")
        except Exception as e:
            traceback.print_exc()
            failures.append((arch_id, shape_id, str(e)))
    print()
    print(format_table(rows))
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump([r.to_json() for r in rows], f, indent=1)
        print(f"wrote {out}")
    return rows, failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--baseline", action="store_true",
                    help="use pre-hillclimb PerfOptions")
    args = ap.parse_args()
    if args.arch and args.shape:
        pairs = [(args.arch, args.shape)]
    else:
        pairs = [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
    run(pairs, args.out, baseline=args.baseline)


if __name__ == "__main__":
    main()
