"""Figure 16: TTFT speedups for LLM inference with optimized (opt_b2b) DMA
KV fetch vs baseline per-block DMA, at 100% CPU cache hit, prompts
4096/8192.  ``opt_b2b`` is the fetch the serving engine's ``kv_fetch_plan``
actually requests: the batched path on the optimized command stream
(DESIGN.md §7/§8)."""
from __future__ import annotations

from repro.core.serving_model import PAPER_LLMS, ttft
from .common import ClaimChecker


def run(verbose: bool = True):
    rows = []
    for prompt in (4096, 8192):
        for spec in PAPER_LLMS:
            t_p = ttft(spec, prompt, "pcpy")
            t_b = ttft(spec, prompt, "opt_b2b")
            t_k = ttft(spec, prompt, "kernel")
            rows.append((prompt, spec, t_p, t_b, t_k))
    if verbose:
        print("prompt model                  ttft_gpu_speedup  ttft_total_speedup  kernel_vs_b2b")
        for prompt, spec, t_p, t_b, t_k in rows:
            print(f"{prompt:6d} {spec.name:22s} {t_p['gpu']/t_b['gpu']:16.2f} "
                  f"{t_p['total']/t_b['total']:18.2f} {t_b['total']/t_k['total']:13.2f}")
    cc = ClaimChecker("fig16")
    gpu_max = max(r[2]["gpu"] / r[3]["gpu"] for r in rows)
    tot_max = max(r[2]["total"] / r[3]["total"] for r in rows)
    cc.check("max TTFT_GPU speedup (paper: up to 2.29x)", gpu_max, 2.29, 1.75, 2.6)
    cc.check("max TTFT_total speedup (paper: up to 1.5x)", tot_max, 1.5, 1.3, 1.7)
    # smaller models benefit more (paper §5.3.3)
    small_gain = rows[0][2]["gpu"] / rows[0][3]["gpu"]
    big_gain = rows[4][2]["gpu"] / rows[4][3]["gpu"]
    cc.check("small-model gain exceeds big-model gain", float(small_gain > big_gain), 1, 1, 1)
    # longer prompts benefit more
    g4 = rows[0][2]["gpu"] / rows[0][3]["gpu"]
    g8 = rows[5][2]["gpu"] / rows[5][3]["gpu"]
    cc.check("longer prompt increases gain", float(g8 > g4), 1, 1, 1)
    return cc, rows


def main():
    cc, _ = run()
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
