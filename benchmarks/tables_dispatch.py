"""Tables 2 & 3: the paper's size-range dispatch vs the dispatch re-derived
from the calibrated timing model (MI300X) and re-derived for the TPU v5e
topology (what the latte CommBackend actually uses).

``tpu_devices`` parameterizes the TPU slice size (any count
``tpu_dispatch_tables`` accepts); the multi-node hierarchical sweeps
(tpu64/tpu256/mi300x-2node, DESIGN.md §11) live in
``benchmarks/tables_multinode.py``."""
from __future__ import annotations

import argparse

from repro.core.backend import tpu_dispatch_tables
from repro.core.dma import (PAPER_AA_DISPATCH, PAPER_AG_DISPATCH, derive_dispatch,
                            mi300x_platform, paper_dispatch)
from .common import ALL_SIZES, ClaimChecker, fmt_size


def run(verbose: bool = True, tpu_devices: int = 16):
    topo = mi300x_platform()
    cc = ClaimChecker("tables")
    for coll, paper_table in (("all_gather", PAPER_AG_DISPATCH),
                              ("all_to_all", PAPER_AA_DISPATCH)):
        derived = derive_dispatch(topo, coll, ALL_SIZES)
        if verbose:
            print(f"== {coll} ==")
            print("  paper table:")
            for lo, hi, v in paper_table:
                print(f"    [{fmt_size(lo)}, {fmt_size(hi) if hi else 'inf'}) -> {v}")
            print("  derived from model (MI300X):")
            for e in derived:
                print(f"    [{fmt_size(e.lo)}, {fmt_size(e.hi) if e.hi else 'inf'}) -> {e.variant}")
        # agreement on a probe grid (base variant; prelaunch composes with all)
        def strip(v: str) -> str:
            return v.replace("prelaunch_", "")

        agree = 0
        probes = ALL_SIZES
        for s in probes:
            model_v = next(e.variant for e in derived
                           if s >= e.lo and (e.hi is None or s < e.hi))
            if strip(model_v) == strip(paper_dispatch(coll, s)):
                agree += 1
        frac = agree / len(probes)
        cc.check(f"{coll}: derived dispatch agrees with paper table", frac, 1.0, 0.6, 1.0)
    ag, aa, rs, ar = tpu_dispatch_tables(tpu_devices)
    if verbose:
        print(f"== TPU v5e ({tpu_devices} devices) re-derived thresholds "
              "(used by CommBackend('latte')) ==")
        for name, t in (("all_gather", ag), ("all_to_all", aa),
                        ("reduce_scatter", rs), ("all_reduce", ar)):
            for e in t:
                print(f"  {name}: [{fmt_size(e.lo)}, {fmt_size(e.hi) if e.hi else 'inf'}) "
                      f"-> {e.variant}")
    # The v7 tables sweep the full single-node variant space, so the
    # latency-bound winners are optimized command streams (opt_ batching/
    # fused signals dominate where per-command overhead does) rather than
    # the baseline b2b of the baseline-only v6 sweep.
    cc.check("TPU tables open with an optimized stream at the smallest sizes",
             float(ag[0].variant.startswith("opt_")
                   and aa[0].variant.startswith("opt_")), 1, 1, 1)
    cc.check("TPU AG tables carry a pipelined winner at the top (DESIGN.md §9)",
             float("pipe_" in ag[-1].variant), 1, 1, 1)
    cc.check("TPU reduce tables carry a pipelined winner (DESIGN.md §10)",
             float(any("pipe_" in e.variant for e in rs)
                   and any("pipe_" in e.variant for e in ar)), 1, 1, 1)
    return cc, None


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--tpu-devices", type=int, default=16,
                   help="TPU slice size for the re-derived tables "
                        "(default 16, the paper-scale pod)")
    args = p.parse_args()
    cc, _ = run(tpu_devices=args.tpu_devices)
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
