"""TPU-native adaptation benchmark: latte shard_map collectives vs XLA
reference on the local mesh — correctness + wall-clock per call, plus the
modeled step-count reduction of each schedule (the structural win that maps
to the paper's command/sync reduction)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import make_mesh, shard_map
from jax.sharding import PartitionSpec as P

from repro.core import collectives as coll
from .common import ClaimChecker, time_us


def run(verbose: bool = True):
    n = len(jax.devices())
    mesh = make_mesh((n,), ("x",))
    x = jax.random.normal(jax.random.PRNGKey(0), (n * 8, 128), jnp.float32)

    def wrap(fn):
        return jax.jit(shard_map(lambda a: fn(a, "x"), mesh=mesh,
                                 in_specs=P("x", None), out_specs=P(None, None, None),
                                 check_vma=False))

    impls = {
        "reference": wrap(coll.reference_all_gather),
        "ring(b2b)": wrap(coll.ring_all_gather),
        "bidir(bcst)": wrap(coll.bidir_ring_all_gather),
    }
    ref = np.asarray(impls["reference"](x))
    rows = []
    cc = ClaimChecker("tpu_collectives")
    for name, fn in impls.items():
        y = np.asarray(fn(x))
        ok = np.allclose(y, ref)
        us = time_us(lambda: jax.block_until_ready(fn(x)), reps=50, warmup=5)
        rows.append((name, ok, us))
        cc.check(f"{name} correct", float(ok), 1, 1, 1)
    if verbose:
        for name, ok, us in rows:
            print(f"  {name:12s} correct={ok} {us:8.1f} us/call (local CPU mesh)")
        # structural accounting (steps ~ sync rounds on the critical path)
        steps_ring = n - 1
        steps_bidir = (n - 1 + 1) // 2
        if steps_bidir:
            print(f"  ring steps={steps_ring}, bidirectional steps={steps_bidir} "
                  f"({steps_ring/steps_bidir:.1f}x fewer sync rounds — the bcst analogue)")
        else:
            print("  single-device mesh: run under XLA_FLAGS="
                  "--xla_force_host_platform_device_count=N for ring timings")
    return cc, rows


def main():
    cc, _ = run()
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
