"""Benchmark harness: one module per paper table/figure.

Prints a ``name,us_per_call,derived`` CSV row per benchmark (us_per_call is
the mean wall time of one model/simulator evaluation; ``derived`` is the
benchmark's headline derived quantity), then the claim-check report.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time


def main() -> None:
    from . import (calibration, fig01_ag_gap, fig07_copy_breakdown, fig13_allgather,
                   fig14_alltoall, fig15_power, fig16_ttft, fig17_throughput,
                   fig_allreduce, fig_faults, fig_fused_overlap,
                   fig_serving_load, tables_dispatch, tables_multinode,
                   tpu_collectives, trace_export)

    benches = [
        ("calibration", calibration),
        ("fig01_ag_gap", fig01_ag_gap),
        ("fig07_copy_breakdown", fig07_copy_breakdown),
        ("fig13_allgather", fig13_allgather),
        ("fig14_alltoall", fig14_alltoall),
        ("fig_allreduce", fig_allreduce),
        ("fig15_power", fig15_power),
        ("fig16_ttft", fig16_ttft),
        ("fig17_throughput", fig17_throughput),
        ("fig_serving_load", fig_serving_load),
        ("fig_faults", fig_faults),
        ("fig_fused_overlap", fig_fused_overlap),
        ("tables_dispatch", tables_dispatch),
        ("tables_multinode", tables_multinode),
        ("tpu_collectives", tpu_collectives),
        ("trace_export", trace_export),
    ]

    print("name,us_per_call,derived")
    results = []
    for name, mod in benches:
        t0 = time.perf_counter()
        cc, _ = mod.run(verbose=False)
        us = (time.perf_counter() - t0) * 1e6
        n_ok = sum(1 for r in cc.rows if r[5])
        derived = f"{n_ok}/{len(cc.rows)}_claims_ok"
        print(f"{name},{us:.1f},{derived}")
        results.append((name, cc))

    print("\n== claim checks ==")
    all_ok = True
    for name, cc in results:
        print(f"[{name}]")
        if not cc.report():
            all_ok = False
    print("\nALL BENCHMARK CLAIMS OK" if all_ok else "\nSOME CLAIMS OUT OF BAND")
    raise SystemExit(0 if all_ok else 1)


if __name__ == "__main__":
    main()
