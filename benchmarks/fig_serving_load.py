"""Serving under concurrent traffic (DESIGN.md §12): offered-load sweep of
the modeled continuous-batching loop, FIFO vs contention-aware (defer)
admission.

The paper's serving figures (16/17) time ONE request's KV fetch in
isolation; this figure predicts what those offloaded fetches do to each
other under load.  Every round of the loop composes the in-flight KV-fetch
command streams, the decode batch's all-gathers, and MoE all-to-alls into
ONE resource world (``run_composed``), so host-link queueing, engine
sharing and batch-slot head-of-line blocking are emergent — not modeled by
hand.  Reported per offered load: TTFT and TPOT p50/p99 plus goodput
(SLO-meeting output tokens/s) for both admission policies.
"""
from __future__ import annotations

from repro.core.dma.claims import (SERVING_RATES, serving_load_claims,
                                   serving_report, serving_workload)
from .common import ClaimChecker


def run(verbose: bool = True):
    reports = {}
    for rate in SERVING_RATES:
        for admission in ("fifo", "defer"):
            reports[(rate, admission)] = serving_report(rate, admission)
    if verbose:
        n = len(serving_workload(SERVING_RATES[0]))
        print(f"canonical workload: {n} bursty requests, 4096-token prompts, "
              f"4 output tokens, qwen2.5-7b on the MI300X platform")
        print(f"{'rate':>6} {'policy':>6} {'ttft_p50':>9} {'ttft_p99':>9} "
              f"{'tpot_p50':>9} {'tpot_p99':>9} {'goodput':>8} {'thruput':>8} "
              f"{'deferred':>8}")
        for rate in SERVING_RATES:
            for admission in ("fifo", "defer"):
                r = reports[(rate, admission)]
                print(f"{rate:6.0f} {admission:>6} "
                      f"{r.ttft_p50 * 1e3:8.2f}m {r.ttft_p99 * 1e3:8.2f}m "
                      f"{r.tpot_p50 * 1e3:8.2f}m {r.tpot_p99 * 1e3:8.2f}m "
                      f"{r.goodput:8.1f} {r.throughput:8.1f} "
                      f"{r.deferred:8d}")
    cc = ClaimChecker("fig_serving_load")
    for c in serving_load_claims(reports):
        cc.check(c.description, c.model_value, c.paper_value, c.lo, c.hi)
    # Sanity rails on the sweep itself: unloaded end reproduces the
    # single-request regime (both policies identical), and the admission
    # policy never hurts goodput at the low end.
    lo = SERVING_RATES[0]
    same = float(reports[(lo, "fifo")].ttft_p99 == reports[(lo, "defer")].ttft_p99)
    cc.check("admission policies identical when unloaded", same, 1, 1, 1)
    return cc, reports


def main():
    cc, _ = run()
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
