"""Figure 15: total GPU power of the best DMA all-gather vs CU (RCCL):
~32% less power at bandwidth-bound sizes (3.7x less XCD power), 3-4% from
fewer engines (b2b) at 16-64KB, 5-10% from bcst's single source read >1MB.

``--optimized`` additionally prices the §7 command streams (DESIGN.md §8.4:
fewer host wakeups under batched submission, fused signals skipping the
engine's atomic round-trip) and checks the paper's 3-10% additional power
saving at latency-bound sizes."""
from __future__ import annotations

from repro.core.dma import (allgather_schedule, allreduce_schedule,
                            alltoall_schedule, cu_collective_power,
                            dma_collective_power, mi300x_platform, paper_dispatch,
                            rccl_aa_calibration, rccl_ag_calibration,
                            reduce_scatter_schedule, simulate)
from repro.core.dma.rccl_model import rccl_collective_latency
from repro.core.dma.topology import PowerCalibration
from .common import KB, MB, ClaimChecker, fmt_size


def run(verbose: bool = True, optimized: bool = False):
    topo = mi300x_platform()
    rc = rccl_ag_calibration()
    sizes = [16 * KB, 64 * KB, 1 * MB, 4 * MB, 64 * MB, 256 * MB, 1024 * MB]
    rows = []
    for s in sizes:
        v = paper_dispatch("all_gather", s)
        sim = simulate(allgather_schedule(topo, s, v), topo)
        p_dma = dma_collective_power(topo, s, sim)
        p_cu = cu_collective_power(topo, s, rccl_collective_latency(topo, s, rc))
        rows.append((s, v, p_dma, p_cu))
    if verbose:
        print("size   variant           dma_W (xcd/iod/hbm/host)      cu_W (xcd)   saving")
        for s, v, pd, pc in rows:
            print(f"{fmt_size(s):>5} {v:>16} {pd.total:7.1f} ({pd.xcd:5.1f}/{pd.iod:4.1f}/"
                  f"{pd.hbm:5.1f}/{pd.host:4.1f}) {pc.total:8.1f} ({pc.xcd:5.1f}) "
                  f"{1-pd.total/pc.total:7.1%}")

    cc = ClaimChecker("fig15")
    bw = [r for r in rows if r[0] >= 64 * MB]
    saving_bw = sum(1 - r[2].total / r[3].total for r in bw) / len(bw)
    cc.check("power saving at >=64MB (paper ~32%)", saving_bw, 0.32, 0.20, 0.45)
    xcd_ratio = bw[-1][3].xcd / bw[-1][2].xcd
    cc.check("XCD power ratio CU/DMA at BW-bound (paper 3.7x)", xcd_ratio, 3.7, 2.8, 4.6)

    # b2b vs pcpy engines power at 16-64KB (3-4%), bcst savings >1MB (5-10%)
    for s, lo, hi, a, b, paper in ((32 * KB, 0.02, 0.08, "prelaunch_pcpy", "prelaunch_b2b", 0.035),
                                   (2 * MB, 0.03, 0.12, "prelaunch_pcpy", "prelaunch_bcst", 0.075)):
        pa = dma_collective_power(topo, s, simulate(allgather_schedule(topo, s, a), topo)).total
        pb = dma_collective_power(topo, s, simulate(allgather_schedule(topo, s, b), topo)).total
        cc.check(f"{b} saving vs {a} @{fmt_size(s)}", 1 - pb / pa, paper, lo, hi)
    per_collective_power_report(cc, topo, verbose)
    if optimized:
        optimized_power_report(cc, topo, verbose)
    return cc, rows


def per_collective_power_report(cc: ClaimChecker, topo, verbose: bool) -> None:
    """CU-vs-DMA power per collective kind at a bandwidth-bound size.

    The CU power model's HBM payload differs per collective (all_to_all
    moves per-peer shards at the same total bytes; the reduce collectives
    read the local accumulator per arrived chunk — 3x per delivery vs the
    gather collectives' 2x, all_reduce composing RS+AG at 5x over twice
    the wire time), so the savings band is checked per collective instead
    of extrapolating the all-gather number.
    """
    s = 256 * MB
    lat_ag = rccl_collective_latency(topo, s, rccl_ag_calibration())
    lat_aa = rccl_collective_latency(topo, s, rccl_aa_calibration())
    cu = {
        "all_gather": cu_collective_power(topo, s, lat_ag,
                                          collective="all_gather"),
        "all_to_all": cu_collective_power(topo, s, lat_aa,
                                          collective="all_to_all"),
        "reduce_scatter": cu_collective_power(topo, s, lat_ag,
                                              collective="reduce_scatter"),
        # RS + ring-AG composition: same ring wire twice.
        "all_reduce": cu_collective_power(topo, s, 2 * lat_ag,
                                          collective="all_reduce"),
    }
    dma = {
        "all_gather": allgather_schedule(topo, s, paper_dispatch("all_gather", s)),
        "all_to_all": alltoall_schedule(topo, s, paper_dispatch("all_to_all", s)),
        "reduce_scatter": reduce_scatter_schedule(topo, s, "pipe_bidir_ring_rs"),
        "all_reduce": allreduce_schedule(topo, s, "pipe_bidir_ring_rs"),
    }
    savings = {}
    if verbose:
        print("\nCU-vs-DMA power per collective @256MB:")
    for name, sched in dma.items():
        p_dma = dma_collective_power(topo, s, simulate(sched, topo))
        savings[name] = 1 - p_dma.total / cu[name].total
        if verbose:
            print(f"  {name:>15}: cu {cu[name].total:6.1f}W "
                  f"dma {p_dma.total:6.1f}W  saving {savings[name]:6.1%}")
    cc.check("cu-vs-dma saving all_gather @256MB", savings["all_gather"],
             0.39, 0.30, 0.48)
    cc.check("cu-vs-dma saving all_to_all @256MB", savings["all_to_all"],
             0.39, 0.30, 0.48)
    cc.check("cu-vs-dma saving reduce_scatter @256MB",
             savings["reduce_scatter"], 0.49, 0.40, 0.58)
    cc.check("cu-vs-dma saving all_reduce @256MB", savings["all_reduce"],
             0.50, 0.40, 0.60)
    # The payload accounting itself: dynamic HBM power ratios pin the 3x/2x
    # accumulator traffic and the 5x-over-2x-wire-time RS+AG composition.
    hs = PowerCalibration().hbm_static
    dyn = {k: p.hbm - hs for k, p in cu.items()}
    cc.check("cu RS/AG dynamic HBM power (3x vs 2x payload)",
             dyn["reduce_scatter"] / dyn["all_gather"], 1.5, 1.45, 1.55)
    cc.check("cu AR/AG dynamic HBM power (5x payload over 2x wire)",
             dyn["all_reduce"] / dyn["all_gather"], 1.25, 1.20, 1.30)


def optimized_power_report(cc: ClaimChecker, topo, verbose: bool) -> None:
    """Baseline-vs-optimized stream power (DESIGN.md §8.4) + the claim band."""
    from repro.core.dma.claims import optimized_power_claims

    if verbose:
        print("\nbaseline-vs-optimized stream power (same pcpy schedule family):")
        print(f"{'size':>6} {'pcpy_W':>8} {'opt_W':>8} {'saving':>8}  (host wakeups, atomics)")
        for s in (16 * KB, 64 * KB, 256 * KB, 1 * MB):
            base = simulate(allgather_schedule(topo, s, "pcpy"), topo)
            opt = simulate(allgather_schedule(topo, s, "opt_pcpy"), topo)
            pb = dma_collective_power(topo, s, base)
            po = dma_collective_power(topo, s, opt)
            dev = max(base.per_device, key=lambda d: base.per_device[d].total)
            print(f"{fmt_size(s):>6} {pb.total:8.1f} {po.total:8.1f} "
                  f"{1 - po.total / pb.total:8.1%}  "
                  f"({base.host_events[dev]}->{opt.host_events[dev]}, "
                  f"{base.engine_atomics[dev]}->{opt.engine_atomics[dev]})")
    for c in optimized_power_claims(topo):
        cc.check(c.description, c.model_value, c.paper_value, c.lo, c.hi)


def main(argv=None):
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--optimized", action="store_true",
                   help="also price the opt_ command streams (DESIGN.md §8.4) "
                        "and check the paper's 3-10%% additional saving")
    args = p.parse_args(argv)
    cc, _ = run(optimized=args.optimized)
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
