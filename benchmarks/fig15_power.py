"""Figure 15: total GPU power of the best DMA all-gather vs CU (RCCL):
~32% less power at bandwidth-bound sizes (3.7x less XCD power), 3-4% from
fewer engines (b2b) at 16-64KB, 5-10% from bcst's single source read >1MB."""
from __future__ import annotations

from repro.core.dma import (allgather_schedule, cu_collective_power,
                            dma_collective_power, mi300x_platform, paper_dispatch,
                            rccl_ag_calibration, simulate)
from repro.core.dma.rccl_model import rccl_collective_latency
from .common import KB, MB, ClaimChecker, fmt_size


def run(verbose: bool = True):
    topo = mi300x_platform()
    rc = rccl_ag_calibration()
    sizes = [16 * KB, 64 * KB, 1 * MB, 4 * MB, 64 * MB, 256 * MB, 1024 * MB]
    rows = []
    for s in sizes:
        v = paper_dispatch("all_gather", s)
        sim = simulate(allgather_schedule(topo, s, v), topo)
        p_dma = dma_collective_power(topo, s, sim)
        p_cu = cu_collective_power(topo, s, rccl_collective_latency(topo, s, rc))
        rows.append((s, v, p_dma, p_cu))
    if verbose:
        print("size   variant           dma_W (xcd/iod/hbm)      cu_W (xcd)   saving")
        for s, v, pd, pc in rows:
            print(f"{fmt_size(s):>5} {v:>16} {pd.total:7.1f} ({pd.xcd:5.1f}/{pd.iod:4.1f}/"
                  f"{pd.hbm:5.1f}) {pc.total:8.1f} ({pc.xcd:5.1f}) {1-pd.total/pc.total:7.1%}")

    cc = ClaimChecker("fig15")
    bw = [r for r in rows if r[0] >= 64 * MB]
    saving_bw = sum(1 - r[2].total / r[3].total for r in bw) / len(bw)
    cc.check("power saving at >=64MB (paper ~32%)", saving_bw, 0.32, 0.20, 0.45)
    xcd_ratio = bw[-1][3].xcd / bw[-1][2].xcd
    cc.check("XCD power ratio CU/DMA at BW-bound (paper 3.7x)", xcd_ratio, 3.7, 2.8, 4.6)

    # b2b vs pcpy engines power at 16-64KB (3-4%), bcst savings >1MB (5-10%)
    for s, lo, hi, a, b, paper in ((32 * KB, 0.02, 0.08, "prelaunch_pcpy", "prelaunch_b2b", 0.035),
                                   (2 * MB, 0.03, 0.12, "prelaunch_pcpy", "prelaunch_bcst", 0.075)):
        pa = dma_collective_power(topo, s, simulate(allgather_schedule(topo, s, a), topo)).total
        pb = dma_collective_power(topo, s, simulate(allgather_schedule(topo, s, b), topo)).total
        cc.check(f"{b} saving vs {a} @{fmt_size(s)}", 1 - pb / pa, paper, lo, hi)
    return cc, rows


def main():
    cc, _ = run()
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
