"""Multi-node hierarchical dispatch tables (DESIGN.md §11): the v6 bundled
sweeps for the 64/256-device TPU multislices and the 2-node MI300X RDMA
cluster — ``(ag, rs, ar)`` per topology, hier candidates only (all_to_all
has no hierarchical rendering and is deliberately absent).

There is no paper counterpart to agree with (DMA-Latte measures a single
node), so the checks pin the *structure* the model predicts: every winner
is a hierarchical stream, and the pipelined rendering owns the
bandwidth-bound top of each table (the inter-tier overlap claim,
``hier_pipe_overlap_gain``).
"""
from __future__ import annotations

import argparse

from repro.core.backend import MULTINODE_TOPOS, multinode_dispatch_tables
from .common import ClaimChecker, fmt_size

MB = 1024 * 1024


def run(verbose: bool = True, specs: tuple[str, ...] = tuple(MULTINODE_TOPOS)):
    cc = ClaimChecker("tables_multinode")
    for spec in specs:
        ag, rs, ar = multinode_dispatch_tables(spec)
        if verbose:
            print(f"== {spec} hierarchical thresholds (DESIGN.md §11) ==")
            for name, t in (("all_gather", ag), ("reduce_scatter", rs),
                            ("all_reduce", ar)):
                for e in t:
                    print(f"  {name}: [{fmt_size(e.lo)}, "
                          f"{fmt_size(e.hi) if e.hi else 'inf'}) "
                          f"-> {e.variant}"
                          + (f" (chunk {fmt_size(e.chunk)})" if e.chunk else ""))
        all_hier = all("hier_" in e.variant
                       for t in (ag, rs, ar) for e in t)
        cc.check(f"{spec}: every winner is a hierarchical stream",
                 float(all_hier), 1, 1, 1)
        top_pipe = all("hier_pipe" in t[-1].variant for t in (ag, rs, ar))
        cc.check(f"{spec}: pipelined hier stream owns the bandwidth-bound top",
                 float(top_pipe), 1, 1, 1)
    return cc, None


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--spec", choices=sorted(MULTINODE_TOPOS), default=None,
                   help="restrict to one multi-node topology spec")
    args = p.parse_args()
    specs = (args.spec,) if args.spec else tuple(MULTINODE_TOPOS)
    cc, _ = run(specs=specs)
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
