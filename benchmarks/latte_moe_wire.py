import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

__doc__ = """§Perf confirmation experiment: per-layer collective wire bytes of
GSPMD-transparent MoE dispatch vs the hierarchical latte dispatch
(local pack + explicit expert all-to-all) on the production 16x16 mesh,
olmoe-1b-7b geometry, fwd+bwd of one MoE layer.

    PYTHONPATH=src python -m benchmarks.latte_moe_wire
"""

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.latte_moe import latte_moe_local
from repro.launch.mesh import make_production_mesh
from repro.models import moe as moe_mod
from repro.roofline.hlo_parse import wire_bytes_by_kind


def run(verbose: bool = True):
    mesh = make_production_mesh()
    cfg = get_config("olmoe-1b-7b")
    rng = jax.random.PRNGKey(0)
    p_shape = jax.eval_shape(lambda: moe_mod.init_moe(cfg, rng))
    B, S, D = 256, 4096, cfg.d_model
    x_sh = NamedSharding(mesh, P("data", "model", None))
    x_abs = jax.ShapeDtypeStruct((B, S, D), jnp.bfloat16)

    def measure(loss_fn, p_sharding):
        g = jax.grad(loss_fn, argnums=(0, 1))
        with mesh:
            c = jax.jit(g, in_shardings=(p_sharding, x_sh)).lower(p_shape, x_abs).compile()
        w = wire_bytes_by_kind(c.as_text())
        return sum(w.values()), w

    def gspmd_loss(p, x):
        out, aux = moe_mod.apply_moe(cfg, p, x)
        return jnp.sum(out.astype(jnp.float32)) + aux

    p_sh = {"router": NamedSharding(mesh, P(None, None)),
            "wg": NamedSharding(mesh, P("model", "data", None)),
            "wu": NamedSharding(mesh, P("model", "data", None)),
            "wd": NamedSharding(mesh, P("model", None, "data"))}
    wb_gspmd, wk1 = measure(gspmd_loss, p_sh)

    def latte_loss(p, x):
        def body(router, wg, wu, wd, xl):
            b, s, d = xl.shape
            out, aux = latte_moe_local(
                cfg, {"router": router, "wg": wg, "wu": wu, "wd": wd},
                xl.reshape(b * s, d), "model")
            return out.reshape(b, s, d), jax.lax.pmean(aux, "model")

        mapped = shard_map(body, mesh=mesh,
                           in_specs=(P(None, None), P("model", None, None),
                                     P("model", None, None), P("model", None, None),
                                     P("data", "model", None)),
                           out_specs=(P("data", "model", None), P()),
                           check_vma=False)
        out, aux = mapped(p["router"], p["wg"], p["wu"], p["wd"], x)
        return jnp.sum(out.astype(jnp.float32)) + aux

    p_sh2 = {"router": NamedSharding(mesh, P(None, None)),
             "wg": NamedSharding(mesh, P("model", None, None)),
             "wu": NamedSharding(mesh, P("model", None, None)),
             "wd": NamedSharding(mesh, P("model", None, None))}
    wb_latte, wk2 = measure(latte_loss, p_sh2)

    ratio = wb_gspmd / max(wb_latte, 1e-9)
    if verbose:
        print(f"GSPMD dispatch: {wb_gspmd/1e9:7.1f} GB/device  {wk1}")
        print(f"latte dispatch: {wb_latte/1e9:7.1f} GB/device  {wk2}")
        print(f"wire reduction: {ratio:.1f}x")
    assert ratio > 10, f"expected >10x reduction, got {ratio:.1f}x"
    return ratio


def main():
    run()


if __name__ == "__main__":
    main()
