"""Reduce collectives sweep (DESIGN.md §10): reduce-scatter and all-reduce
latency of the ring reduce family across 1KB-4GB, the all-reduce
decomposition gain (composed vs sequential RS-then-AG) on MI300X and the
TPU torus, and the §10 claim bands.

``--pipelined`` additionally prints the chunk-depth sensitivity of
``pipe_bidir_ring_rs`` against its final-chunk-only control arm — the
per-arrived-chunk reduction overlap of arXiv:2512.10236.
"""
from __future__ import annotations

from repro.core.dma import (mi300x_platform, reduce_variants, tpu_v5e_pod,
                            variant_latency)
from repro.core.dma.claims import (PIPE_DEPTH_SWEEP, PIPE_MID_SIZES,
                                   allreduce_decomposition_ratio,
                                   reduce_stream_claims,
                                   rs_pipe_vs_final_chunk_ratio)
from .common import ALL_SIZES, MB, ClaimChecker, fmt_size, geomean

VARIANTS = ("ring_rs", "bidir_ring_rs", "pipe_bidir_ring_rs",
            "opt_prelaunch_pipe_bidir_ring_rs")


def run(verbose: bool = True, pipelined: bool = False):
    mi = mi300x_platform()
    tpu = tpu_v5e_pod(16)
    lat = {v: {} for v in VARIANTS}
    for s in ALL_SIZES:
        for v in VARIANTS:
            lat[v][s] = variant_latency(mi, "reduce_scatter", s, v)
    if verbose:
        print("reduce-scatter, MI300X (speedup vs ring_rs):")
        print(f"{'size':>5} " + "".join(f"{v:>34}" for v in VARIANTS))
        for s in ALL_SIZES:
            print(f"{fmt_size(s):>5} "
                  + "".join(f"{lat['ring_rs'][s] / lat[v][s]:34.2f}"
                            for v in VARIANTS))
        print("\nall-reduce decomposition (sequential RS+AG over composed AR, "
              "DESIGN.md §10):")
        print(f"{'size':>5} {'mi300x':>10} {'tpu16':>10}")
        for s in PIPE_MID_SIZES:
            print(f"{fmt_size(s):>5} "
                  f"{allreduce_decomposition_ratio(mi, s):10.3f} "
                  f"{allreduce_decomposition_ratio(tpu, s):10.3f}")
    if pipelined and verbose:
        print("\nper-chunk vs final-chunk-only signaling of pipe_bidir_ring_rs "
              "(ratio > 1 = reducing each chunk as it lands wins, §10):")
        print(f"{'size':>5} {'topo':>7} "
              + "".join(f"{'depth ' + str(d):>9}" for d in PIPE_DEPTH_SWEEP))
        for topo, name in ((tpu, "tpu16"), (mi, "mi300x")):
            for s in (1 * MB, 4 * MB, 32 * MB):
                row = [f"{fmt_size(s):>5} {name:>7} "]
                for d in PIPE_DEPTH_SWEEP:
                    row.append(f"{rs_pipe_vs_final_chunk_ratio(topo, s, d):9.3f}")
                print("".join(row))

    cc = ClaimChecker("fig_allreduce")
    # Best pipelined vs best non-pipelined RS stream on the torus (where the
    # ring family is the dispatch winner) — the §10 analogue of
    # pipe_midsize_gain; on MI300X's heavier host constants the baseline
    # pipe_ variants lose below ~1MB exactly as in §9.1, so the mid-band
    # claim is pinned on the TPU target.
    rs_all = reduce_variants(tpu)
    pipe_vs = [v for v in rs_all if "pipe_" in v]
    nonpipe_vs = [v for v in rs_all if "pipe_" not in v]
    cc.check("best pipe_ RS over best non-pipe RS, tpu16 1-32MB geomean",
             geomean(min(variant_latency(tpu, "reduce_scatter", s, v)
                         for v in nonpipe_vs)
                     / min(variant_latency(tpu, "reduce_scatter", s, v)
                           for v in pipe_vs)
                     for s in PIPE_MID_SIZES), 1.10, 1.02, 1.4)
    for c in reduce_stream_claims(mi300x=mi, tpu=tpu):
        cc.check(c.description, c.model_value, c.paper_value, c.lo, c.hi)
    return cc, lat


def main(argv=None):
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--pipelined", action="store_true",
                   help="also print the chunk-depth sensitivity of the "
                        "per-chunk-reduced rings (DESIGN.md §10)")
    args = p.parse_args(argv)
    cc, _ = run(pipelined=args.pipelined)
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
