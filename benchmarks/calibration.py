"""Calibration report: every headline paper claim vs the calibrated model.

This is the fitting target set used to set Calibration/RcclCalibration
defaults (fit once by random search over phase constants; the resulting
constants are checked in, this module verifies them)."""
from __future__ import annotations

from repro.core.dma.claims import evaluate_claims
from .common import ClaimChecker


def run(verbose: bool = True):
    cc = ClaimChecker("calibration")
    for c in evaluate_claims():
        cc.check(c.description, c.model_value, c.paper_value, c.lo, c.hi)
    return cc, None


def main():
    cc, _ = run()
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
