"""Figure 17: tokens/s throughput gain with optimized DMA KV fetch at 100%
cache hit (up to 1.9x over baseline; up to 1.3x over kernel-based fetch),
plus the hit-rate sweep direction (benefits shrink as hit% drops).  The
optimized column is ``opt_b2b`` — the serving engine's planned fetch
(batched path + optimized command stream, DESIGN.md §7/§8)."""
from __future__ import annotations

from repro.core.serving_model import PAPER_LLMS, throughput
from .common import ClaimChecker


def run(verbose: bool = True):
    rows = []
    for prompt in (4096, 8192):
        for spec in PAPER_LLMS:
            tp = {b: throughput(spec, prompt, b)
                  for b in ("pcpy", "opt_b2b", "kernel")}
            rows.append((prompt, spec, tp))
    if verbose:
        print("prompt model              opt_b2b/pcpy  opt_b2b/kernel")
        for prompt, spec, tp in rows:
            print(f"{prompt:6d} {spec.name:22s} {tp['opt_b2b']/tp['pcpy']:8.2f} "
                  f"{tp['opt_b2b']/tp['kernel']:10.2f}")
    cc = ClaimChecker("fig17")
    up_max = max(tp["opt_b2b"] / tp["pcpy"] for _, _, tp in rows)
    vk_max = max(tp["opt_b2b"] / tp["kernel"] for _, _, tp in rows)
    cc.check("max throughput gain (paper: up to 1.9x)", up_max, 1.9, 1.5, 2.1)
    cc.check("max gain vs kernel fetch (paper: up to 1.3x)", vk_max, 1.3, 1.15, 1.45)
    # throughput gains exceed TTFT gains (paper: overlap effect)
    from repro.core.serving_model import ttft
    spec = PAPER_LLMS[3]
    tt = ttft(spec, 4096, "pcpy")["total"] / ttft(spec, 4096, "opt_b2b")["total"]
    tp = throughput(spec, 4096, "opt_b2b") / throughput(spec, 4096, "pcpy")
    cc.check("throughput gain exceeds TTFT gain (llama3.1-8b)", float(tp > tt), 1, 1, 1)
    # hit-rate sweep: gains shrink with more prefill work
    g100 = throughput(spec, 4096, "opt_b2b", hit_rate=1.0) / throughput(spec, 4096, "pcpy", hit_rate=1.0)
    g50 = throughput(spec, 4096, "opt_b2b", hit_rate=0.5) / throughput(spec, 4096, "pcpy", hit_rate=0.5)
    cc.check("gain shrinks at 50% hit rate", float(g50 < g100), 1, 1, 1)
    return cc, rows


def main():
    cc, _ = run()
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
