"""Figure 7: latency breakdown of a single DMA copy (control / schedule /
copy / sync) across sizes 4KB-2MB; non-copy phases up to ~60% at the
smallest sizes, <20% only above 1MB."""
from __future__ import annotations

from repro.core.dma import mi300x_platform, single_copy_breakdown
from .common import KB, MB, ClaimChecker


def run(verbose: bool = True):
    topo = mi300x_platform()
    sizes = [4 * KB, 16 * KB, 64 * KB, 256 * KB, 1 * MB, 2 * MB]
    rows = []
    for s in sizes:
        b = single_copy_breakdown(s, topo)
        rows.append((s, b))
    if verbose:
        print("size     control  schedule  copy     sync     noncopy%")
        for s, b in rows:
            print(f"{s >> 10:5d}KB {b.control*1e6:8.2f} {b.schedule*1e6:9.2f} "
                  f"{b.copy*1e6:8.2f} {b.sync*1e6:8.2f} {b.noncopy_fraction:8.1%}")
    cc = ClaimChecker("fig07")
    b4k = rows[0][1]
    b2m = rows[-1][1]
    cc.check("noncopy fraction @4KB (paper ~60%)", b4k.noncopy_fraction, 0.60, 0.45, 0.75)
    cc.check("noncopy fraction @2MB (paper <20%)", b2m.noncopy_fraction, 0.15, 0.02, 0.20)
    ordering = b4k.copy > b4k.schedule and b4k.copy > b4k.sync and b4k.sync > b4k.control
    cc.check("phase ordering copy>schedule~sync>>control", float(ordering), 1.0, 1.0, 1.0)
    return cc, rows


def main():
    cc, _ = run()
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
