"""Fault injection, retry recovery and degraded-mode serving (DESIGN.md §13).

The paper measures DMA collectives on healthy hardware; this figure asks
what the modeled offload does when hardware misbehaves — the robustness
story a production offload needs.  Four panels, all driven by the seeded
deterministic fault layer (``repro.core.dma.faults``):

* **Graceful degradation** — the same ``pipe_b2b`` all-gather queues under
  per-chunk vs final-chunk-only signaling, clean and under a 4x straggler
  engine: per-chunk signaling degrades more gracefully because downstream
  devices keep consuming the straggler's early chunks (``fault_pipe_grace``
  / ``fault_pipe_gap`` claim bands).
* **Watchdog/retry** — latency overhead of small random signal-drop rates:
  each lost doorbell costs ~one watchdog expiry plus a re-issued command,
  recovered within ``max_attempts`` (``fault_retry_overhead`` /
  ``fault_retry_recovery``).
* **Dispatch robustness** — winner stability of the TPU-torus all-gather
  sweep under calibration drift and a straggler (§13.5): fragile entries
  cluster at the latency-to-bandwidth crossover, and the worst regret of
  shipping a stale winner is bounded.
* **Degraded-mode serving** — the §12 loop under a permanent straggler
  (ride through; FIFO) and a transient host-link outage (fault-aware defer
  admission pushes launches past the window — ``serving_fault_tail`` /
  ``serving_outage_defer_gain``).

``--check`` (CI) runs the claim bands without the tables and exits nonzero
on any violation.
"""
from __future__ import annotations

import argparse

from repro.core.dma import simulate
from repro.core.dma.claims import (FAULT_DEPTH, FAULT_SLOWDOWN,
                                   SERVING_FAULT_RATE, fault_degradation_arms,
                                   fault_degradation_claims,
                                   fault_retry_claims, serving_fault_claims,
                                   serving_fault_report, serving_outage_plan)
from repro.core.dma.collectives import allgather_schedule
from repro.core.dma.dispatch import dispatch_robustness
from repro.core.dma.faults import FaultPlan, straggler_plan
from repro.core.dma.topology import tpu_v5e_pod

from .common import KB, MB, ClaimChecker, fmt_size

#: Size grid of the dispatch-robustness audit: dense around the
#: latency-to-bandwidth crossover (where winners actually flip — a coarse
#: grid reports false stability), sparse in the bandwidth-bound tail.
ROBUST_SIZES = [64 * KB, 128 * KB, 256 * KB, 512 * KB,
                1 * MB, 2 * MB, 8 * MB, 32 * MB]

#: Drop-rate sweep of the retry panel (the claim band pins the smallest).
DROP_RATES = (0.005, 0.01, 0.02)


def run(verbose: bool = True):
    topo = tpu_v5e_pod(16)
    cc = ClaimChecker("fig_faults")

    # -- graceful degradation under a straggler ---------------------------
    arms = fault_degradation_arms(topo)
    if verbose:
        print(f"pipe_b2b AG depth {FAULT_DEPTH}, device-0 straggler "
              f"x{FAULT_SLOWDOWN:g}, TPU v5e 16 (per-chunk vs "
              f"final-chunk-only signaling; grace = relative degradation):")
        print(f"{'size':>5} {'pipe_clean':>11} {'pipe_fault':>11} "
              f"{'fco_clean':>11} {'fco_fault':>11} {'grace':>7} {'gap':>7}")
        for size, a in arms.items():
            grace = ((a["fco_faulted"] / a["fco_clean"])
                     / (a["pipe_faulted"] / a["pipe_clean"]))
            gap = a["fco_faulted"] / a["pipe_faulted"]
            print(f"{fmt_size(size):>5} "
                  f"{a['pipe_clean'] * 1e6:10.1f}u {a['pipe_faulted'] * 1e6:10.1f}u "
                  f"{a['fco_clean'] * 1e6:10.1f}u {a['fco_faulted'] * 1e6:10.1f}u "
                  f"{grace:7.3f} {gap:7.3f}")
    for c in fault_degradation_claims(topo, arms):
        cc.check(c.description, c.model_value, c.paper_value, c.lo, c.hi)

    # -- watchdog/retry recovery ------------------------------------------
    sched = allgather_schedule(topo, 8 * MB, "pipe_b2b", pipe_depth=FAULT_DEPTH)
    clean = simulate(sched, topo)
    if verbose:
        print("\nsignal-drop recovery, pipe_b2b AG 8MB depth 4 (watchdog "
              "re-issue with exponential backoff, DESIGN.md §13.2):")
        print(f"{'drop':>6} {'latency':>10} {'overhead':>9} {'dropped':>8} "
              f"{'retries':>8} {'recovered':>9}")
        for dr in DROP_RATES:
            r = simulate(sched, topo, faults=FaultPlan(drop_rate=dr))
            rep = r.fault_report
            print(f"{dr:6.3f} {r.latency * 1e6:9.1f}u "
                  f"{r.latency / clean.latency:9.3f} {len(rep.dropped):8d} "
                  f"{len(rep.retries):8d} {rep.recovered:9d}")
    for c in fault_retry_claims(topo):
        cc.check(c.description, c.model_value, c.paper_value, c.lo, c.hi)
    # Sanity rail: the no-fault identity is structural — an empty plan is
    # normalized away and the result carries no fault report (§13.1).
    empty = simulate(sched, topo, faults=FaultPlan())
    same = float(empty.latency == clean.latency
                 and empty.fault_report is None)
    cc.check("empty FaultPlan bit-identical to fault-free run", same, 1, 1, 1)

    # -- dispatch robustness (§13.5) --------------------------------------
    rob = dispatch_robustness(topo, "all_gather", ROBUST_SIZES,
                              allow_optimized=True, allow_pipelined=True)
    if verbose:
        print(f"\ndispatch robustness, TPU AG sweep x {len(rob.scenarios)} "
              f"scenarios ({', '.join(rob.scenarios)}):")
        print(f"  {rob.n_fragile}/{rob.n_points} fragile points, "
              f"max regret {rob.max_regret:.3f}x")
        for f in rob.fragile:
            print(f"  {fmt_size(f.size):>5} {f.scenario:>15}: "
                  f"{f.base_variant} -> {f.new_variant} "
                  f"(regret {f.regret:.3f}x)")
    cc.check("fragile dispatch entries at the crossover (audit detects flips)",
             rob.n_fragile, 3, 1, 10)
    cc.check("fragile fraction of the audited sweep",
             rob.fragile_fraction, 0.06, 0.0, 0.25)
    cc.check("max regret of shipping a stale winner",
             rob.max_regret, 1.81, 1.1, 2.3)

    # -- degraded-mode serving (§13.4) ------------------------------------
    serving_arms = (("clean", "fifo", None),
                    ("straggler", "fifo", straggler_plan(0, FAULT_SLOWDOWN)),
                    ("outage", "fifo", serving_outage_plan(SERVING_FAULT_RATE)),
                    ("outage", "defer", serving_outage_plan(SERVING_FAULT_RATE)))
    reports = {(kind, admission): serving_fault_report(
        SERVING_FAULT_RATE, admission, plan)
        for kind, admission, plan in serving_arms}
    if verbose:
        print(f"\ndegraded-mode serving, {SERVING_FAULT_RATE:.0f} req/s "
              f"(straggler ridden through, transient outage deferred past):")
        print(f"{'fault':>10} {'policy':>6} {'ttft_p50':>9} {'ttft_p99':>9} "
              f"{'goodput':>8} {'deferred':>8}")
        for kind, admission, _ in serving_arms:
            r = reports[(kind, admission)]
            print(f"{kind:>10} {admission:>6} "
                  f"{r.ttft_p50 * 1e3:8.2f}m {r.ttft_p99 * 1e3:8.2f}m "
                  f"{r.goodput:8.1f} {r.deferred:8d}")
    for c in serving_fault_claims(reports):
        cc.check(c.description, c.model_value, c.paper_value, c.lo, c.hi)
    # Sanity rail: deferring never hurts goodput under the outage.
    gain = (reports[("outage", "defer")].goodput
            / reports[("outage", "fifo")].goodput)
    cc.check("defer goodput gain under transient outage", gain, 1.34, 1.0, 2.0)
    return cc, reports


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--check", action="store_true",
                   help="CI claim guard: skip the tables, exit nonzero when "
                        "any §13 claim band is violated")
    args = p.parse_args(argv)
    cc, _ = run(verbose=not args.check)
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
