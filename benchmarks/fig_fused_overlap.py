"""Fused compute-collective overlap (DESIGN.md §15): GEMM+reduce-scatter
and all-gather+GEMM schedules whose tile/chunk gating lets collective
chunks hide under the CU tile stream, vs the sequential control arm (same
command stream, gates coarsened to the final tile / final arrival).

Checks the named claim bands of ``fused_overlap_claims``: bandwidth-bound
overlap gain on both fabrics, the exposed-comm fraction left after fusing,
and the reduce-placement crossover (CU-side epilogue wins small, engine-side
wins large, à la arXiv:2512.10236) — plus that the ``allow_fused`` dispatch
sweep actually renders that crossover as a size band on MI300X.
"""
from __future__ import annotations

from repro.core.dma import mi300x_platform, tpu_v5e_pod
from repro.core.dma.claims import fused_overlap_claims
from repro.core.dma.dispatch import (derive_dispatch, pick_variant,
                                     variant_latency)
from .common import KB, MB, ClaimChecker, fmt_size

SIZES = [16 * KB, 256 * KB, 1 * MB, 16 * MB, 64 * MB, 256 * MB, 1024 * MB]


def run(verbose: bool = True):
    mi, tpu = mi300x_platform(), tpu_v5e_pod(16)
    if verbose:
        for name, topo in (("mi300x", mi), ("tpu16", tpu)):
            print(f"{name}: GEMM+RS latency (us) and overlap gain")
            print(f"{'size':>6} {'seq':>10} {'fused_cu':>10} {'fused_eng':>10}"
                  f" {'gain':>6}  {'ag_seq':>10} {'ag_fused':>10} {'gain':>6}")
            for s in SIZES:
                seq = variant_latency(topo, "fused_gemm_rs", s, "seq")
                cu = variant_latency(topo, "fused_gemm_rs", s, "fused_cu_d4")
                eng = variant_latency(topo, "fused_gemm_rs", s,
                                      "fused_engine_d4")
                aseq = variant_latency(topo, "fused_ag_gemm", s, "seq")
                af = variant_latency(topo, "fused_ag_gemm", s, "fused_d4")
                print(f"{fmt_size(s):>6} {seq*1e6:10.2f} {cu*1e6:10.2f} "
                      f"{eng*1e6:10.2f} {seq/min(cu, eng):6.3f} "
                      f"{aseq*1e6:10.2f} {af*1e6:10.2f} {aseq/af:6.3f}")
            print()

    cc = ClaimChecker("fig_fused_overlap")
    for c in fused_overlap_claims(mi, tpu):
        cc.check(c.description, c.model_value, c.paper_value, c.lo, c.hi)

    # The dispatch sweep must render the placement crossover as a size
    # band, not just two cherry-picked points: some swept size dispatches
    # to a CU-placed variant and some larger size to an engine-placed one.
    entries = derive_dispatch(mi, "fused_gemm_rs", SIZES, allow_fused=True,
                              allow_prelaunch=False)
    winners = {s: pick_variant(entries, s) for s in SIZES}
    if verbose:
        print("mi300x fused_gemm_rs dispatch (allow_fused sweep):")
        for s in SIZES:
            print(f"  {fmt_size(s):>6} -> {winners[s]}")
    cu_sizes = [s for s in SIZES if "_cu_" in winners[s]]
    eng_sizes = [s for s in SIZES if "_engine_" in winners[s]]
    cc.check("dispatch renders a cu-placement band (n sizes)",
             float(len(cu_sizes)), 2.0, 1.0, float(len(SIZES) - 1))
    cc.check("dispatch renders an engine-placement band (n sizes)",
             float(len(eng_sizes)), 3.0, 1.0, float(len(SIZES) - 1))
    if cu_sizes and eng_sizes:
        cc.check("cu band sits below the engine band",
                 1.0 if max(cu_sizes) < min(eng_sizes) else 0.0,
                 1.0, 0.5, 1.5)
    return cc, winners


def main(argv=None):
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--check", action="store_true",
                   help="suppress the tables, only report the claim bands")
    args = p.parse_args(argv)
    cc, _ = run(verbose=not args.check)
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
