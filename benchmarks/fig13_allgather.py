"""Figure 13 + Table 2: all-gather speedup of every DMA variant vs RCCL
across 1KB-4GB, and the per-range winning implementation.

``--optimized`` additionally sweeps the optimized command streams
(DESIGN.md §7: batched submission, SDMA queue slots, fused write+signal),
emits the baseline-vs-optimized curves, and checks the paper's
optimized-collective claim bands (~30% slower than RCCL at small sizes,
~7% gain at large sizes).

``--pipelined`` adds the per-chunk-signaled pipelined ring curves
(DESIGN.md §9), the chunk-depth sensitivity against final-chunk-only
signaling, and the §9 claim bands.

``--hierarchical`` swaps in the 2-node MI300X RDMA cluster (DESIGN.md §11)
and emits the flat-vs-hierarchical all-gather curves — flat ring, direct
fan-out, ``hier_ring``, ``hier_pipe`` — plus the §11 claim bands
(``hier_ag_nic_gain``, ``hier_pipe_overlap_gain``).
"""
from __future__ import annotations

from repro.core.dma import (allgather_schedule, derive_dispatch, mi300x_platform,
                            paper_dispatch, rccl_ag_calibration, simulate)
from repro.core.dma.claims import hierarchical_stream_claims
from repro.core.dma.dispatch import variant_latency
from repro.core.dma.rccl_model import rccl_collective_latency
from repro.core.dma.topology import mi300x_cluster
from .common import (ALL_SIZES, MB, SMALL_SIZES, ClaimChecker, fmt_size,
                     geomean, optimized_report, pipelined_report)

VARIANTS = ("pcpy", "bcst", "b2b", "prelaunch_pcpy", "prelaunch_bcst", "prelaunch_b2b")
OPT_VARIANTS = tuple(f"opt_{v}" for v in VARIANTS)


#: --hierarchical curve variants: the two flat streams the cluster could run
#: unchanged vs the two-tier decompositions (DESIGN.md §11).
HIER_VARIANTS = ("ring", "pcpy", "hier_ring", "hier_pipe")


def hierarchical_report(cc: ClaimChecker, verbose: bool) -> None:
    """Flat-vs-hierarchical AG curves on the 2-node MI300X cluster plus the
    §11 claim bands.  Sizes start at 1MB: below that the comparison is a
    NIC-latency shootout the claims don't cover, and the flat streams run
    the full (non-symmetric) event loop, so the probe grid stays modest."""
    cluster = mi300x_cluster(2)
    sizes = [s for s in ALL_SIZES if s >= 1 * MB]
    lat = {v: {s: variant_latency(cluster, "all_gather", s, v) for s in sizes}
           for v in HIER_VARIANTS}
    if verbose:
        print(f"\n== hierarchical all-gather, {cluster.name} "
              "(speedup vs flat ring, DESIGN.md §11) ==")
        print("size   " + "".join(f"{v:>12}" for v in HIER_VARIANTS))
        for s in sizes:
            print(f"{fmt_size(s):>5} "
                  + "".join(f"{lat['ring'][s] / lat[v][s]:12.2f}"
                            for v in HIER_VARIANTS))
    for claim in hierarchical_stream_claims(cluster):
        cc.check(claim.description, claim.model_value, claim.paper_value,
                 claim.lo, claim.hi)


def run(verbose: bool = True, optimized: bool = False, pipelined: bool = False,
        hierarchical: bool = False):
    topo = mi300x_platform()
    rc = rccl_ag_calibration()
    variants = VARIANTS + OPT_VARIANTS if optimized else VARIANTS
    lat = {v: {} for v in variants}
    util = {v: {} for v in variants}      # busiest-link wire utilization
    rccl = {}
    for s in ALL_SIZES:
        rccl[s] = rccl_collective_latency(topo, s, rc)
        for v in variants:
            sim = simulate(allgather_schedule(topo, s, v), topo)
            lat[v][s] = sim.latency
            links = [k for k in sim.busy if k.startswith("link:")]
            util[v][s] = max((sim.utilization(k) for k in links), default=0.0)
    if verbose:
        print("size   " + "".join(f"{v:>16}" for v in VARIANTS) + "   (speedup vs RCCL)")
        for s in ALL_SIZES:
            print(f"{fmt_size(s):>5} " + "".join(f"{rccl[s]/lat[v][s]:16.2f}" for v in VARIANTS))
        print("\nbusiest-link wire utilization (event timelines; non-copy "
              "overhead is why latency-bound sizes sit far below 1.0):")
        for s in (4096, 1 * MB, 256 * MB):
            print(f"{fmt_size(s):>5} " + "".join(f"{util[v][s]:16.2f}" for v in VARIANTS))

    cc = ClaimChecker("fig13")
    sub1m = [s for s in SMALL_SIZES if s < 1 * MB]
    upto4m = [s for s in SMALL_SIZES if s <= 4 * MB]
    cc.check("bcst over pcpy <=4MB (paper 1.7x)",
             geomean(lat["pcpy"][s] / lat["bcst"][s] for s in upto4m), 1.7, 1.35, 2.05)
    cc.check("b2b over pcpy <1MB (paper 2.7x)",
             geomean(lat["pcpy"][s] / lat["b2b"][s] for s in sub1m), 2.7, 2.1, 3.3)
    cc.check("b2b over bcst <1MB (paper 1.5x)",
             geomean(lat["bcst"][s] / lat["b2b"][s] for s in sub1m), 1.5, 1.25, 1.85)
    cc.check("prelaunch on pcpy (paper 1.9x)",
             geomean(lat["pcpy"][s] / lat["prelaunch_pcpy"][s] for s in ALL_SIZES),
             1.9, 1.55, 2.25)
    cc.check("optimized geomean vs RCCL <32MB (paper 1.3x slower)",
             geomean(min(lat[v][s] for v in VARIANTS) / rccl[s] for s in SMALL_SIZES),
             1.3, 1.0, 1.55)
    cc.check("pcpy speedup >32MB (paper 1.14x)",
             geomean(rccl[s] / lat["prelaunch_pcpy"][s] for s in ALL_SIZES if s > 32 * MB),
             1.2, 1.05, 1.45)

    # Table 2: derived dispatch should match the paper's winners per range
    table = derive_dispatch(topo, "all_gather", ALL_SIZES)
    if verbose:
        print("\nDerived dispatch (cf. paper Table 2):")
        for e in table:
            hi = fmt_size(e.hi) if e.hi else "inf"
            print(f"  [{fmt_size(e.lo)}, {hi}) -> {e.variant}")
    probe = {4096: "prelaunch_b2b", 512 * 1024: "prelaunch_bcst",
             64 * MB: "prelaunch_pcpy"}
    agree = sum(paper_dispatch("all_gather", s) ==
                next(v for v in [e.variant for e in table if s >= e.lo and (e.hi is None or s < e.hi)])
                for s in probe)
    cc.check("derived dispatch matches Table 2 on probe sizes", agree, 3, 2, 3)
    if optimized:
        optimized_report(cc, topo, "all_gather", lat, rccl, verbose)
    if pipelined:
        pipelined_report(cc, topo, "all_gather", lat, rccl, verbose)
    if hierarchical:
        hierarchical_report(cc, verbose)
    return cc, lat


def main(argv=None):
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--optimized", action="store_true",
                   help="also sweep the opt_ command streams (DESIGN.md §7) "
                        "and emit baseline-vs-optimized curves")
    p.add_argument("--pipelined", action="store_true",
                   help="also sweep the per-chunk-signaled pipelined rings "
                        "(DESIGN.md §9) and check the §9 claim bands")
    p.add_argument("--hierarchical", action="store_true",
                   help="also emit the flat-vs-hierarchical curves on the "
                        "2-node MI300X cluster (DESIGN.md §11) and check "
                        "the §11 claim bands")
    args = p.parse_args(argv)
    cc, _ = run(optimized=args.optimized, pipelined=args.pipelined,
                hierarchical=args.hierarchical)
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
