"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import math
import time

KB = 1024
MB = 1024 * 1024
GB = 1024 * 1024 * 1024

SMALL_SIZES = [2 ** i for i in range(10, 26)]   # 1KB..32MB
LARGE_SIZES = [2 ** i for i in range(26, 33)]   # 64MB..4GB
ALL_SIZES = SMALL_SIZES + LARGE_SIZES


def geomean(xs):
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def fmt_size(s: int) -> str:
    if s >= GB:
        return f"{s // GB}G"
    if s >= MB:
        return f"{s // MB}M"
    return f"{s // KB}K"


def time_us(fn, *args, reps: int = 200, warmup: int = 20) -> float:
    """Wall-clock microseconds per call (for CSV reporting)."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args)
    return (time.perf_counter() - t0) / reps * 1e6


#: Chunk granularities the ``--optimized`` figure sweeps offer the argmin
#: (DESIGN.md §8.1): the calibrated hardware ceiling (None) plus finer splits.
CHUNK_SWEEP = (None, 2 * MB, 1 * MB)


def optimized_report(cc: "ClaimChecker", topo, collective: str,
                     lat: dict, rccl: dict, verbose: bool) -> None:
    """Shared ``--optimized`` tail for fig13/fig14: baseline-vs-optimized
    curve, chunk-size sensitivity at GB scale, re-derived dispatch with the
    ``opt_`` streams over (variant, chunk) pairs (DESIGN.md §7/§8), and the
    optimized claim bands for ``collective``."""
    from repro.core.dma import derive_dispatch, variant_latency
    from repro.core.dma.claims import optimized_stream_claims

    base_vs = {v for v in lat if not v.startswith("opt_")}
    opt_vs = {v for v in lat if v.startswith("opt_")}
    if verbose:
        print("\nbaseline-vs-optimized (speedup vs RCCL; gain = best-opt/best-base):")
        print(f"{'size':>5} {'best-baseline':>16} {'best-optimized':>16} {'gain':>7}")
        for s in ALL_SIZES:
            b = min(lat[v][s] for v in base_vs)
            o = min(lat[v][s] for v in opt_vs)
            print(f"{fmt_size(s):>5} {rccl[s]/b:16.2f} {rccl[s]/o:16.2f} {b/o:7.2f}")
        chunks = [c for c in (512 * KB, 1 * MB, 2 * MB, 4 * MB)
                  if c <= topo.calib.max_chunk_bytes]
        print("\nchunk-size sensitivity (opt gain = pcpy/opt_pcpy per "
              "max_chunk_bytes, DESIGN.md §8.1):")
        print(f"{'size':>5} " + "".join(f"{fmt_size(c):>10}" for c in chunks))
        for s in (256 * MB, 1 * GB, 4 * GB):
            row = []
            for ch in chunks:
                b = variant_latency(topo, collective, s, "pcpy", ch)
                o = variant_latency(topo, collective, s, "opt_pcpy", ch)
                row.append(b / o)
            print(f"{fmt_size(s):>5} " + "".join(f"{g:10.3f}" for g in row))
        table = derive_dispatch(topo, collective, ALL_SIZES,
                                allow_optimized=True, chunk_sizes=CHUNK_SWEEP)
        print("\nDerived dispatch with optimized streams + chunk sweep "
              "(DESIGN.md §7/§8):")
        for e in table:
            hi = fmt_size(e.hi) if e.hi else "inf"
            ch = "calib" if e.chunk is None else fmt_size(e.chunk)
            print(f"  [{fmt_size(e.lo)}, {hi}) -> {e.variant} (chunk {ch})")
    for c in optimized_stream_claims(topo, collectives=(collective,)):
        cc.check(c.description, c.model_value, c.paper_value, c.lo, c.hi)


def pipelined_report(cc: "ClaimChecker", topo, collective: str,
                     lat: dict, rccl: dict, verbose: bool) -> None:
    """Shared ``--pipelined`` tail for fig13/fig14 (DESIGN.md §9): the
    per-chunk-signaled ring curves on the figure's MI300X topology, the
    chunk-depth sensitivity of ``pipe_b2b`` against its final-chunk-only
    control arm, and the §9 claim bands (pinned on the TPU torus, where the
    ring family is the dispatch winner — see ``claims.pipelined_stream_claims``)."""
    from repro.core.dma import simulate
    from repro.core.dma.claims import (PIPE_DEPTH_SWEEP, pipe_vs_final_chunk_ratio,
                                       pipelined_stream_claims)
    from repro.core.dma.collectives import allgather_schedule, alltoall_schedule

    builder = allgather_schedule if collective == "all_gather" else alltoall_schedule
    pipe_vs = ("pipe_b2b", "pipe_bidir_ring", "opt_pipe_bidir_ring",
               "prelaunch_pipe_bidir_ring") if collective == "all_gather" \
        else ("pipe_b2b", "opt_pipe_b2b")
    if verbose:
        print("\npipelined ring streams (speedup vs RCCL; ring = chained "
              "final-chunk-signaling baseline):")
        print(f"{'size':>5} {'ring':>10} " + "".join(f"{v:>26}" for v in pipe_vs))
        for s in ALL_SIZES:
            ring = simulate(builder(topo, s, "ring"), topo).latency
            row = [f"{fmt_size(s):>5} {rccl[s]/ring:10.2f}"]
            for v in pipe_vs:
                row.append(f"{rccl[s]/simulate(builder(topo, s, v), topo).latency:26.2f}")
            print("".join(row))
        print("\nper-chunk vs final-chunk-only signaling of pipe_b2b "
              "(ratio > 1 = per-chunk wins; saturates at the wire floor, "
              "DESIGN.md §9.1):")
        print(f"{'size':>5} " + "".join(f"{'depth ' + str(d):>9}" for d in PIPE_DEPTH_SWEEP))
        for s in (1 * MB, 4 * MB, 32 * MB):
            row = [f"{fmt_size(s):>5} "]
            for d in PIPE_DEPTH_SWEEP:
                row.append(f"{pipe_vs_final_chunk_ratio(topo, s, d, collective=collective):9.3f}")
            print("".join(row))
    for c in pipelined_stream_claims(collectives=(collective,)):
        cc.check(c.description, c.model_value, c.paper_value, c.lo, c.hi)


class ClaimChecker:
    def __init__(self, name: str):
        self.name = name
        self.rows: list[tuple[str, float, float, float, float, bool]] = []

    def check(self, label: str, value: float, paper: float, lo: float, hi: float):
        ok = lo <= value <= hi
        self.rows.append((label, value, paper, lo, hi, ok))
        return ok

    def report(self) -> bool:
        all_ok = True
        for label, v, p, lo, hi, ok in self.rows:
            mark = "OK  " if ok else "FAIL"
            if not ok:
                all_ok = False
            print(f"  [{mark}] {label}: model={v:.3f} paper={p:.3f} band=[{lo},{hi}]")
        return all_ok
