"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import math
import time

KB = 1024
MB = 1024 * 1024
GB = 1024 * 1024 * 1024

SMALL_SIZES = [2 ** i for i in range(10, 26)]   # 1KB..32MB
LARGE_SIZES = [2 ** i for i in range(26, 33)]   # 64MB..4GB
ALL_SIZES = SMALL_SIZES + LARGE_SIZES


def geomean(xs):
    xs = list(xs)
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def fmt_size(s: int) -> str:
    if s >= GB:
        return f"{s // GB}G"
    if s >= MB:
        return f"{s // MB}M"
    return f"{s // KB}K"


def time_us(fn, *args, reps: int = 200, warmup: int = 20) -> float:
    """Wall-clock microseconds per call (for CSV reporting)."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args)
    return (time.perf_counter() - t0) / reps * 1e6


class ClaimChecker:
    def __init__(self, name: str):
        self.name = name
        self.rows: list[tuple[str, float, float, float, float, bool]] = []

    def check(self, label: str, value: float, paper: float, lo: float, hi: float):
        ok = lo <= value <= hi
        self.rows.append((label, value, paper, lo, hi, ok))
        return ok

    def report(self) -> bool:
        all_ok = True
        for label, v, p, lo, hi, ok in self.rows:
            mark = "OK  " if ok else "FAIL"
            if not ok:
                all_ok = False
            print(f"  [{mark}] {label}: model={v:.3f} paper={p:.3f} band=[{lo},{hi}]")
        return all_ok
