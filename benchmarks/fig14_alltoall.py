"""Figure 14 + Table 3: all-to-all speedup of every DMA variant vs RCCL.

``--optimized`` additionally sweeps the optimized command streams
(DESIGN.md §7) and emits the baseline-vs-optimized curves plus the paper's
optimized-collective claim bands (~20% faster than RCCL at small sizes,
~7% gain at large sizes).

``--pipelined`` adds the pipelined rotation-ring curves and the §9
all-to-all parity band (rotation AA gains little from per-chunk signaling,
DESIGN.md §9.3).
"""
from __future__ import annotations

from repro.core.dma import (alltoall_schedule, derive_dispatch, mi300x_platform,
                            rccl_aa_calibration, simulate)
from repro.core.dma.rccl_model import rccl_collective_latency
from .common import (ALL_SIZES, MB, SMALL_SIZES, ClaimChecker, fmt_size,
                     geomean, optimized_report, pipelined_report)

VARIANTS = ("pcpy", "swap", "b2b", "prelaunch_pcpy", "prelaunch_swap", "prelaunch_b2b")
OPT_VARIANTS = tuple(f"opt_{v}" for v in VARIANTS)


def run(verbose: bool = True, optimized: bool = False, pipelined: bool = False):
    topo = mi300x_platform()
    rc = rccl_aa_calibration()
    variants = VARIANTS + OPT_VARIANTS if optimized else VARIANTS
    lat = {v: {} for v in variants}
    rccl = {}
    for s in ALL_SIZES:
        rccl[s] = rccl_collective_latency(topo, s, rc)
        for v in variants:
            lat[v][s] = simulate(alltoall_schedule(topo, s, v), topo).latency
    if verbose:
        print("size   " + "".join(f"{v:>16}" for v in VARIANTS) + "   (speedup vs RCCL)")
        for s in ALL_SIZES:
            print(f"{fmt_size(s):>5} " + "".join(f"{rccl[s]/lat[v][s]:16.2f}" for v in VARIANTS))

    cc = ClaimChecker("fig14")
    sub1m = [s for s in SMALL_SIZES if s < 1 * MB]
    upto4m = [s for s in SMALL_SIZES if s <= 4 * MB]
    cc.check("pcpy geomean slowdown <32MB (paper 2.5x)",
             geomean(lat["pcpy"][s] / rccl[s] for s in SMALL_SIZES), 2.5, 1.9, 3.3)
    cc.check("swap over pcpy <=4MB (paper 1.7x)",
             geomean(lat["pcpy"][s] / lat["swap"][s] for s in upto4m), 1.7, 1.35, 2.05)
    cc.check("b2b over pcpy <1MB (paper 2.5x)",
             geomean(lat["pcpy"][s] / lat["b2b"][s] for s in sub1m), 2.5, 2.0, 3.1)
    cc.check("b2b over swap <1MB (paper 1.4x)",
             geomean(lat["swap"][s] / lat["b2b"][s] for s in sub1m), 1.4, 1.15, 1.85)
    cc.check("optimized vs RCCL <32MB (paper: 20% FASTER, i.e. 0.83x)",
             geomean(min(lat[v][s] for v in VARIANTS) / rccl[s] for s in SMALL_SIZES),
             0.83, 0.68, 0.98)
    cc.check("pcpy speedup >32MB (paper 1.18x)",
             geomean(rccl[s] / lat["prelaunch_pcpy"][s] for s in ALL_SIZES if s > 32 * MB),
             1.2, 1.05, 1.45)

    table = derive_dispatch(topo, "all_to_all", ALL_SIZES)
    if verbose:
        print("\nDerived dispatch (cf. paper Table 3):")
        for e in table:
            hi = fmt_size(e.hi) if e.hi else "inf"
            print(f"  [{fmt_size(e.lo)}, {hi}) -> {e.variant}")
    if optimized:
        optimized_report(cc, topo, "all_to_all", lat, rccl, verbose)
    if pipelined:
        pipelined_report(cc, topo, "all_to_all", lat, rccl, verbose)
    return cc, lat


def main(argv=None):
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--optimized", action="store_true",
                   help="also sweep the opt_ command streams (DESIGN.md §7) "
                        "and emit baseline-vs-optimized curves")
    p.add_argument("--pipelined", action="store_true",
                   help="also sweep the pipelined rotation rings "
                        "(DESIGN.md §9) and check the §9 parity band")
    args = p.parse_args(argv)
    cc, _ = run(optimized=args.optimized, pipelined=args.pipelined)
    return 0 if cc.report() else 1


if __name__ == "__main__":
    raise SystemExit(main())
