"""Figure 1: the baseline DMA all-gather gap vs RCCL across the size
spectrum (up to ~7x slower in latency-bound regions) and how DMA-Latte's
feature dispatch closes it."""
from __future__ import annotations

from repro.core.dma import (allgather_schedule, mi300x_platform, paper_dispatch,
                            rccl_ag_calibration, simulate)
from repro.core.dma.rccl_model import rccl_collective_latency
from .common import ALL_SIZES, SMALL_SIZES, ClaimChecker, fmt_size, geomean


def run(verbose: bool = True):
    topo = mi300x_platform()
    rc = rccl_ag_calibration()
    rows = []
    for s in ALL_SIZES:
        rccl = rccl_collective_latency(topo, s, rc)
        pcpy = simulate(allgather_schedule(topo, s, "pcpy"), topo).latency
        best_v = paper_dispatch("all_gather", s)
        best = simulate(allgather_schedule(topo, s, best_v), topo).latency
        rows.append((s, rccl, pcpy, best, best_v))
    if verbose:
        print("size  rccl_us  pcpy_us  latte_us  latte_variant  pcpy_slowdown")
        for s, rccl, pcpy, best, v in rows:
            print(f"{fmt_size(s):>5} {rccl*1e6:8.1f} {pcpy*1e6:8.1f} {best*1e6:9.1f} "
                  f"{v:>15} {pcpy/rccl:6.2f}x")
    cc = ClaimChecker("fig01")
    max_gap = max(p / r for s, r, p, b, v in rows if s in SMALL_SIZES)
    cc.check("max baseline gap (paper: up to 7x)", max_gap, 7.0, 5.0, 8.5)
    gm = geomean(p / r for s, r, p, b, v in rows if s in SMALL_SIZES)
    cc.check("pcpy geomean slowdown <32MB (paper 4.5x)", gm, 4.5, 3.4, 5.6)
    return cc, rows


def main():
    cc, _ = run()
    ok = cc.report()
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
