"""Chrome-trace export of recorded simulator runs (DESIGN.md §14).

Renders any schedule the simulator can run — baseline/``opt_``/``pipe_``
streams, hierarchical multi-node collectives, fault-injected runs with
watchdog retries, or a composed serving round — as Chrome ``trace_event``
JSON: one process per device, one thread per resource, flow arrows from
each tag raise to the waits it wakes.  Load the dump in ``ui.perfetto.dev``
or ``chrome://tracing``.

    PYTHONPATH=src python -m benchmarks.trace_export \
        --collective all_gather --variant hier_pipe --topo mi300x-2node \
        --size 4MB --out trace.json

``--faults`` injects a deterministic dropped signal (plus a straggler
engine) so the dump shows watchdog retry slices; ``--serving`` records one
composed round of the §12 serving loop instead of a single schedule.  The
``run()`` entry (benchmarks.run registry) checks the §14 contract: recorded
and unrecorded runs are latency-bit-identical, fault runs carry retry
slices, and ``record_trace=False`` attaches no trace.
"""
from __future__ import annotations

import argparse
import json

from repro.core.dma import simulate
from repro.core.dma.commands import tag_name
from repro.core.dma.dispatch import COLLECTIVE_BUILDERS
from repro.core.dma.faults import FaultPlan, Straggler
from repro.core.dma.topology import (mi300x_cluster, mi300x_platform,
                                     tpu_v5e_multislice, tpu_v5e_pod)
from repro.core.dma.trace import chrome_trace, write_chrome_trace
from repro.serve.engine import ServingSimulator
from repro.serve.workload import Request

from .common import MB, ClaimChecker, fmt_size

TOPOLOGIES = {
    "mi300x": mi300x_platform,
    "tpu16": lambda: tpu_v5e_pod(16),
    "mi300x-2node": lambda: mi300x_cluster(2),
    "tpu64": lambda: tpu_v5e_multislice(64),
}


def first_tag_name(schedule) -> str | None:
    """First tagged signal name in the schedule — a deterministic handle
    for ``FaultPlan.drop_tags`` (§13.2)."""
    for q in schedule.queues:
        for c in q.commands:
            for t in (c.tag, c.fused_tag):
                if t is not None:
                    name = tag_name(t)
                    if isinstance(name, str):
                        return name
    return None


def fault_plan_for(schedule) -> FaultPlan:
    """Deterministic plan that guarantees retry slices in the trace: drop
    the first raise of the schedule's first tag name, and slow one engine
    so the retry window is visible."""
    name = first_tag_name(schedule)
    drops = () if name is None else (name,)
    dev = schedule.devices[0]
    return FaultPlan(drop_tags=drops,
                     stragglers=(Straggler(device=dev, engine=None,
                                           slowdown=1.5),))


def export_schedule(collective: str, variant: str, size: int, topo_name: str,
                    *, faults: bool = False):
    """Build, trace, and return ``(SimResult, unrecorded SimResult,
    FaultPlan | None)`` for one collective schedule."""
    topo = TOPOLOGIES[topo_name]()
    sched = COLLECTIVE_BUILDERS[collective](topo, size, variant)
    plan = fault_plan_for(sched) if faults else None
    plain = simulate(sched, topo, faults=plan)
    recorded = simulate(sched, topo, faults=plan, record_trace=True)
    return recorded, plain, plan


def serving_round(n_requests: int = 6, record_round: int = 0):
    """One composed serving round (§12) with its trace recorded."""
    sim = ServingSimulator()
    reqs = [Request(rid=i, arrival=i * 1e-4, prompt_tokens=512,
                    output_tokens=8) for i in range(n_requests)]
    plain = ServingSimulator().run(reqs)
    report = sim.run(reqs, record_round=record_round)
    return sim.last_recorded, plain, report


def run(verbose: bool = True):
    """Claim-check the §14 trace contract over the three acceptance
    scenarios (hier-pipelined AG, fault-injected retry run, composed
    serving round)."""
    cc = ClaimChecker("trace_export")

    # (a) pipelined hierarchical all-gather -------------------------------
    recorded, plain, _ = export_schedule("all_gather", "hier_pipe", 4 * MB,
                                         "mi300x-2node")
    cc.check("hier_pipe AG recorded/unrecorded latency ratio",
             recorded.latency / plain.latency, 1.0, 1.0, 1.0)
    cc.check("record_trace=False attaches no trace",
             1.0 if plain.trace is None else 0.0, 1.0, 1.0, 1.0)
    n_ev = len(chrome_trace(recorded)["traceEvents"])
    if verbose:
        print(f"hier_pipe AG 4MB mi300x-2node: {len(recorded.trace.spans)} "
              f"spans, {len(recorded.trace.flows)} flows, {n_ev} events")
    cc.check("hier trace renders events", float(n_ev > 0), 1.0, 1.0, 1.0)

    # (b) fault-injected run with watchdog retries ------------------------
    frec, fplain, plan = export_schedule("all_gather", "pipe_b2b", 8 * MB,
                                         "tpu16", faults=True)
    cc.check("fault run recorded/unrecorded latency ratio",
             frec.latency / fplain.latency, 1.0, 1.0, 1.0)
    retries = sum(1 for s in frec.trace.spans if s.retry)
    if verbose:
        print(f"fault pipe_b2b AG 8MB tpu16: dropped {plan.drop_tags}, "
              f"{retries} retry slices")
    cc.check("fault trace carries retry slices", float(retries > 0), 1.0,
             1.0, 1.0)

    # (c) composed serving round ------------------------------------------
    comp, plain_report, report = serving_round()
    cc.check("serving recorded/unrecorded makespan ratio",
             report.makespan / plain_report.makespan, 1.0, 1.0, 1.0)
    n_sev = len(chrome_trace(comp)["traceEvents"])
    if verbose:
        print(f"serving round 0: {len(comp.result.trace.spans)} spans, "
              f"{n_sev} events")
    cc.check("serving trace renders events", float(n_sev > 0), 1.0, 1.0, 1.0)

    return cc, {"hier": recorded, "fault": frec, "serving": comp}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--collective", default="all_gather",
                   choices=sorted(COLLECTIVE_BUILDERS))
    p.add_argument("--variant", default="hier_pipe")
    p.add_argument("--size", default="4MB",
                   help="message size, e.g. 512KB / 4MB / 1048576")
    p.add_argument("--topo", default="mi300x-2node",
                   choices=sorted(TOPOLOGIES))
    p.add_argument("--faults", action="store_true",
                   help="inject a deterministic dropped signal + straggler "
                        "so the dump shows watchdog retry slices")
    p.add_argument("--serving", action="store_true",
                   help="export one composed serving round (§12) instead "
                        "of a single schedule")
    p.add_argument("--round", type=int, default=0,
                   help="which serving round to record (with --serving)")
    p.add_argument("--out", default="trace.json",
                   help="output path for the Chrome trace-event JSON")
    p.add_argument("--check", action="store_true",
                   help="CI guard: run the §14 contract claims instead of "
                        "exporting")
    args = p.parse_args(argv)

    if args.check:
        cc, _ = run(verbose=False)
        return 0 if cc.report() else 1

    if args.serving:
        comp, plain_report, report = serving_round(record_round=args.round)
        if comp is None:
            print(f"serving run finished before round {args.round}")
            return 1
        label = f"serving round {args.round}"
        obj = comp
    else:
        size = parse_size(args.size)
        obj, plain, plan = export_schedule(args.collective, args.variant,
                                           size, args.topo,
                                           faults=args.faults)
        assert obj.latency == plain.latency      # §14: recording is free
        label = (f"{args.collective} {args.variant} {fmt_size(size)} "
                 f"{args.topo}" + (" +faults" if plan is not None else ""))
    path = write_chrome_trace(obj, args.out, label=label)
    n = len(chrome_trace(obj)["traceEvents"])
    print(f"wrote {n} trace events to {path} ({label}); "
          f"load it in ui.perfetto.dev or chrome://tracing")
    return 0


def parse_size(text: str) -> int:
    t = text.strip().upper()
    for suffix, mult in (("KB", 1024), ("MB", MB), ("B", 1)):
        if t.endswith(suffix):
            return int(float(t[:-len(suffix)]) * mult)
    return int(t)


if __name__ == "__main__":
    raise SystemExit(main())
