"""Simulator hot-path microbenchmark (DESIGN.md §8.2/§8.3) + CI perf guard.

Chunking (§8.1) multiplies event counts 10-100x: a 4GB all-to-all on the
8-GPU MI300X box is ~7000 commands instead of ~60.  This benchmark times the
overhauled simulator (heap-based event queue, append-only coalescing
timelines, closed-form chunk runs) against the **pre-overhaul simulator**
(vendored below: per-command execution, non-coalescing timelines, scan-based
worklist — the PR-2 core) on the same chunked schedules, and asserts a >=5x
speedup on the reference chunked 8-device GB-scale all-to-all sweep.

``--check`` (CI) additionally enforces a wall-clock budget on the new
simulator's sweep and writes a JSON report next to the dispatch-sweep cache
(``$REPRO_DISPATCH_CACHE``, falling back to the untracked ``artifacts/``
directory) so the perf numbers ride the same artifact.

``--sweep`` times the other perf-guarded layer (DESIGN.md §11.3): the
vectorized dispatch-sweep fast path (representative-only builds) against
the historical per-point loop (full schedule build + ``simulate()`` per
(variant, size, chunk) point) on the 64-device TPU multislice all-gather
sweep — the derivation the v6 multi-node tables depend on.  Latencies are
asserted bit-identical point by point; ``--sweep --check`` enforces the
>=5x throughput floor and a wall budget on the fast path.

``--composed`` guards the §12 multi-schedule composition path: K
staggered chunked GB-scale streams through ``run_composed`` must cost no
more than a small constant factor over the sum of K isolated full-loop
``simulate()`` runs.  Tag namespacing memoizes per command *object*, so a
regression that breaks §8.3 identity-run sharing (every chunk becoming its
own event) blows the ratio up by orders of magnitude — this floor is the
tripwire.  K=1 is asserted bit-identical to ``simulate`` while at it.

Both simulators produce the same latencies (asserted per scenario): the
overhaul changes data structures, not semantics.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from collections import defaultdict

from repro.core.backend import _SWEEP_CHUNKS, _SWEEP_SIZES
from repro.core.dma import (alltoall_schedule, mi300x_platform,
                            run_composed, simulate)
from repro.core.dma.collectives import allgather_schedule
from repro.core.dma.commands import DATA_KINDS, CmdKind
from repro.core.dma.dispatch import candidate_variants
from repro.core.dma.faults import FaultPlan
from repro.core.dma.sweep import sweep_variant_latencies
from repro.core.dma.topology import tpu_v5e_multislice

GB = 1024 * 1024 * 1024

#: Reference scenario for the perf guard: chunked 8-device GB-scale
#: all-to-all, baseline and optimized streams, full (non-symmetric) sim.
SCENARIOS = tuple(
    (size, variant)
    for size in (1 * GB, 2 * GB, 4 * GB)
    for variant in ("pcpy", "opt_pcpy"))

MIN_SPEEDUP = 5.0        # acceptance floor; the overhaul lands far above
BUDGET_S = 2.5           # --check: new-sim wall budget for the whole sweep

#: --sweep acceptance floor (DESIGN.md §11.3): the vectorized fast path
#: must beat the per-point loop >=5x on the tpu64 all-gather sweep (it
#: lands far above — the per-device build work it deletes grows linearly
#: with device count), inside a wall budget that keeps CI honest.
SWEEP_MIN_SPEEDUP = 5.0
SWEEP_BUDGET_S = 2.0

#: --composed acceptance: run_composed over K concurrent chunked GB-scale
#: streams vs the sum of K isolated simulate() walls.  Composition adds
#: work (one shared world serializes more events than K private ones), so
#: the guard is an overhead *ceiling*, not a speedup floor.
COMPOSED_MAX_OVERHEAD = 2.5
COMPOSED_BUDGET_S = 3.0

#: Fault-hook acceptance (DESIGN.md §13.1): an *empty* FaultPlan is
#: normalized to the untouched fault-free path, so passing one must be
#: bit-identical AND essentially free — the guard caps the wall-clock
#: ratio of the empty-plan run over the plain run on the reference
#: scenario.  A regression here means fault threading leaked work into
#: the fault-free event loop.
FAULT_MAX_OVERHEAD = 1.05

#: Trace-hook acceptance (DESIGN.md §14): ``record_trace=False`` (the
#: default) must leave the hot path structurally untouched — every hook is
#: an ``if tr is not None`` branch off a local.  The guard caps the
#: wall-clock ratio of an explicit ``record_trace=False`` run over the
#: plain call on the reference scenario.  A regression here means trace
#: threading leaked work into the unrecorded event loop.
TRACE_MAX_OVERHEAD = 1.02

#: CU-resource acceptance (DESIGN.md §15): the compute-collective overlap
#: work adds a ``cu:{dev}`` timeline and a COMPUTE branch to the event
#: loop, but an *unfused* schedule must not pay for it.  The guard pairs
#: the reference chunked scenario against the same schedule carrying one
#: prelaunched 1-FLOP COMPUTE probe (which instantiates the CU timeline
#: and exercises the branch without perturbing the latency — asserted
#: bit-identical) and caps the wall-clock ratio.  A regression here means
#: CU plumbing leaked work into the per-command hot path.
CU_MAX_OVERHEAD = 1.02


# --------------------------------------------------------------------------
# Pre-overhaul simulator (vendored PR-2 core, trimmed): per-command event
# loop, non-coalescing interval timelines, scan-based blocked-queue worklist.
# Kept verbatim-in-spirit so the speedup is measured against the real thing.
# --------------------------------------------------------------------------

class _LegacyTimeline:
    __slots__ = ("free", "busy", "intervals")

    def __init__(self):
        self.free = 0.0
        self.busy = 0.0
        self.intervals = []

    def acquire(self, t, dur):
        start = t if t > self.free else self.free
        end = start + dur
        self.free = end
        if dur > 0.0:
            self.busy += dur
            self.intervals.append((start, end))
        return start, end


class _LegacyQueueState:
    __slots__ = ("q", "idx", "issue", "seen_data", "last_end", "copy_end", "start")

    def __init__(self, q, start):
        self.q = q
        self.idx = 0
        self.start = start
        self.issue = start
        self.seen_data = False
        self.last_end = start
        self.copy_end = start


class _LegacySim:
    def __init__(self, topo):
        self.topo = topo
        self.calib = topo.calib
        self.timelines = {}
        self.tags = {}
        self.host_signals = defaultdict(list)
        self.fused_signals = defaultdict(list)

    def timeline(self, key):
        tl = self.timelines.get(key)
        if tl is None:
            tl = self.timelines[key] = _LegacyTimeline()
        return tl

    def transfer(self, src, dst, size, start):
        c = self.calib
        eff = c.dma_link_efficiency
        if src == "host" or dst == "host":
            dev = dst if src == "host" else src
            dirn = "h2d" if src == "host" else "d2h"
            tl = self.timeline(f"hostlink:{dev}:{dirn}")
            _, end = tl.acquire(start, size / (self.topo.host_link_bw * eff))
            return end
        wire = size / (self.topo.link_bw * eff)
        t = start
        end = start
        for h, (a, b) in enumerate(self.topo.route(src, dst)):
            req = t if h == 0 else t + c.hop_latency
            s, end = self.timeline(f"link:{a}>{b}").acquire(req, wire)
            t = s
        return end

    def advance(self, st):
        c = self.calib
        cmds = st.q.commands
        while st.idx < len(cmds):
            cmd = cmds[st.idx]
            kind = cmd.kind
            if kind is CmdKind.WAIT:
                t = self.tags.get(cmd.tag)
                if t is None:
                    return False
                arrival = t + c.poll_trigger
                if arrival > st.issue:
                    st.issue = arrival
            elif kind is CmdKind.POLL:
                pass
            elif kind is CmdKind.SIGNAL:
                t = max(st.issue, st.last_end) + c.sync_engine
                if cmd.tag is not None:
                    st.issue = t
                    self.tags[cmd.tag] = t
                else:
                    self.host_signals[st.q.device].append(t)
            elif kind in DATA_KINDS:
                st.issue += c.b2b_issue if st.seen_data else c.copy_setup
                st.seen_data = True
                if kind is CmdKind.SWAP:
                    stream_bytes = 2 * cmd.size
                else:
                    stream_bytes = max(cmd.local_read_bytes, cmd.remote_write_bytes)
                engine = self.timeline(f"engine:{st.q.device}.{st.q.engine}")
                start = max(st.issue, engine.free)
                _, end = engine.acquire(start, stream_bytes / c.engine_bw)
                for dst in cmd.dsts:
                    end = max(end, self.transfer(cmd.src, dst, cmd.size, start))
                if kind is CmdKind.SWAP:
                    end = max(end, self.transfer(cmd.dsts[0], cmd.src, cmd.size, start))
                st.last_end = max(st.last_end, end)
                st.copy_end = max(st.copy_end, end)
                if cmd.fused_tag is not None:
                    self.tags[cmd.fused_tag] = end + c.fused_sync
                if cmd.fused_signal:
                    self.fused_signals[st.q.device].append(end + c.fused_sync)
            st.idx += 1
        return True


def _legacy_control_cost(live, c):
    t = 0.0
    room = 0
    for q in live:
        if q.batch <= 1:
            t += len(q.commands) * c.control
            room = 0
            continue
        for _ in q.commands:
            if room == 0:
                t += c.control
                room = q.batch - 1
            else:
                t += c.control_batched
                room -= 1
    return t


def _legacy_start_device(sim, dev, queues):
    c = sim.topo.calib
    live = [q for q in queues if not q.prelaunched]
    pre = [q for q in queues if q.prelaunched]
    host = sim.timeline(f"host:{dev}")
    t_control = _legacy_control_cost(live, c)
    host.acquire(0.0, t_control)
    states = []
    batched_seen = False
    for q in live:
        bell_cost = c.doorbell_batched if q.batch > 1 and batched_seen else c.doorbell
        batched_seen = q.batch > 1
        _, bell = host.acquire(host.free, bell_cost)
        sim.timeline(f"engine:{dev}.{q.engine}").acquire(bell, c.fetch)
        states.append(_LegacyQueueState(q, bell + c.fetch))
    for q in pre:
        states.append(_LegacyQueueState(q, c.poll_trigger))
    return t_control, states


def _legacy_finish_device(sim, dev, t_control, states):
    c = sim.topo.calib
    sched_end = max((st.start for st in states), default=t_control)
    copy_end = max((st.copy_end for st in states), default=sched_end)
    sigs = sim.host_signals.get(dev, [])
    fused = sim.fused_signals.get(dev, [])
    t_obs = len(sigs) * c.sync_obs
    if fused:
        t_obs += c.sync_obs + (len(fused) - 1) * c.sync_obs_batched
    signal_done = max([copy_end] + sigs + fused)
    _, total = sim.timeline(f"host:{dev}").acquire(signal_done, t_obs)
    return total


def legacy_simulate(schedule, topo):
    """Pre-overhaul full simulation; returns end-to-end latency (seconds)."""
    sim = _LegacySim(topo)
    devices = schedule.devices
    started = {d: _legacy_start_device(sim, d, schedule.queues_for(d))
               for d in devices}
    pending = [st for _, states in started.values() for st in states]
    while pending:                      # scan-based worklist: O(passes x queues)
        progressed = False
        still = []
        for st in pending:
            before = st.idx
            if not sim.advance(st):
                still.append(st)
            progressed = progressed or st.idx != before or st not in still
        if not progressed:
            raise RuntimeError("deadlocked schedule")
        pending = still
    return max(_legacy_finish_device(sim, d, t, states)
               for d, (t, states) in started.items())


# --------------------------------------------------------------------------


def _wall(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _paired_overheads(base, variants, reps=9, inner=3):
    """Wall-clock ratio of each variant over ``base``, noise-robust.

    Each rep times base and variants back-to-back (``inner`` calls per
    sample so one sample outlasts scheduler jitter) and forms per-rep
    ratios; the *minimum* ratio across reps is reported.  A genuine
    structural overhead inflates every pair, so the min still catches it;
    a load spike inflates only the pairs it lands on, so the min discards
    it — unlike min-of-walls taken in separate phases, where a spike
    during one phase skews the ratio permanently."""
    best = [float("inf")] * len(variants)
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            base()
        t_base = time.perf_counter() - t0
        for i, fn in enumerate(variants):
            t0 = time.perf_counter()
            for _ in range(inner):
                fn()
            ratio = (time.perf_counter() - t0) / t_base
            if ratio < best[i]:
                best[i] = ratio
    return best


def run(verbose: bool = True) -> dict:
    topo = mi300x_platform()
    scenarios = []
    new_total = legacy_total = 0.0
    for size, variant in SCENARIOS:
        sched = alltoall_schedule(topo, size, variant)
        n_cmds = sched.total_commands()
        lat_new = simulate(sched, topo, symmetric=False).latency
        lat_old = legacy_simulate(sched, topo)
        if abs(lat_new - lat_old) > 1e-9 + 1e-6 * lat_old:
            raise AssertionError(
                f"overhauled sim diverged from pre-overhaul reference on "
                f"{variant}@{size}: {lat_new} vs {lat_old}")
        t_new = _wall(lambda: simulate(sched, topo, symmetric=False))
        t_old = _wall(lambda: legacy_simulate(sched, topo))
        new_total += t_new
        legacy_total += t_old
        scenarios.append({
            "size": size, "variant": variant, "commands": n_cmds,
            "latency_s": lat_new, "wall_new_s": t_new, "wall_legacy_s": t_old,
            "speedup": t_old / t_new,
        })
        if verbose:
            print(f"  {variant:>9} @{size // GB}GB: {n_cmds:5d} cmds  "
                  f"new {t_new * 1e3:7.2f}ms  legacy {t_old * 1e3:7.2f}ms  "
                  f"{t_old / t_new:6.1f}x")
    speedup = legacy_total / new_total

    # Fault-hook overhead (§13.1): empty plan must be bit-identical and free.
    sched = alltoall_schedule(topo, SCENARIOS[0][0], SCENARIOS[0][1])
    plain = simulate(sched, topo, symmetric=False)
    empty = simulate(sched, topo, symmetric=False, faults=FaultPlan())
    if plain.latency != empty.latency or empty.fault_report is not None:
        raise AssertionError(
            "empty FaultPlan diverged from the fault-free run: "
            f"{empty.latency} vs {plain.latency}")
    # Trace-hook overhead (§14): record_trace=False must be free (and is
    # trivially bit-identical — it takes the same code path).
    untraced = simulate(sched, topo, symmetric=False, record_trace=False)
    if plain.latency != untraced.latency or untraced.trace is not None:
        raise AssertionError(
            "record_trace=False diverged from the plain run: "
            f"{untraced.latency} vs {plain.latency}")
    # CU-resource overhead (§15): a prelaunched 1-FLOP COMPUTE probe
    # instantiates the cu:{dev} timeline and runs the COMPUTE branch once;
    # the GB-scale transfer latency must be untouched by it.
    import dataclasses as _dc

    from repro.core.dma.commands import EngineQueue
    from repro.core.dma import commands as _cmd
    probe = EngineQueue(sched.queues[0].device, topo.n_engines,
                        (_cmd.poll(), _cmd.compute(1)), prelaunched=True)
    cu_sched = _dc.replace(sched, queues=sched.queues + (probe,))
    cu_probe = simulate(cu_sched, topo, symmetric=False)
    if plain.latency != cu_probe.latency:
        raise AssertionError(
            "the CU compute probe perturbed the unfused latency: "
            f"{cu_probe.latency} vs {plain.latency}")
    fault_overhead, trace_overhead, cu_overhead = _paired_overheads(
        lambda: simulate(sched, topo, symmetric=False),
        [lambda: simulate(sched, topo, symmetric=False, faults=FaultPlan()),
         lambda: simulate(sched, topo, symmetric=False, record_trace=False),
         lambda: simulate(cu_sched, topo, symmetric=False)])

    report = {
        "scenarios": scenarios,
        "wall_new_s": new_total,
        "wall_legacy_s": legacy_total,
        "speedup": speedup,
        "min_speedup": MIN_SPEEDUP,
        "budget_s": BUDGET_S,
        "fault_overhead": fault_overhead,
        "fault_max_overhead": FAULT_MAX_OVERHEAD,
        "trace_overhead": trace_overhead,
        "trace_max_overhead": TRACE_MAX_OVERHEAD,
        "cu_overhead": cu_overhead,
        "cu_max_overhead": CU_MAX_OVERHEAD,
    }
    if verbose:
        print(f"chunked 8-device GB-scale all-to-all sweep: "
              f"{speedup:.1f}x speedup (floor {MIN_SPEEDUP}x), "
              f"new-sim wall {new_total:.3f}s (budget {BUDGET_S}s)")
        print(f"empty-FaultPlan overhead on the fault-free path: "
              f"{fault_overhead:.3f}x (ceiling {FAULT_MAX_OVERHEAD}x, "
              f"bit-identical asserted)")
        print(f"record_trace=False overhead on the unrecorded path: "
              f"{trace_overhead:.3f}x (ceiling {TRACE_MAX_OVERHEAD}x, "
              f"bit-identical asserted)")
        print(f"CU-resource overhead on the unfused path: "
              f"{cu_overhead:.3f}x (ceiling {CU_MAX_OVERHEAD}x, "
              f"bit-identical asserted)")
    return report


def run_sweep(verbose: bool = True) -> dict:
    """Time the vectorized dispatch sweep against the per-point loop
    (DESIGN.md §11.3) on the tpu64 all-gather derivation, asserting
    bit-identity point by point."""
    topo = tpu_v5e_multislice(64)
    sizes = tuple(_SWEEP_SIZES)
    variants = candidate_variants(topo, "all_gather", allow_pipelined=True,
                                  allow_optimized=True)
    candidates = [(v, ch) for v in variants for ch in _SWEEP_CHUNKS]

    t0 = time.perf_counter()
    fast = {}
    for v, ch in candidates:
        lats = sweep_variant_latencies(topo, "all_gather", sizes, v, ch)
        assert lats is not None, f"{v} lost the symmetric fast path on tpu64"
        fast[(v, ch)] = lats
    t_fast = time.perf_counter() - t0

    t0 = time.perf_counter()
    ref = {}
    for v, ch in candidates:
        ref[(v, ch)] = [
            simulate(allgather_schedule(topo, s, v, max_chunk_bytes=ch),
                     topo).latency
            for s in sizes]
    t_ref = time.perf_counter() - t0

    for key in candidates:
        if fast[key] != ref[key]:
            raise AssertionError(
                f"vectorized sweep diverged from per-point loop on {key}")

    n_points = len(candidates) * len(sizes)
    speedup = t_ref / t_fast
    report = {
        "topology": topo.name,
        "collective": "all_gather",
        "points": n_points,
        "wall_fast_s": t_fast,
        "wall_per_point_s": t_ref,
        "speedup": speedup,
        "min_speedup": SWEEP_MIN_SPEEDUP,
        "budget_s": SWEEP_BUDGET_S,
    }
    if verbose:
        print(f"tpu64 all-gather dispatch sweep ({n_points} points): "
              f"fast {t_fast:.3f}s  per-point {t_ref:.3f}s  "
              f"{speedup:.1f}x speedup (floor {SWEEP_MIN_SPEEDUP}x, "
              f"fast-path budget {SWEEP_BUDGET_S}s)")
    return report


def run_composed_bench(verbose: bool = True) -> dict:
    """Time the §12 composition path: K staggered GB-scale chunked streams
    in one world vs K isolated full-loop runs, plus the K=1 identity."""
    topo = mi300x_platform()
    streams = [alltoall_schedule(topo, 1 * GB, v)
               for v in ("pcpy", "opt_pcpy", "pcpy", "opt_pcpy", "pcpy",
                         "opt_pcpy")]
    releases = [k * 1e-4 for k in range(len(streams))]

    one = simulate(streams[0], topo, symmetric=False)
    k1 = run_composed([streams[0]], topo)
    if (k1.result.latency != one.latency
            or k1.result.per_device != one.per_device):
        raise AssertionError("run_composed K=1 diverged from simulate()")

    t_iso = sum(_wall(lambda s=s: simulate(s, topo, symmetric=False))
                for s in streams)
    t_comp = _wall(lambda: run_composed(streams, topo, releases))
    comp = run_composed(streams, topo, releases)
    overhead = t_comp / t_iso
    report = {
        "streams": len(streams),
        "wall_isolated_sum_s": t_iso,
        "wall_composed_s": t_comp,
        "overhead": overhead,
        "makespan_s": comp.makespan,
        "max_overhead": COMPOSED_MAX_OVERHEAD,
        "budget_s": COMPOSED_BUDGET_S,
    }
    if verbose:
        print(f"composed {len(streams)}-stream GB-scale all-to-all: "
              f"composed {t_comp * 1e3:.1f}ms vs isolated-sum "
              f"{t_iso * 1e3:.1f}ms -> {overhead:.2f}x overhead "
              f"(ceiling {COMPOSED_MAX_OVERHEAD}x, budget {COMPOSED_BUDGET_S}s)")
    return report


def _json_path(name: str = "sim_perf.json") -> str:
    """Report destination: the dispatch-sweep cache dir when set, else the
    untracked ``artifacts/`` directory — never the repo root, where a stale
    report reads like a committed result (tools/check_docs.py guards that
    no benchmark artifact ever becomes tracked)."""
    cache_dir = os.environ.get("REPRO_DISPATCH_CACHE") or "artifacts"
    os.makedirs(cache_dir, exist_ok=True)
    return os.path.join(cache_dir, name)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--check", action="store_true",
                   help="CI perf guard: fail when the speedup floor or the "
                        "wall-clock budget is violated; write the JSON "
                        "report next to the dispatch-sweep cache")
    p.add_argument("--json", default=None,
                   help="explicit JSON report path (default: "
                        "$REPRO_DISPATCH_CACHE/sim_perf.json, falling back "
                        "to artifacts/sim_perf.json; sim_perf_sweep.json "
                        "with --sweep)")
    p.add_argument("--composed", action="store_true",
                   help="benchmark the multi-schedule composition path "
                        "(run_composed, DESIGN.md §12) against the sum of "
                        "isolated simulate() runs and enforce the overhead "
                        "ceiling with --check")
    p.add_argument("--sweep", action="store_true",
                   help="benchmark the vectorized dispatch-sweep fast path "
                        "against the per-point loop on tpu64 (DESIGN.md "
                        "§11.3) instead of the simulator hot path")
    args = p.parse_args(argv)
    if args.composed:
        report = run_composed_bench()
        if args.check or args.json:
            path = args.json or _json_path("sim_perf_composed.json")
            with open(path, "w") as f:
                json.dump(report, f, indent=1)
            print(f"wrote {path}")
        if not args.check:
            return 0
        ok = True
        if report["overhead"] > COMPOSED_MAX_OVERHEAD:
            print(f"FAIL: composed overhead {report['overhead']:.2f}x exceeds "
                  f"{COMPOSED_MAX_OVERHEAD}x ceiling")
            ok = False
        if report["wall_composed_s"] > COMPOSED_BUDGET_S:
            print(f"FAIL: composed wall {report['wall_composed_s']:.3f}s "
                  f"exceeds {COMPOSED_BUDGET_S}s budget")
            ok = False
        return 0 if ok else 1
    if args.sweep:
        report = run_sweep()
        if args.check or args.json:
            path = args.json or _json_path("sim_perf_sweep.json")
            with open(path, "w") as f:
                json.dump(report, f, indent=1)
            print(f"wrote {path}")
        if not args.check:
            return 0
        ok = True
        if report["speedup"] < SWEEP_MIN_SPEEDUP:
            print(f"FAIL: sweep speedup {report['speedup']:.1f}x < "
                  f"{SWEEP_MIN_SPEEDUP}x floor")
            ok = False
        if report["wall_fast_s"] > SWEEP_BUDGET_S:
            print(f"FAIL: fast-path wall {report['wall_fast_s']:.3f}s "
                  f"exceeds {SWEEP_BUDGET_S}s budget")
            ok = False
        return 0 if ok else 1
    report = run()
    if args.check or args.json:
        path = args.json or _json_path()
        with open(path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {path}")
    if not args.check:
        return 0
    ok = True
    if report["speedup"] < MIN_SPEEDUP:
        print(f"FAIL: speedup {report['speedup']:.1f}x < {MIN_SPEEDUP}x floor")
        ok = False
    if report["wall_new_s"] > BUDGET_S:
        print(f"FAIL: new-sim wall {report['wall_new_s']:.3f}s exceeds "
              f"{BUDGET_S}s budget")
        ok = False
    if report["fault_overhead"] > FAULT_MAX_OVERHEAD:
        print(f"FAIL: empty-FaultPlan overhead "
              f"{report['fault_overhead']:.3f}x exceeds "
              f"{FAULT_MAX_OVERHEAD}x ceiling")
        ok = False
    if report["trace_overhead"] > TRACE_MAX_OVERHEAD:
        print(f"FAIL: record_trace=False overhead "
              f"{report['trace_overhead']:.3f}x exceeds "
              f"{TRACE_MAX_OVERHEAD}x ceiling")
        ok = False
    if report["cu_overhead"] > CU_MAX_OVERHEAD:
        print(f"FAIL: CU-resource overhead {report['cu_overhead']:.3f}x "
              f"exceeds {CU_MAX_OVERHEAD}x ceiling")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
