"""Unit tests for the DMA command set, engine timing model, schedules,
dispatch policy, and the paper-claim validation."""
import pytest

from repro.core.dma import (
    CmdKind, allgather_schedule, alltoall_schedule, commands as cmd,
    cu_collective_power, derive_dispatch, dma_collective_power, kv_fetch_schedule,
    mi300x_platform, paper_dispatch, rccl_aa_calibration, rccl_ag_calibration,
    simulate, single_copy_breakdown, tpu_v5e_pod,
)
from repro.core.dma.claims import evaluate_claims
from repro.core.dma.rccl_model import rccl_collective_latency

KB, MB = 1024, 1024 * 1024
TOPO = mi300x_platform()


class TestCommands:
    def test_copy_validations(self):
        with pytest.raises(ValueError):
            cmd.Command(CmdKind.COPY, 0, (1, 2), 64)
        with pytest.raises(ValueError):
            cmd.Command(CmdKind.BCST, 0, (1,), 64)
        with pytest.raises(ValueError):
            cmd.Command(CmdKind.COPY, 0, (1,), -4)

    def test_bcst_reads_once_writes_twice(self):
        b = cmd.bcst(0, 1, 2, 1000)
        assert b.local_read_bytes == 1000
        assert b.remote_write_bytes == 2000
        assert b.n_copies == 2

    def test_prelaunch_queue_must_start_with_poll(self):
        with pytest.raises(ValueError):
            cmd.EngineQueue(0, 0, (cmd.copy(0, 1, 64),), prelaunched=True)
        q = cmd.EngineQueue(0, 0, (cmd.poll(), cmd.copy(0, 1, 64), cmd.signal()),
                            prelaunched=True)
        assert q.n_signals == 1
        assert len(q.data_commands) == 1


class TestSchedules:
    def test_allgather_traffic_conservation(self):
        """Every device must send its shard to all n-1 peers, any variant."""
        n = TOPO.n_devices
        size = 8 * MB
        for variant in ("pcpy", "bcst", "b2b", "prelaunch_b2b"):
            sched = allgather_schedule(TOPO, size, variant)
            recv = {d: set() for d in range(n)}
            for q in sched.queues:
                for c in q.data_commands:
                    for dst in c.dsts:
                        recv[dst].add(c.src)
            for d in range(n):
                assert recv[d] == set(range(n)) - {d}, (variant, d)

    def test_alltoall_swap_halves_commands(self):
        pcpy = alltoall_schedule(TOPO, 8 * MB, "pcpy")
        swap = alltoall_schedule(TOPO, 8 * MB, "swap")
        assert sum(len(q.data_commands) for q in swap.queues) * 2 == \
            sum(len(q.data_commands) for q in pcpy.queues)

    def test_bcst_halves_engines(self):
        pcpy = allgather_schedule(TOPO, 1 * MB, "pcpy")
        bcst = allgather_schedule(TOPO, 1 * MB, "bcst")
        assert pcpy.engines_used(0) == 7
        assert bcst.engines_used(0) == 4

    def test_b2b_single_engine(self):
        b2b = allgather_schedule(TOPO, 1 * MB, "b2b")
        assert b2b.engines_used(0) == 1
        assert b2b.queues_for(0)[0].n_signals == 1

    def test_kv_fetch_b2b_fanout_threshold(self):
        small = kv_fetch_schedule(TOPO, 16, 64 * KB, "b2b")
        big = kv_fetch_schedule(TOPO, 64, 2 * MB, "b2b")
        assert small.engines_used(0) == 1
        assert big.engines_used(0) > 1


class TestEngineModel:
    def test_latency_monotonic_in_size(self):
        prev = 0.0
        for size in (4 * KB, 64 * KB, 1 * MB, 16 * MB, 256 * MB):
            t = simulate(allgather_schedule(TOPO, size, "pcpy"), TOPO).latency
            assert t > prev
            prev = t

    def test_prelaunch_always_helps(self):
        for v in ("pcpy", "bcst", "b2b"):
            for size in (4 * KB, 1 * MB, 64 * MB):
                base = simulate(allgather_schedule(TOPO, size, v), TOPO).latency
                pre = simulate(allgather_schedule(TOPO, size, f"prelaunch_{v}"), TOPO).latency
                assert pre < base, (v, size)

    def test_breakdown_sums_to_total(self):
        b = single_copy_breakdown(64 * KB, TOPO)
        assert abs((b.control + b.schedule + b.copy + b.sync) - b.total) < 1e-12

    def test_prelaunch_removes_control_and_schedule(self):
        b = single_copy_breakdown(64 * KB, TOPO, prelaunch=True)
        assert b.control == 0.0

    def test_unknown_variant_raises(self):
        with pytest.raises(ValueError):
            allgather_schedule(TOPO, 1 * MB, "warp")
        with pytest.raises(ValueError):
            alltoall_schedule(TOPO, 1 * MB, "bcst")  # bcst is AG-only


class TestDispatch:
    def test_paper_tables(self):
        assert paper_dispatch("all_gather", 4 * KB) == "prelaunch_b2b"
        assert paper_dispatch("all_gather", 512 * KB) == "prelaunch_bcst"
        assert paper_dispatch("all_gather", 64 * MB) == "prelaunch_pcpy"
        assert paper_dispatch("all_gather", 1024 * MB) == "pcpy"
        assert paper_dispatch("all_to_all", 32 * KB) == "prelaunch_b2b"
        assert paper_dispatch("all_to_all", 1 * MB) == "prelaunch_swap"

    def test_derived_dispatch_covers_all_sizes(self):
        sizes = [2 ** i for i in range(10, 33)]
        entries = derive_dispatch(TOPO, "all_gather", sizes)
        assert entries[0].lo == sizes[0]
        assert entries[-1].hi is None

    def test_derived_matches_paper_structure_aa(self):
        """swap wins the mid range, pcpy the large range (Table 3)."""
        sizes = [2 ** i for i in range(10, 33)]
        entries = derive_dispatch(TOPO, "all_to_all", sizes)
        variants = [e.variant.replace("prelaunch_", "") for e in entries]
        assert variants == ["b2b", "swap", "pcpy"]


class TestClaims:
    def test_all_paper_claims_in_band(self):
        bad = [c for c in evaluate_claims() if not c.ok]
        assert not bad, [f"{c.name}: {c.model_value} not in [{c.lo},{c.hi}]" for c in bad]


class TestPower:
    def test_dma_saves_power_at_bw_bound(self):
        size = 256 * MB
        sim = simulate(allgather_schedule(TOPO, size, "pcpy"), TOPO)
        p_dma = dma_collective_power(TOPO, size, sim).total
        p_cu = cu_collective_power(
            TOPO, size, rccl_collective_latency(TOPO, size, rccl_ag_calibration())).total
        assert p_dma < p_cu

    def test_fewer_engines_less_power(self):
        size = 32 * KB
        p = {}
        for v in ("pcpy", "b2b"):
            sim = simulate(allgather_schedule(TOPO, size, v), TOPO)
            p[v] = dma_collective_power(TOPO, size, sim).total
        assert p["b2b"] < p["pcpy"]


class TestTopologies:
    def test_tpu_topology_reasonable(self):
        t = tpu_v5e_pod(256)
        assert t.n_devices == 256
        assert not t.fully_connected
        assert t.calib.doorbell == 0.0  # no host doorbell on-chip
