"""Multi-schedule composition invariants (DESIGN.md §12).

``run_composed`` executes K independent command streams in ONE resource
world.  This suite pins the contract the serving simulation stands on:

* K=1 composition is BIT-IDENTICAL to ``simulate(..., symmetric=False)``
  (hypothesis-driven across the variant space);
* tag namespacing conserves per-schedule bytes and reduction work, and the
  composed world's aggregate HBM/reduction counters are the sums of the
  isolated runs;
* the composed makespan is bounded below by every isolated latency, no
  stream ever beats its own isolated latency, and per-resource busy time
  is additive when streams are added (contention monotonicity, stated
  modulo Graham-style scheduling anomalies — see the test's docstring);
* two streams sharing one host link serialize (busy time conserved, bounded
  by the makespan) while disjoint-resource streams compose with ZERO
  slowdown — bit-identical to their isolated runs;
* seeded workload generators are reproducible across processes, and one
  small composed serving run is pinned token-for-token (golden TTFTs).

CI runs this file un-skipped (the fast job installs ``hypothesis`` and a
guard step fails if collection comes back empty); locally the hypothesis
tests skip when it is unavailable.
"""
from __future__ import annotations

import subprocess
import sys

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dma import (allgather_schedule, allreduce_schedule,
                            alltoall_schedule, kv_fetch_schedule,
                            link_traffic, mi300x_platform,
                            reduce_scatter_schedule, reduce_work,
                            run_composed, simulate, tpu_v5e_pod)
from repro.core.dma.sim import _namespace_schedule

KB, MB = 1024, 1024 * 1024
TOPO = mi300x_platform()
TPU = tpu_v5e_pod(16)

# One strategy over the whole composable space: (builder, variant) pairs
# spanning baselines, optimized streams (§7), rings and pipelined rings
# (§9), plus reduction collectives (§10).
_BUILDS = [
    (allgather_schedule, "pcpy"), (allgather_schedule, "b2b"),
    (allgather_schedule, "opt_b2b"), (allgather_schedule, "ring"),
    (allgather_schedule, "pipe_bidir_ring"),
    (alltoall_schedule, "swap"), (alltoall_schedule, "opt_pcpy"),
    (alltoall_schedule, "pipe_b2b"),
    (reduce_scatter_schedule, "ring_rs"),
    (reduce_scatter_schedule, "pipe_bidir_ring_rs"),
    (allreduce_schedule, "ring_rs"),
]
builds = st.sampled_from(_BUILDS)
sizes = st.integers(min_value=8 * KB, max_value=64 * MB)
topos = st.sampled_from([TOPO, TPU])


def _build(topo, build, size):
    builder, variant = build
    return builder(topo, size, variant)


def _fetch(device, n_blocks=24, block_bytes=1 * MB, topo=TOPO):
    return kv_fetch_schedule(topo, n_blocks, block_bytes, "opt_prelaunch_b2b",
                             device=device)


# ------------------------------------------------------------------------ #
# K=1 bit-identity                                                         #
# ------------------------------------------------------------------------ #

@settings(max_examples=40, deadline=None)
@given(topos, builds, sizes)
def test_k1_composition_bit_identical_to_simulate(topo, build, size):
    sched = _build(topo, build, size)
    ref = simulate(sched, topo, symmetric=False)
    comp = run_composed([sched], topo)
    res = comp.result
    assert res.latency == ref.latency
    assert res.per_device == ref.per_device
    assert res.busy == ref.busy
    assert res.timelines == ref.timelines
    assert res.host_events == ref.host_events
    assert res.engine_atomics == ref.engine_atomics
    assert res.reduce_chunks == ref.reduce_chunks
    assert res.hbm_bytes == ref.hbm_bytes
    out, = comp.outcomes
    assert out.release == 0.0
    assert out.finish == ref.latency
    assert out.latency == ref.latency


def test_k1_matches_symmetric_fast_path_latency():
    # For a symmetric schedule the full loop equals the fast path, so the
    # composed K=1 latency also equals plain simulate().
    sched = allgather_schedule(TOPO, 4 * MB, "opt_b2b")
    assert run_composed([sched], TOPO).makespan == simulate(sched, TOPO).latency


# ------------------------------------------------------------------------ #
# Conservation under namespacing and composition                           #
# ------------------------------------------------------------------------ #

@settings(max_examples=25, deadline=None)
@given(topos, builds, sizes, st.integers(min_value=0, max_value=5))
def test_namespacing_conserves_bytes_and_reduction_work(topo, build, size, k):
    sched = _build(topo, build, size)
    ns = _namespace_schedule(sched, k)
    assert link_traffic(ns) == link_traffic(sched)
    assert reduce_work(ns) == reduce_work(sched)
    assert not ns.symmetric    # composed streams never take the fast path


def test_composed_counters_are_sums_of_isolated():
    s1 = reduce_scatter_schedule(TOPO, 8 * MB, "ring_rs")
    s2 = alltoall_schedule(TOPO, 4 * MB, "opt_pcpy")
    r1 = simulate(s1, TOPO, symmetric=False)
    r2 = simulate(s2, TOPO, symmetric=False)
    comp = run_composed([s1, s2], TOPO).result
    for d in r1.per_device:
        assert comp.hbm_bytes[d] == r1.hbm_bytes[d] + r2.hbm_bytes[d]
        assert comp.reduce_chunks[d] == (r1.reduce_chunks.get(d, 0)
                                         + r2.reduce_chunks.get(d, 0))
        assert comp.host_events[d] == r1.host_events[d] + r2.host_events[d]


# ------------------------------------------------------------------------ #
# Makespan bounds and contention monotonicity                              #
# ------------------------------------------------------------------------ #

@settings(max_examples=20, deadline=None)
@given(builds, builds, sizes,
       st.floats(min_value=0.0, max_value=5e-4, allow_nan=False))
def test_makespan_at_least_max_isolated(build_a, build_b, size, release):
    a = _build(TOPO, build_a, size)
    b = _build(TOPO, build_b, size)
    iso_a = simulate(a, TOPO, symmetric=False).latency
    iso_b = simulate(b, TOPO, symmetric=False).latency
    comp = run_composed([a, b], TOPO, [0.0, release])
    assert comp.makespan >= iso_a * (1 - 1e-9)
    assert comp.makespan >= release + iso_b * (1 - 1e-9)
    # No schedule beats its own isolated latency inside a shared world
    # (1e-9 slack: float-sum reassociation only).
    assert comp.outcomes[0].latency >= iso_a * (1 - 1e-9)
    assert comp.outcomes[1].latency >= iso_b * (1 - 1e-9)


@settings(max_examples=15, deadline=None)
@given(builds, builds, builds, sizes)
def test_adding_a_schedule_never_speeds_up_existing(build_a, build_b,
                                                    build_c, size):
    """Contention monotonicity, modulo scheduling anomalies.

    Strict per-schedule monotonicity is FALSE in any FIFO resource world
    (Graham's timing anomalies: an extra stream can perturb event
    interleaving so an existing stream grabs a link earlier — observed up
    to ~10% on this simulator).  The invariants that DO hold, and that the
    serving results stand on: a stream never beats its own isolated
    latency, the makespan covers every stream, and per-resource busy time
    is strictly additive when streams are added.
    """
    scheds = [_build(TOPO, bd, size) for bd in (build_a, build_b, build_c)]
    two = run_composed(scheds[:2], TOPO)
    three = run_composed(scheds, TOPO)
    for k in range(2):
        # Anomalies reshuffle queueing; they cannot manufacture bandwidth:
        # a stream never beats its own isolated latency, however the world
        # around it changes (1e-9 slack: float-sum reassociation only).
        iso = simulate(scheds[k], TOPO, symmetric=False).latency
        assert three.outcomes[k].latency >= iso * (1 - 1e-9)
        assert three.makespan >= iso * (1 - 1e-9)
    # Resource-time conservation: the third stream only ADDS busy time —
    # on every resource the 3-stream world's busy equals the 2-stream
    # world's plus the newcomer's isolated busy (transfer durations are
    # closed-form, contention moves them without stretching them).
    iso_c = simulate(scheds[2], TOPO, symmetric=False)
    for res, busy3 in three.result.busy.items():
        expect = two.result.busy.get(res, 0.0) + iso_c.busy.get(res, 0.0)
        assert busy3 == pytest.approx(expect, rel=1e-9, abs=1e-15)


# ------------------------------------------------------------------------ #
# Contention serialization on a shared link                                #
# ------------------------------------------------------------------------ #

def test_shared_hostlink_serializes():
    a, b = _fetch(0), _fetch(0)
    iso = simulate(a, TOPO, symmetric=False)
    comp = run_composed([a, b], TOPO)
    link = "hostlink:0:h2d"
    # Byte-work conservation on the shared link: composed busy time is the
    # sum of the isolated busy times (same transfers, one timeline).
    assert comp.result.busy[link] == pytest.approx(2 * iso.busy[link],
                                                   rel=1e-9)
    # The link serializes: its busy time bounds the makespan from below,
    # and no overlap-free timeline can beat the sum of transfer times.
    assert comp.makespan >= comp.result.busy[link]
    assert comp.outcomes[1].finish >= 2 * iso.busy[link]
    # The second stream pays for the first: both cannot finish at 1x.
    assert comp.outcomes[1].finish > iso.latency
    # Intervals on one timeline never overlap.
    intervals = comp.result.timelines[link]
    for (s0, e0), (s1, e1) in zip(intervals, intervals[1:]):
        assert e0 <= s1 or s1 >= s0  # sorted, coalesced


def test_disjoint_resources_compose_with_zero_slowdown():
    a, b = _fetch(0), _fetch(1)
    ra = simulate(a, TOPO, symmetric=False)
    rb = simulate(b, TOPO, symmetric=False)
    comp = run_composed([a, b], TOPO)
    # Bit-identical finishes: nothing shared, nothing slowed.
    assert comp.outcomes[0].finish == ra.latency
    assert comp.outcomes[1].finish == rb.latency
    assert comp.outcomes[0].per_device[0].as_dict() == \
        ra.per_device[0].as_dict()
    assert comp.outcomes[1].per_device[1].as_dict() == \
        rb.per_device[1].as_dict()


def test_release_shift_translates_lone_schedule():
    sched = _fetch(2)
    iso = simulate(sched, TOPO, symmetric=False).latency
    shift = 1.25e-3
    comp = run_composed([sched], TOPO, [shift])
    assert comp.outcomes[0].finish == pytest.approx(shift + iso, rel=1e-12)
    assert comp.outcomes[0].latency == pytest.approx(iso, rel=1e-9)


# ------------------------------------------------------------------------ #
# Seeded workloads: determinism across processes                           #
# ------------------------------------------------------------------------ #

def test_workload_generators_deterministic():
    from repro.serve.workload import (bursty_arrivals, poisson_arrivals,
                                      synthetic_workload)
    assert poisson_arrivals(100.0, 50, seed=3) == poisson_arrivals(
        100.0, 50, seed=3)
    assert bursty_arrivals(100.0, 50, seed=3) == bursty_arrivals(
        100.0, 50, seed=3)
    assert poisson_arrivals(100.0, 50, seed=3) != poisson_arrivals(
        100.0, 50, seed=4)
    w1 = synthetic_workload(20, 500.0, seed=9, kind="bursty")
    w2 = synthetic_workload(20, 500.0, seed=9, kind="bursty")
    assert w1 == w2
    # Arrivals are strictly increasing and shapes jittered within bounds.
    arr = [r.arrival for r in w1]
    assert arr == sorted(arr)
    assert all(1536 <= r.prompt_tokens <= 2560 for r in w1)


def test_workload_deterministic_across_processes():
    code = ("from repro.serve.workload import poisson_arrivals; "
            "print(repr(poisson_arrivals(250.0, 8, seed=42)))")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                        text=True, check=True).stdout.strip()
    from repro.serve.workload import poisson_arrivals
    assert out == repr(poisson_arrivals(250.0, 8, seed=42))


def test_bursty_mean_rate_is_normalized():
    from repro.serve.workload import bursty_arrivals
    arr = bursty_arrivals(200.0, 4000, seed=0)
    rate = len(arr) / arr[-1]
    assert rate == pytest.approx(200.0, rel=0.15)


# ------------------------------------------------------------------------ #
# Golden trace: one small composed serving run, pinned exactly             #
# ------------------------------------------------------------------------ #

def test_golden_serving_trace():
    """Per-request TTFTs of a small contended run, byte-for-byte.

    The whole §12 stack — seeded workload, admission, remainder carryover,
    run_composed — is deterministic pure Python/numpy, so exact float
    equality is the right pin: any behavioral drift (event ordering, tag
    namespacing, fluid-progress accounting) shows up here first.
    """
    from repro.serve.engine import ServingConfig, ServingSimulator
    from repro.serve.workload import synthetic_workload
    wl = synthetic_workload(6, 1800.0, seed=11, kind="bursty",
                            prompt_tokens=2048, output_tokens=2,
                            burst_factor=10.0, p_enter=0.4, p_exit=0.1)
    rep = ServingSimulator(ServingConfig()).run(wl)
    assert [t.ttft for t in rep.timings] == GOLDEN_TTFTS
    assert rep.makespan == GOLDEN_MAKESPAN


GOLDEN_TTFTS = [
    0.006820849473559791,
    0.006701581586704549,
    0.006746553942608438,
    0.00741472298598555,
    0.007356276562592923,
    0.007392437572635273,
]
GOLDEN_MAKESPAN = 0.0106606932103967
