"""Event-simulator core tests: contended resources, torus routing,
cross-device waits, the symmetric fast path, dispatch derivation, the
optimized command streams (DESIGN.md §7), chunked transfers plus the
hot-path overhaul (DESIGN.md §8), the per-chunk-signaled pipelined
rings (DESIGN.md §9), and the reduce collectives (DESIGN.md §10)."""
import pytest

from repro.core.dma import (
    allgather_schedule, allreduce_schedule, alltoall_schedule, batch_commands,
    candidate_variants, chunk_schedule, commands as cmd, derive_dispatch,
    fuse_signals, mi300x_platform, optimize, pipelined_variants,
    reduce_scatter_schedule, reduce_variants, reduce_work, simulate,
    split_queues, tpu_v5e_pod, variant_latency,
)
from repro.core.dma.claims import (
    optimized_power_claims,
    optimized_stream_claims,
    pipe_vs_final_chunk_ratio,
    pipelined_stream_claims,
    reduce_stream_claims,
    rs_pipe_vs_final_chunk_ratio,
)
from repro.core.dma.commands import CmdKind, EngineQueue, Schedule
from repro.core.dma.optimizations import OptimizationConfig

KB, MB = 1024, 1024 * 1024
MI = mi300x_platform()
TPU = tpu_v5e_pod(16)


def _single(topo, queues):
    return simulate(Schedule("t", tuple(queues)), topo)


class TestLinkContention:
    def test_two_copies_one_link_serialize(self):
        """Two engines pushing the same directed link take ~2x the wire time."""
        size = 64 * MB
        one = _single(MI, [EngineQueue(0, 0, (cmd.copy(0, 1, size), cmd.signal()))])
        two = _single(MI, [
            EngineQueue(0, 0, (cmd.copy(0, 1, size), cmd.signal())),
            EngineQueue(0, 1, (cmd.copy(0, 1, size), cmd.signal())),
        ])
        wire = size / (MI.link_bw * MI.calib.dma_link_efficiency)
        assert two.latency - one.latency == pytest.approx(wire, rel=0.05)

    def test_distinct_links_overlap(self):
        """Same two copies on distinct links run concurrently."""
        size = 64 * MB
        two_links = _single(MI, [
            EngineQueue(0, 0, (cmd.copy(0, 1, size), cmd.signal())),
            EngineQueue(0, 1, (cmd.copy(0, 2, size), cmd.signal())),
        ])
        same_link = _single(MI, [
            EngineQueue(0, 0, (cmd.copy(0, 1, size), cmd.signal())),
            EngineQueue(0, 1, (cmd.copy(0, 1, size), cmd.signal())),
        ])
        assert two_links.latency < same_link.latency * 0.75

    def test_host_link_shared_across_engines(self):
        """All engines of a device contend for the one PCIe link."""
        size = 16 * MB
        fan1 = _single(MI, [EngineQueue(0, 0, (cmd.copy("host", 0, 4 * size), cmd.signal()))])
        fan4 = _single(MI, [
            EngineQueue(0, e, (cmd.copy("host", 0, size), cmd.signal()))
            for e in range(4)
        ])
        # fan-out cannot beat the shared wire: same bytes over the same link
        wire = 4 * size / (MI.host_link_bw * MI.calib.dma_link_efficiency)
        assert fan4.busy["hostlink:0:h2d"] == pytest.approx(wire, rel=1e-9)
        assert fan4.latency >= wire
        assert fan4.latency >= fan1.latency * 0.9


class TestTorusRouting:
    def test_route_lengths(self):
        assert TPU.grid == (4, 4)
        assert len(TPU.route(0, 1)) == 1
        assert len(TPU.route(0, 2)) == 2
        assert len(TPU.route(0, 10)) == 4          # 2 row + 2 col hops
        assert len(TPU.route(0, 3)) == 1           # wraparound
        assert len(TPU.route(0, 12)) == 1          # column wraparound

    def test_two_hop_step_strictly_slower(self):
        """Acceptance: a 2-hop all-gather step is strictly slower than 1-hop."""
        size = 1 * MB
        one = _single(TPU, [EngineQueue(0, 0, (cmd.copy(0, 1, size), cmd.signal()))])
        two = _single(TPU, [EngineQueue(0, 0, (cmd.copy(0, 2, size), cmd.signal()))])
        assert two.latency > one.latency

    def test_multihop_occupies_every_link(self):
        size = 1 * MB
        r = _single(TPU, [EngineQueue(0, 0, (cmd.copy(0, 2, size), cmd.signal()))])
        assert r.busy.get("link:0>1", 0.0) > 0.0
        assert r.busy.get("link:1>2", 0.0) > 0.0

    def test_ring_order_is_neighbor_adjacent(self):
        order = TPU.ring_order()
        n = len(order)
        assert sorted(order) == list(range(n))
        for i in range(n):
            assert TPU.is_neighbor(order[i], order[(i + 1) % n]), (order[i], order[(i + 1) % n])

    def test_mi300x_all_direct(self):
        for dst in range(1, MI.n_devices):
            assert MI.route(0, dst) == ((0, dst),)


class TestWaits:
    def test_ring_times_from_signal_arrival(self):
        """n-1 chained ring steps cost at least n-1 serialized (wire+sync)."""
        size = 16 * MB
        n = TPU.n_devices
        shard = size // n
        wire = shard / (TPU.link_bw * TPU.calib.dma_link_efficiency)
        lat = variant_latency(TPU, "all_gather", size, "ring")
        assert lat >= (n - 1) * (wire + TPU.calib.sync_engine)

    def test_bidir_ring_faster_than_ring(self):
        """Half the chained steps -> strictly faster at every size."""
        for size in (64 * KB, 4 * MB, 256 * MB):
            assert variant_latency(TPU, "all_gather", size, "bidir_ring") < \
                variant_latency(TPU, "all_gather", size, "ring")

    def test_missing_signal_deadlocks(self):
        q = EngineQueue(0, 0, (cmd.wait(("nope", 1, 0)), cmd.copy(0, 1, KB), cmd.signal()))
        with pytest.raises(RuntimeError, match="deadlock"):
            simulate(Schedule("t", (q,)), MI)


class TestSymmetricFastPath:
    @pytest.mark.parametrize("coll,variant", [
        ("all_gather", "pcpy"), ("all_gather", "bcst"), ("all_gather", "b2b"),
        ("all_gather", "prelaunch_pcpy"), ("all_to_all", "pcpy"),
    ])
    def test_bit_identical_on_mi300x(self, coll, variant):
        builder = allgather_schedule if coll == "all_gather" else alltoall_schedule
        sched = builder(MI, 4 * MB, variant)
        assert sched.symmetric
        full = simulate(sched, MI, symmetric=False)
        fast = simulate(sched, MI, symmetric=True)
        assert fast.latency == full.latency
        assert fast.per_device == full.per_device
        assert fast.engines_used == full.engines_used
        assert fast.hbm_bytes == full.hbm_bytes

    @pytest.mark.parametrize("coll,variant", [
        ("all_gather", "ring"), ("all_gather", "bidir_ring"),
        ("all_gather", "prelaunch_ring"), ("all_to_all", "ring"),
    ])
    def test_bit_identical_on_torus_rings(self, coll, variant):
        builder = allgather_schedule if coll == "all_gather" else alltoall_schedule
        sched = builder(TPU, 4 * MB, variant)
        assert sched.symmetric
        full = simulate(sched, TPU, symmetric=False)
        fast = simulate(sched, TPU, symmetric=True)
        assert fast.latency == full.latency
        assert fast.per_device == full.per_device

    def test_swap_not_marked_symmetric(self):
        """Executor alternation gives devices different command counts."""
        assert not alltoall_schedule(MI, 4 * MB, "swap").symmetric

    def test_multihop_direct_not_marked_symmetric(self):
        """Transit traffic shares links across devices on the torus."""
        assert not allgather_schedule(TPU, 4 * MB, "pcpy").symmetric

    @pytest.mark.parametrize("n", [9, 15])
    def test_odd_grid_ring_not_marked_symmetric(self, n):
        """On odd-by-odd grids the snake ring's wraparound is multi-hop, so
        devices are NOT symmetric; the builder must force the full sim."""
        topo = tpu_v5e_pod(n)
        sched = allgather_schedule(topo, 1 * MB, "ring")
        assert not sched.symmetric
        # sanity: the full sim really differs from a (wrong) symmetric run
        full = simulate(sched, topo, symmetric=False)
        forced = simulate(sched, topo, symmetric=True)
        assert forced.latency < full.latency


class TestUtilization:
    def test_busy_and_timelines_exposed(self):
        r = simulate(allgather_schedule(MI, 64 * MB, "pcpy"), MI)
        assert any(k.startswith("link:") for k in r.busy)
        assert any(k.startswith("engine:") for k in r.busy)
        assert any(k.startswith("host:") for k in r.busy)
        for k, iv in r.timelines.items():
            for s, e in iv:
                assert e >= s >= 0.0
        assert 0.0 < r.utilization(next(k for k in r.busy if k.startswith("link:"))) <= 1.0

    def test_link_busy_tracks_wire_time(self):
        size = 256 * MB
        r = simulate(allgather_schedule(MI, size, "pcpy"), MI)
        shard = size // MI.n_devices
        wire = shard / (MI.link_bw * MI.calib.dma_link_efficiency)
        dev = r.representative if r.representative is not None else 0
        assert r.link_busy_seconds(dev) == pytest.approx(7 * wire, rel=1e-6)


def _traffic(sched):
    """Multiset of (src, dsts, size) over all data commands."""
    return sorted((c.src, c.dsts, c.size)
                  for q in sched.queues for c in q.data_commands)


class TestOptimizedBatching:
    """§7.1 — batched doorbell/command scheduling."""

    def test_host_cost_monotonically_amortizes_in_n(self):
        """Bigger submission batches never increase the control phase, and
        any batching strictly beats one-command-per-event."""
        sched = allgather_schedule(MI, 64 * KB, "b2b")
        base = simulate(sched, MI).per_device[0].control
        prev = base
        for n in (2, 4, 8, 16, 32):
            ctl = simulate(batch_commands(sched, n), MI).per_device[0].control
            assert ctl < base
            assert ctl <= prev + 1e-15, n
            prev = ctl

    def test_batched_doorbells_cheaper(self):
        """pcpy rings 7 doorbells; batched submission amortizes them."""
        sched = allgather_schedule(MI, 64 * KB, "pcpy")
        base = simulate(sched, MI)
        opt = simulate(batch_commands(sched, 8), MI)
        assert opt.per_device[0].schedule < base.per_device[0].schedule
        assert opt.latency < base.latency

    def test_batch_one_is_identity(self):
        sched = allgather_schedule(MI, 1 * MB, "b2b")
        assert simulate(batch_commands(sched, 1), MI).latency == \
            simulate(sched, MI).latency


class TestOptimizedMultiQueue:
    """§7.2 — SDMA queue-level parallelism."""

    def _split_b2b(self, size):
        sched = allgather_schedule(MI, size, "b2b")
        # Lowered gates: exercise the slot mechanics on stream-bound queues
        # that the default issue-bound gates would (rightly) leave alone.
        return sched, split_queues(sched, 4, min_commands=2,
                                   max_bytes=MI.calib.max_chunk_bytes)

    def test_split_preserves_traffic_and_engine_count(self):
        sched, split = self._split_b2b(8 * MB)
        assert _traffic(split) == _traffic(sched)
        assert split.engines_used(0) == sched.engines_used(0) == 1
        assert len(split.queues_for(0)) == 4
        assert {q.slot for q in split.queues_for(0)} == {0, 1, 2, 3}

    def test_overlap_never_exceeds_engine_bandwidth(self):
        """However many slots, the engine's streaming capacity binds: all
        slot traffic flows through the one engine:<dev>.<e> resource."""
        size = 512 * MB
        _, split = self._split_b2b(size)
        res = simulate(split, MI, symmetric=False)
        shard = size // MI.n_devices
        stream_floor = 7 * shard / MI.calib.engine_bw
        assert res.latency >= stream_floor
        assert res.busy["engine:0.0"] >= stream_floor

    def test_slots_overlap_front_end_issue(self):
        """For a long issue-bound stream (many tiny commands on one engine),
        per-slot decode overlap beats the single serial front end."""
        copies = tuple(cmd.copy(0, 1 + (i % 7), 4 * KB) for i in range(64))
        one = Schedule("issue_bound", (
            EngineQueue(0, 0, copies + (cmd.signal(),)),))
        split = split_queues(one, 4, min_commands=2)
        assert len(split.queues) == 4
        base = simulate(optimize(one, OptimizationConfig(queues_per_engine=1)), MI)
        opt = simulate(optimize(one), MI)
        assert opt.latency < base.latency

    def test_chained_ring_queues_not_split(self):
        """Queues with cross-device ordering must keep their command order."""
        sched = allgather_schedule(TPU, 8 * MB, "ring")
        assert split_queues(sched, 4, min_commands=2).queues == sched.queues

    def test_min_commands_gates_short_queues(self):
        """The 7-command b2b queue stays unsplit at the default threshold:
        streaming hides the front end, so the extra fences would only hurt."""
        sched = allgather_schedule(MI, 8 * MB, "b2b")
        assert split_queues(sched, 4).queues == sched.queues

    def test_fused_queues_not_split(self):
        """Reversed composition order must be a no-op, not signal inflation:
        split(fuse(s)) may not add standalone completions on top of the
        fused ones."""
        fused = fuse_signals(allgather_schedule(MI, 8 * MB, "b2b"))
        again = split_queues(fused, 4, min_commands=2)
        assert again.queues == fused.queues
        assert sum(q.n_signals for q in again.queues_for(0)) == 1

    def test_unbatched_queue_breaks_scheduling_event(self):
        """Doorbell and control batching agree on event boundaries: a
        baseline queue between two batched ones restarts the event, so all
        three doorbells ring at full cost."""
        import dataclasses
        qs = [EngineQueue(0, e, (cmd.copy(0, e + 1, 64 * KB), cmd.signal()))
              for e in range(3)]
        qs[0] = dataclasses.replace(qs[0], batch=8)
        qs[2] = dataclasses.replace(qs[2], batch=8)
        res = simulate(Schedule("mixed", tuple(qs)), MI, symmetric=False)
        c = MI.calib
        assert res.per_device[0].schedule == pytest.approx(
            3 * c.doorbell + c.fetch, rel=1e-9)
        assert res.per_device[0].control == pytest.approx(
            2 * (c.control + c.control_batched) + 2 * c.control, rel=1e-9)


class TestOptimizedFusedSignaling:
    """§7.3 — fused write+signal."""

    def test_removes_exactly_one_host_event_per_step(self):
        """Every ring step's standalone signal command fuses into its copy:
        one fewer host command-creation event per step (plus the trailing
        completion), and the control phase shrinks by exactly that much."""
        n = TPU.n_devices
        sched = allgather_schedule(TPU, 16 * MB, "ring")
        fused = fuse_signals(sched)
        steps = n - 1
        for d in sched.devices:
            before = sum(len(q.commands) for q in sched.queues_for(d))
            after = sum(len(q.commands) for q in fused.queues_for(d))
            assert before - after == steps + 1     # per-step tag + completion
            assert sum(1 for q in fused.queues_for(d) for c in q.commands
                       if c.kind is CmdKind.SIGNAL) == 0
        ctl_before = simulate(sched, TPU).per_device[0].control
        ctl_after = simulate(fused, TPU).per_device[0].control
        assert ctl_before - ctl_after == pytest.approx(
            (steps + 1) * TPU.calib.control, rel=1e-9)

    def test_fused_ring_chains_without_engine_round_trip(self):
        base = simulate(allgather_schedule(TPU, 4 * MB, "ring"), TPU)
        fused = simulate(fuse_signals(allgather_schedule(TPU, 4 * MB, "ring")), TPU)
        assert fused.latency < base.latency
        saved = base.latency - fused.latency
        n_steps = TPU.n_devices - 1
        # each chained step replaced sync_engine by fused_sync
        assert saved >= n_steps * (TPU.calib.sync_engine - TPU.calib.fused_sync) * 0.9

    def test_idempotent(self):
        sched = allgather_schedule(MI, 1 * MB, "pcpy")
        once = fuse_signals(sched)
        assert fuse_signals(once).queues == once.queues

    def test_fused_completion_still_observed_by_host(self):
        sched = fuse_signals(allgather_schedule(MI, 1 * MB, "pcpy"))
        for q in sched.queues:
            assert q.n_signals == 1               # fused, but still host-visible
        assert simulate(sched, MI).per_device[0].sync > 0.0


class TestOptimizedStreams:
    """Composition (`optimize` / opt_ variants) and the §7 claim bands."""

    @pytest.mark.parametrize("coll,variant", [
        ("all_gather", "opt_pcpy"), ("all_gather", "opt_b2b"),
        ("all_gather", "opt_prelaunch_pcpy"), ("all_to_all", "opt_pcpy"),
    ])
    def test_symmetric_fast_path_bit_identical(self, coll, variant):
        builder = allgather_schedule if coll == "all_gather" else alltoall_schedule
        sched = builder(MI, 4 * MB, variant)
        assert sched.symmetric
        full = simulate(sched, MI, symmetric=False)
        fast = simulate(sched, MI, symmetric=True)
        assert fast.latency == full.latency
        assert fast.per_device == full.per_device

    def test_opt_ring_bit_identical_on_torus(self):
        sched = allgather_schedule(TPU, 4 * MB, "opt_ring")
        assert sched.symmetric
        assert simulate(sched, TPU, symmetric=True).latency == \
            simulate(sched, TPU, symmetric=False).latency

    def test_optimize_preserves_traffic(self):
        for coll, variant in (("all_gather", "pcpy"), ("all_gather", "b2b"),
                              ("all_to_all", "swap"), ("all_gather", "ring")):
            builder = allgather_schedule if coll == "all_gather" else alltoall_schedule
            topo = TPU if variant == "ring" else MI
            assert _traffic(builder(topo, 8 * MB, f"opt_{variant}")) == \
                _traffic(builder(topo, 8 * MB, variant)), (coll, variant)

    def test_optimized_beats_baseline_where_it_matters(self):
        """opt_ strictly improves the un-prelaunched streams at every size,
        and the prelaunched ones wherever fusion has a signal to absorb."""
        for v in ("pcpy", "b2b"):
            for size in (4 * KB, 1 * MB, 64 * MB):
                assert variant_latency(MI, "all_gather", size, f"opt_{v}") < \
                    variant_latency(MI, "all_gather", size, v), (v, size)
        for size in (4 * KB, 64 * MB):
            assert variant_latency(MI, "all_gather", size, "opt_prelaunch_pcpy") < \
                variant_latency(MI, "all_gather", size, "prelaunch_pcpy")

    def test_opt_config_validation(self):
        with pytest.raises(ValueError):
            OptimizationConfig(batch=0)
        with pytest.raises(ValueError):
            OptimizationConfig(queues_per_engine=0)

    def test_optimized_claim_bands_hold(self):
        """The simulator's optimized schedules land inside the paper's
        bands: AG ~30% slower / AA ~20% faster than RCCL at small sizes,
        ~7% gain over pcpy at large sizes (DESIGN.md §7)."""
        bad = [c for c in optimized_stream_claims() if not c.ok]
        assert not bad, [
            f"{c.name}: {c.model_value} not in [{c.lo},{c.hi}]" for c in bad]

    def test_optimized_dispatch_structure(self):
        """With the §7 streams available, the argmin keeps the Table 2
        shape (b2b -> bcst -> pcpy) but picks optimized streams."""
        sizes = [2 ** i for i in range(10, 33)]
        entries = derive_dispatch(MI, "all_gather", sizes, allow_optimized=True)
        assert all(e.variant.startswith("opt_") for e in entries)
        bases = [e.variant.replace("opt_", "").replace("prelaunch_", "")
                 for e in entries]
        assert bases == ["b2b", "bcst", "pcpy"]


# Schedule-level traffic accounting now lives in the command layer
# (chunk/pipe-invariant by construction); keep the short local name.
_link_traffic = cmd.link_traffic


class TestChunking:
    """Chunked sDMA transfers (DESIGN.md §8.1) + the hot-path fast paths."""

    GB = 1024 * MB

    def test_traffic_conserved_under_chunking(self):
        """Chunking never changes WHAT is transferred: per-(src, dst) byte
        totals are identical to the monolithic schedule, every variant."""
        for coll, variant in (("all_gather", "pcpy"), ("all_gather", "b2b"),
                              ("all_gather", "bcst"), ("all_to_all", "swap")):
            builder = allgather_schedule if coll == "all_gather" else alltoall_schedule
            mono = builder(MI, 1 * self.GB, variant, max_chunk_bytes=0)
            chunked = builder(MI, 1 * self.GB, variant)
            assert sum(len(q.data_commands) for q in chunked.queues) > \
                sum(len(q.data_commands) for q in mono.queues)
            assert _link_traffic(chunked) == _link_traffic(mono), (coll, variant)

    def test_chunked_link_busy_equals_monolithic(self):
        """Same bytes -> same wire-busy seconds per directed link."""
        mono = simulate(allgather_schedule(MI, 1 * self.GB, "pcpy",
                                           max_chunk_bytes=0), MI)
        chunked = simulate(allgather_schedule(MI, 1 * self.GB, "pcpy"), MI)
        links = {k for k in mono.busy if k.startswith("link:")}
        assert links == {k for k in chunked.busy if k.startswith("link:")}
        for k in links:
            assert chunked.busy[k] == pytest.approx(mono.busy[k], rel=1e-9), k

    def test_completion_monotone_in_chunk_count(self):
        """At fixed size, more chunks (smaller max_chunk_bytes) never get
        faster: per-chunk issue/packet costs only add."""
        size = 512 * MB
        prev = 0.0
        for chunk in (0, 64 * MB, 16 * MB, 4 * MB, 1 * MB, 256 * KB):
            lat = variant_latency(MI, "all_gather", size, "pcpy", chunk)
            assert lat >= prev, chunk
            prev = lat

    def test_fused_signal_rides_final_chunk_only(self):
        """opt_ chunked streams fuse the completion onto the LAST chunk."""
        sched = allgather_schedule(MI, 1 * self.GB, "opt_pcpy")
        for q in sched.queues:
            data = q.data_commands
            assert len(data) == 32                  # 128MB shard / 4MB chunks
            assert data[-1].fused_signal
            assert not any(c.fused_signal or c.fused_tag for c in data[:-1])
            assert q.n_signals == 1

    @pytest.mark.parametrize("variant", ["pcpy", "opt_pcpy", "b2b", "opt_b2b",
                                         "prelaunch_pcpy"])
    def test_symmetric_fast_path_bit_identical_chunked(self, variant):
        sched = allgather_schedule(MI, 1 * self.GB, variant)
        assert sched.symmetric
        full = simulate(sched, MI, symmetric=False)
        fast = simulate(sched, MI, symmetric=True)
        assert fast.latency == full.latency
        assert fast.per_device == full.per_device
        assert fast.host_events == full.host_events
        assert fast.engine_atomics == full.engine_atomics

    def test_chunk_run_fast_path_matches_per_chunk_loop(self):
        """The closed-form run (§8.3: identical commands share one object)
        must time exactly like the generic loop over distinct-but-equal
        commands (which cannot coalesce and takes the per-chunk path)."""
        n, size = 64, 4 * MB
        shared = cmd.copy(0, 1, size)
        run_q = EngineQueue(0, 0, (shared,) * n + (cmd.signal(),))
        loose_q = EngineQueue(0, 0, tuple(cmd.copy(0, 1, size) for _ in range(n))
                              + (cmd.signal(),))
        fast = simulate(Schedule("run", (run_q,)), MI)
        slow = simulate(Schedule("loose", (loose_q,)), MI)
        # closed form multiplies where the loop accumulates -> ulp tolerance
        assert fast.latency == pytest.approx(slow.latency, rel=1e-12)
        for ph in ("control", "schedule", "copy", "sync"):
            assert getattr(fast.per_device[0], ph) == \
                pytest.approx(getattr(slow.per_device[0], ph), rel=1e-12, abs=1e-15)
        assert fast.busy["link:0>1"] == pytest.approx(
            slow.busy["link:0>1"], rel=1e-12)
        assert len(fast.timelines["link:0>1"]) == 1   # coalesced run interval

    def test_issue_bound_run_falls_back_exactly(self):
        """Tiny chunks (wire < b2b_issue) gap on the engine; the fast path
        must decline and the per-chunk loop must produce identical timing."""
        n = 32
        shared = cmd.copy(0, 1, 1024)       # 1KB: wire 16ns << b2b_issue
        run_q = EngineQueue(0, 0, (shared,) * n + (cmd.signal(),))
        loose_q = EngineQueue(0, 0, tuple(cmd.copy(0, 1, 1024) for _ in range(n))
                              + (cmd.signal(),))
        fast = simulate(Schedule("run", (run_q,)), MI)
        slow = simulate(Schedule("loose", (loose_q,)), MI)
        assert fast.latency == pytest.approx(slow.latency, rel=1e-12)

    def test_chunk_schedule_noop_below_threshold(self):
        sched = allgather_schedule(MI, 8 * MB, "pcpy", max_chunk_bytes=0)
        assert chunk_schedule(sched, 4 * MB) is sched

    def test_remainder_chunk(self):
        (a, b) = cmd.chunk_command(cmd.copy(0, 1, 5 * MB), 4 * MB)
        assert (a.size, b.size) == (4 * MB, 1 * MB)
        assert cmd.chunk_command(cmd.copy(0, 1, 4 * MB), 4 * MB) == \
            (cmd.copy(0, 1, 4 * MB),)

    def test_optimized_power_claim_band(self):
        """§8.4: the opt_ streams' 3-10% additional power saving holds."""
        bad = [c for c in optimized_power_claims() if not c.ok]
        assert not bad, [
            f"{c.name}: {c.model_value} not in [{c.lo},{c.hi}]" for c in bad]

    def test_host_events_and_atomics_counted(self):
        base = simulate(allgather_schedule(MI, 64 * KB, "pcpy"), MI)
        opt = simulate(allgather_schedule(MI, 64 * KB, "opt_pcpy"), MI)
        # pcpy: 14 packet-creation events + 7 doorbells + 1 drain; 7 atomics.
        assert base.host_events[0] == 22
        assert base.engine_atomics[0] == 7
        # opt: 7 fused commands fill ONE batch-8 creation event, + 1 full
        # doorbell (rest ring batched) + 1 drain; every signal fused away.
        assert opt.host_events[0] == 3
        assert opt.engine_atomics[0] == 0

    def test_dispatch_chunk_sweep_records_chunk(self):
        sizes = [2 ** i for i in range(10, 33)]
        entries = derive_dispatch(MI, "all_gather", sizes,
                                  chunk_sizes=(None, 1 * MB))
        assert all(e.chunk in (None, 1 * MB) for e in entries)
        # the calibrated default wins when finer chunks only add overhead
        assert entries[0].chunk is None


class TestPipelinedRings:
    """Per-chunk signaling + pipelined ring collectives (DESIGN.md §9)."""

    def test_pipe_beats_final_chunk_signaling_monotone(self):
        """THE §9 acceptance claim: per-chunk signaling beats final-chunk-only
        signaling of the same pipe_b2b schedule at >= 2 chunks, with the
        improvement monotone in chunk count up to the sweep ceiling
        (PIPE_DEPTH = 4) and still > 1 one doubling past it."""
        for size in (512 * KB, 1 * MB):
            f = {d: pipe_vs_final_chunk_ratio(TPU, size, d) for d in (1, 2, 4, 8)}
            assert f[1] == pytest.approx(1.0, abs=1e-9), size   # structural
            assert f[2] > 1.05, (size, f)                       # beats at 2 chunks
            assert f[4] > f[2], (size, f)                       # monotone to ceiling
            assert f[8] > 1.0, (size, f)                        # saturates, not flips

    def test_pipe_beats_fco_midband(self):
        """>= 2 chunks wins across the whole §9 mid-size band on the torus."""
        for size in (2 * MB, 4 * MB, 8 * MB, 32 * MB):
            assert pipe_vs_final_chunk_ratio(TPU, size, 2) > 1.0, size

    def test_pipelined_claim_bands(self):
        bad = [c for c in pipelined_stream_claims() if not c.ok]
        assert not bad, [
            f"{c.name}: {c.model_value} not in [{c.lo},{c.hi}]" for c in bad]

    def test_pipe_traffic_matches_ring(self):
        """Pipelining never changes WHAT moves: per-(src, dst) byte totals of
        pipe_b2b equal the chained ring's, at every pipeline depth."""
        ring = _link_traffic(allgather_schedule(TPU, 64 * MB, "ring"))
        for depth in (1, 2, 4, 8):
            pipe = _link_traffic(allgather_schedule(TPU, 64 * MB, "pipe_b2b",
                                                    pipe_depth=depth))
            assert pipe == ring, depth
        aa_ring = _link_traffic(alltoall_schedule(TPU, 64 * MB, "ring"))
        aa_pipe = _link_traffic(alltoall_schedule(TPU, 64 * MB, "pipe_b2b"))
        assert aa_pipe == aa_ring

    def test_pipe_bidir_traffic_matches_bidir_ring(self):
        assert _link_traffic(allgather_schedule(TPU, 64 * MB, "pipe_bidir_ring")) \
            == _link_traffic(allgather_schedule(TPU, 64 * MB, "bidir_ring"))

    @pytest.mark.parametrize("variant", [
        "pipe_b2b", "pipe_bidir_ring", "opt_pipe_b2b", "opt_pipe_bidir_ring",
        "prelaunch_pipe_b2b", "opt_prelaunch_pipe_bidir_ring"])
    @pytest.mark.parametrize("topo", [MI, TPU], ids=["mi300x", "tpu16"])
    def test_pipe_symmetric_fast_path_bit_identical(self, topo, variant):
        """Chain-local engine sharing keeps the pipelined rings
        translation-invariant (see _pipe_bidir_ag_queues): the one-device
        fast path must replicate the full simulation exactly."""
        sched = allgather_schedule(topo, 8 * MB, variant)
        assert sched.symmetric
        full = simulate(sched, topo, symmetric=False)
        fast = simulate(sched, topo, symmetric=True)
        assert fast.latency == full.latency
        assert fast.per_device == full.per_device

    def test_pipe_asymmetric_ring_runs_full_sim(self):
        """On an odd-row torus the snake ring's wraparound is multi-hop:
        pipe schedules are not symmetric there, and the chunk-granularity
        waits must still resolve (no deadlock) in the full event loop."""
        topo = tpu_v5e_pod(15)           # 3x5 grid, odd rows
        sched = allgather_schedule(topo, 4 * MB, "pipe_b2b")
        assert not sched.symmetric
        res = simulate(sched, topo)
        assert 0 < res.latency < 1.0

    def test_pipe_chunk_waits_serialize_consumer(self):
        """A consumer waiting on chunk i of a per-chunk-tagged producer
        starts mid-transfer; waiting on the final chunk starts after the
        whole transfer.  Pins the §9 semantics at the command level."""
        size, g = 8 * MB, 1 * MB
        chunks = cmd.chunked_copies(CmdKind.COPY, 0, (1,), size, g, ("t", 0, 0))
        assert len(chunks) == 8
        base = (EngineQueue(0, 0, tuple(chunks) + (cmd.signal(),)),)
        early = simulate(Schedule("e", base + (EngineQueue(
            1, 0, (cmd.wait(cmd.chunk_tag(("t", 0, 0), 0)),
                   cmd.copy(1, 2, size), cmd.signal())),)), MI)
        late = simulate(Schedule("l", base + (EngineQueue(
            1, 0, (cmd.wait(cmd.chunk_tag(("t", 0, 0), 7)),
                   cmd.copy(1, 2, size), cmd.signal())),)), MI)
        wire = g / (MI.link_bw * MI.calib.dma_link_efficiency)
        assert late.latency - early.latency == pytest.approx(7 * wire, rel=0.01)

    def test_tagged_chunk_run_closed_form_matches_loop(self):
        """The §9.2 equivalent-modulo-tag closed form must time (and raise
        every chunk tag) exactly like the per-chunk loop."""
        from repro.core.dma import sim as sim_mod

        sched = allgather_schedule(TPU, 32 * MB, "pipe_b2b", pipe_depth=8)
        fast = simulate(sched, TPU)
        orig = sim_mod._Sim._chunk_run
        sim_mod._Sim._chunk_run = lambda *a, **k: False
        try:
            slow = simulate(sched, TPU)
        finally:
            sim_mod._Sim._chunk_run = orig
        assert fast.latency == pytest.approx(slow.latency, rel=1e-12)
        for d in fast.per_device:
            for ph in ("control", "schedule", "copy", "sync"):
                assert getattr(fast.per_device[d], ph) == pytest.approx(
                    getattr(slow.per_device[d], ph), rel=1e-12, abs=1e-15)

    def test_fuse_signals_is_per_chunk(self):
        """§9 interaction with §7.3: a stream signaling after EVERY chunk
        fuses each semaphore onto its own chunk — bit-identical to the
        per-chunk-tagged commands chunked_copies emits directly."""
        size, g = 8 * MB, 2 * MB
        tag = ("t", 0, 0)
        unfused = []
        for i, c in enumerate(cmd.chunked_copies(CmdKind.COPY, 0, (1,), size, g)):
            unfused += [c, cmd.signal(cmd.chunk_tag(tag, i))]
        fused = fuse_signals(Schedule("s", (EngineQueue(0, 0, tuple(unfused)),)))
        want = cmd.chunked_copies(CmdKind.COPY, 0, (1,), size, g, tag)
        assert fused.queues[0].commands == want

    def test_opt_pipe_composition(self):
        """optimize() on a pipe schedule batches every queue, fuses the
        trailing completion onto the last chunk, and never splits the
        chunk-ordered queues across SDMA slots."""
        base = allgather_schedule(TPU, 8 * MB, "pipe_b2b")
        opt = allgather_schedule(TPU, 8 * MB, "opt_pipe_b2b")
        assert {q.slot for q in opt.queues} == {0}
        assert all(q.batch > 1 for q in opt.queues)
        assert sum(q.n_signals for q in opt.queues) == \
            sum(q.n_signals for q in base.queues)
        finals = [q for q in opt.queues
                  if any(c.fused_signal for c in q.commands)]
        assert len(finals) == TPU.n_devices     # one fused completion/device
        assert not any(c.kind is CmdKind.SIGNAL and c.tag is None
                       for q in opt.queues for c in q.commands)

    def test_pipe_depth_one_equals_final_chunk_only(self):
        """Depth 1 has one chunk per shard: per-chunk and final-chunk-only
        signaling build identical schedules."""
        a = allgather_schedule(TPU, 1 * MB, "pipe_b2b", pipe_depth=1)
        b = allgather_schedule(TPU, 1 * MB, "pipe_b2b", pipe_depth=1,
                               per_chunk_signaling=False)
        assert tuple(q.commands for q in a.queues) == \
            tuple(q.commands for q in b.queues)

    def test_pipelined_dispatch_candidates(self):
        """pipe_ variants join the sweep only on neighbor-link topologies."""
        tpu_vs = pipelined_variants(TPU, "all_gather")
        assert "pipe_b2b" in tpu_vs and "opt_prelaunch_pipe_bidir_ring" in tpu_vs
        assert pipelined_variants(MI, "all_gather") == []   # fully connected
        entries = derive_dispatch(TPU, "all_gather",
                                  [2 ** i for i in range(10, 31)],
                                  allow_pipelined=True)
        assert any("pipe_" in e.variant for e in entries)


class TestReduceScatter:
    """Reduce collectives (DESIGN.md §10): per-chunk reduction costs,
    pipelined reduce-scatter, the all-reduce composition and their claim
    bands."""

    def test_reduce_term_charged(self):
        """A reduce-scatter carries strictly more work than the same ring's
        all-gather (same traffic + n-1 per-shard reductions), and the
        reduce term scales with the calibrated throughput."""
        size = 8 * MB
        rs = variant_latency(TPU, "reduce_scatter", size, "ring_rs")
        ag = variant_latency(TPU, "all_gather", size, "ring")
        assert rs > ag
        import dataclasses
        fast_calib = dataclasses.replace(TPU.calib, reduce_setup=0.0,
                                         reduce_bytes_per_s=1e18)
        fast_topo = tpu_v5e_pod(16, calib=fast_calib)
        assert variant_latency(fast_topo, "reduce_scatter", size, "ring_rs") < rs

    def test_sim_executes_every_scheduled_reduction(self):
        """The event loop executes exactly the reductions the schedule
        carries (SimResult.reduce_chunks == commands.reduce_work)."""
        for v in ("ring_rs", "bidir_ring_rs", "pipe_ring_rs",
                  "pipe_bidir_ring_rs"):
            sched = reduce_scatter_schedule(TPU, 8 * MB, v)
            res = simulate(sched, TPU)
            want = {d: n for d, (n, _) in reduce_work(sched).items()}
            assert res.reduce_chunks == want, v

    def test_pipe_rs_beats_final_chunk_signaling_monotone(self):
        """THE §10 acceptance claim: per-chunk reduction beats
        final-chunk-only signaling of the same pipe_bidir_ring_rs schedule
        at >= 2 chunks, monotone to the depth-4 sweep ceiling and still
        > 1 one doubling past it."""
        for size in (512 * KB, 1 * MB):
            f = {d: rs_pipe_vs_final_chunk_ratio(TPU, size, d)
                 for d in (1, 2, 4, 8)}
            assert f[1] == pytest.approx(1.0, abs=1e-9), size   # structural
            assert f[2] > 1.05, (size, f)                       # wins at 2 chunks
            assert f[4] > f[2], (size, f)                       # monotone to ceiling
            assert f[8] > 1.0, (size, f)                        # saturates, not flips

    def test_pipe_rs_beats_fco_midband_both_variants(self):
        for v in ("pipe_ring_rs", "pipe_bidir_ring_rs"):
            for size in (2 * MB, 4 * MB, 8 * MB, 32 * MB):
                assert rs_pipe_vs_final_chunk_ratio(TPU, size, 2, v) > 1.0, (v, size)

    def test_reduce_claim_bands(self):
        bad = [c for c in reduce_stream_claims() if not c.ok]
        assert not bad, [
            f"{c.name}: {c.model_value} not in [{c.lo},{c.hi}]" for c in bad]

    @pytest.mark.parametrize("variant", [
        "ring_rs", "bidir_ring_rs", "pipe_ring_rs", "pipe_bidir_ring_rs",
        "opt_ring_rs", "opt_pipe_bidir_ring_rs",
        "prelaunch_pipe_ring_rs", "opt_prelaunch_pipe_bidir_ring_rs"])
    @pytest.mark.parametrize("topo", [MI, TPU], ids=["mi300x", "tpu16"])
    def test_rs_symmetric_fast_path_bit_identical(self, topo, variant):
        """Fast-path bit-identity with the reduce term present: the
        one-device run must replicate the full simulation exactly on both
        modeled platforms."""
        sched = reduce_scatter_schedule(topo, 8 * MB, variant)
        assert sched.symmetric
        full = simulate(sched, topo, symmetric=False)
        fast = simulate(sched, topo, symmetric=True)
        assert fast.latency == full.latency
        assert fast.per_device == full.per_device
        assert fast.reduce_chunks == full.reduce_chunks

    @pytest.mark.parametrize("variant", [
        "ring_rs", "bidir_ring_rs", "pipe_ring_rs", "pipe_bidir_ring_rs"])
    @pytest.mark.parametrize("topo", [MI, TPU], ids=["mi300x", "tpu16"])
    def test_ar_symmetric_fast_path_bit_identical(self, topo, variant):
        sched = allreduce_schedule(topo, 8 * MB, variant)
        assert sched.symmetric
        full = simulate(sched, topo, symmetric=False)
        fast = simulate(sched, topo, symmetric=True)
        assert fast.latency == full.latency
        assert fast.per_device == full.per_device

    @pytest.mark.parametrize("variant", ["pipe_ring_rs", "pipe_bidir_ring_rs"])
    def test_rs_closed_form_chunk_run_matches_loop(self, variant):
        """The §9.2 closed-form chunk run stays bit-identical with the
        §10 reduce term downstream: the producer's run commits closed-form
        and each chunk's semaphore wakes its parked reduction exactly as
        the per-chunk loop would — on MI300X and the TPU torus."""
        from repro.core.dma import sim as sim_mod

        for topo in (MI, TPU):
            sched = reduce_scatter_schedule(topo, 32 * MB, variant,
                                            pipe_depth=8)
            fast = simulate(sched, topo)
            orig = sim_mod._Sim._chunk_run
            sim_mod._Sim._chunk_run = lambda *a, **k: False
            try:
                slow = simulate(sched, topo)
            finally:
                sim_mod._Sim._chunk_run = orig
            assert fast.latency == pytest.approx(slow.latency, rel=1e-12)
            for d in fast.per_device:
                for ph in ("control", "schedule", "copy", "sync"):
                    assert getattr(fast.per_device[d], ph) == pytest.approx(
                        getattr(slow.per_device[d], ph), rel=1e-12, abs=1e-15)

    @pytest.mark.parametrize("n", [9, 15])
    def test_odd_grid_rs_runs_full_loop(self, n):
        """Odd-row tori: the snake ring's wraparound is multi-hop, so the
        reduce schedules are not symmetric and must run (and resolve all
        chunk-granularity reduce waits in) the full event loop."""
        topo = tpu_v5e_pod(n)
        for v in ("ring_rs", "pipe_bidir_ring_rs"):
            sched = reduce_scatter_schedule(topo, 4 * MB, v)
            assert not sched.symmetric
            res = simulate(sched, topo)
            assert 0 < res.latency < 1.0
        ar = allreduce_schedule(topo, 4 * MB, "pipe_ring_rs")
        assert not ar.symmetric
        assert 0 < simulate(ar, topo).latency < 1.0

    def test_rs_queues_never_slot_split(self):
        """§7.2 x §10: a reduce stream never slot-splits across the chunk
        boundary — opt_ reduce schedules keep every queue on slot 0."""
        for v in ("opt_ring_rs", "opt_pipe_ring_rs", "opt_pipe_bidir_ring_rs"):
            sched = reduce_scatter_schedule(TPU, 8 * MB, v)
            assert {q.slot for q in sched.queues} == {0}, v

    def test_reduce_dispatch_needs_opt_in(self):
        """reduce_scatter/all_reduce sweeps require allow_reduce=True."""
        with pytest.raises(ValueError, match="allow_reduce"):
            candidate_variants(TPU, "reduce_scatter")
        with pytest.raises(ValueError, match="allow_reduce"):
            derive_dispatch(TPU, "all_reduce", [4 * MB])

    def test_reduce_dispatch_carries_pipe_winner(self):
        vs = reduce_variants(TPU)
        assert "pipe_bidir_ring_rs" in vs
        assert "opt_prelaunch_pipe_ring_rs" in vs
        entries = derive_dispatch(TPU, "reduce_scatter",
                                  [2 ** i for i in range(10, 31)],
                                  allow_pipelined=True, allow_reduce=True)
        assert all(e.variant.endswith("_rs") for e in entries)
        assert any("pipe_" in e.variant for e in entries)

    def test_ar_deadlock_free_without_prelaunch_gate(self):
        """The armed gather phase parks on the reduce phase's result tags;
        a deadlock here would mean the terminal reductions never raised
        them.  Exercise the non-symmetric full loop too."""
        res = simulate(allreduce_schedule(TPU, 1 * MB, "pipe_bidir_ring_rs"),
                       TPU, symmetric=False)
        assert 0 < res.latency < 1.0


class TestHostTimelineIndependence:
    """Pins the ROADMAP 'multi-device host contention' assumption AS IS:
    today every device owns a private host-CPU timeline (``host:<dev>``), so
    control phases of different devices fully overlap.  A single-process
    multi-GPU launcher would in reality serialize them on one host CPU —
    when that shared-host model lands, these are the assertions that must
    flip (the smoke test makes the change observable, not accidental)."""

    def _queues(self, n_dev: int):
        return tuple(
            EngineQueue(d, 0, tuple(cmd.copy(d, (d + 1) % n_dev, 64 * KB)
                                    for _ in range(16)) + (cmd.signal(),))
            for d in range(n_dev))

    def test_control_phases_overlap_across_devices(self):
        res = simulate(Schedule("hosts", self._queues(4)), MI)
        # Each device's host timeline starts at t=0: no cross-device queuing.
        for d in range(4):
            assert res.timelines[f"host:{d}"][0][0] == 0.0
        # All four devices see the same per-device control time (not 4x).
        ctrl = {res.per_device[d].control for d in range(4)}
        assert len(ctrl) == 1

    def test_multi_device_latency_equals_single_device(self):
        """With disjoint links, adding devices leaves per-device timing
        untouched — host CPUs are modeled per-device, not shared."""
        multi = simulate(Schedule("hosts", self._queues(4)), MI)
        solo = simulate(Schedule("solo", self._queues(4)[:1]), MI)
        assert multi.per_device[0] == solo.per_device[0]
        assert multi.latency == pytest.approx(solo.latency, rel=1e-12)

    def test_host_events_accumulate_per_device(self):
        res = simulate(Schedule("hosts", self._queues(4)), MI)
        assert len({res.host_events[d] for d in range(4)}) == 1


class TestDerivedDispatch:
    SIZES = [2 ** i for i in range(10, 33)]

    def test_mi300x_ag_matches_paper_tables(self):
        """Table 2 structure: b2b smallest, bcst mid, pcpy large (prelaunch'd)."""
        entries = derive_dispatch(MI, "all_gather", self.SIZES)
        variants = [e.variant.replace("prelaunch_", "") for e in entries]
        assert variants == ["b2b", "bcst", "pcpy"]
        assert all(e.variant.startswith("prelaunch_") for e in entries[:-1])

    def test_mi300x_aa_matches_paper_tables(self):
        """Table 3 structure: b2b smallest, swap mid, pcpy large."""
        entries = derive_dispatch(MI, "all_to_all", self.SIZES)
        variants = [e.variant.replace("prelaunch_", "") for e in entries]
        assert variants == ["b2b", "swap", "pcpy"]

    def test_tpu_table_prefers_rings_at_bandwidth(self):
        """On the torus the neighbor-only rings win once wire dominates."""
        entries = derive_dispatch(tpu_v5e_pod(16), "all_gather",
                                  [2 ** i for i in range(10, 31)])
        assert entries[0].variant.endswith("b2b")
        assert "ring" in entries[-1].variant
