"""Event-simulator core tests: contended resources, torus routing,
cross-device waits, the symmetric fast path, and dispatch derivation."""
import pytest

from repro.core.dma import (
    allgather_schedule, alltoall_schedule, commands as cmd, derive_dispatch,
    mi300x_platform, simulate, tpu_v5e_pod, variant_latency,
)
from repro.core.dma.commands import EngineQueue, Schedule

KB, MB = 1024, 1024 * 1024
MI = mi300x_platform()
TPU = tpu_v5e_pod(16)


def _single(topo, queues):
    return simulate(Schedule("t", tuple(queues)), topo)


class TestLinkContention:
    def test_two_copies_one_link_serialize(self):
        """Two engines pushing the same directed link take ~2x the wire time."""
        size = 64 * MB
        one = _single(MI, [EngineQueue(0, 0, (cmd.copy(0, 1, size), cmd.signal()))])
        two = _single(MI, [
            EngineQueue(0, 0, (cmd.copy(0, 1, size), cmd.signal())),
            EngineQueue(0, 1, (cmd.copy(0, 1, size), cmd.signal())),
        ])
        wire = size / (MI.link_bw * MI.calib.dma_link_efficiency)
        assert two.latency - one.latency == pytest.approx(wire, rel=0.05)

    def test_distinct_links_overlap(self):
        """Same two copies on distinct links run concurrently."""
        size = 64 * MB
        two_links = _single(MI, [
            EngineQueue(0, 0, (cmd.copy(0, 1, size), cmd.signal())),
            EngineQueue(0, 1, (cmd.copy(0, 2, size), cmd.signal())),
        ])
        same_link = _single(MI, [
            EngineQueue(0, 0, (cmd.copy(0, 1, size), cmd.signal())),
            EngineQueue(0, 1, (cmd.copy(0, 1, size), cmd.signal())),
        ])
        assert two_links.latency < same_link.latency * 0.75

    def test_host_link_shared_across_engines(self):
        """All engines of a device contend for the one PCIe link."""
        size = 16 * MB
        fan1 = _single(MI, [EngineQueue(0, 0, (cmd.copy("host", 0, 4 * size), cmd.signal()))])
        fan4 = _single(MI, [
            EngineQueue(0, e, (cmd.copy("host", 0, size), cmd.signal()))
            for e in range(4)
        ])
        # fan-out cannot beat the shared wire: same bytes over the same link
        wire = 4 * size / (MI.host_link_bw * MI.calib.dma_link_efficiency)
        assert fan4.busy["hostlink:0:h2d"] == pytest.approx(wire, rel=1e-9)
        assert fan4.latency >= wire
        assert fan4.latency >= fan1.latency * 0.9


class TestTorusRouting:
    def test_route_lengths(self):
        assert TPU.grid == (4, 4)
        assert len(TPU.route(0, 1)) == 1
        assert len(TPU.route(0, 2)) == 2
        assert len(TPU.route(0, 10)) == 4          # 2 row + 2 col hops
        assert len(TPU.route(0, 3)) == 1           # wraparound
        assert len(TPU.route(0, 12)) == 1          # column wraparound

    def test_two_hop_step_strictly_slower(self):
        """Acceptance: a 2-hop all-gather step is strictly slower than 1-hop."""
        size = 1 * MB
        one = _single(TPU, [EngineQueue(0, 0, (cmd.copy(0, 1, size), cmd.signal()))])
        two = _single(TPU, [EngineQueue(0, 0, (cmd.copy(0, 2, size), cmd.signal()))])
        assert two.latency > one.latency

    def test_multihop_occupies_every_link(self):
        size = 1 * MB
        r = _single(TPU, [EngineQueue(0, 0, (cmd.copy(0, 2, size), cmd.signal()))])
        assert r.busy.get("link:0>1", 0.0) > 0.0
        assert r.busy.get("link:1>2", 0.0) > 0.0

    def test_ring_order_is_neighbor_adjacent(self):
        order = TPU.ring_order()
        n = len(order)
        assert sorted(order) == list(range(n))
        for i in range(n):
            assert TPU.is_neighbor(order[i], order[(i + 1) % n]), (order[i], order[(i + 1) % n])

    def test_mi300x_all_direct(self):
        for dst in range(1, MI.n_devices):
            assert MI.route(0, dst) == ((0, dst),)


class TestWaits:
    def test_ring_times_from_signal_arrival(self):
        """n-1 chained ring steps cost at least n-1 serialized (wire+sync)."""
        size = 16 * MB
        n = TPU.n_devices
        shard = size // n
        wire = shard / (TPU.link_bw * TPU.calib.dma_link_efficiency)
        lat = variant_latency(TPU, "all_gather", size, "ring")
        assert lat >= (n - 1) * (wire + TPU.calib.sync_engine)

    def test_bidir_ring_faster_than_ring(self):
        """Half the chained steps -> strictly faster at every size."""
        for size in (64 * KB, 4 * MB, 256 * MB):
            assert variant_latency(TPU, "all_gather", size, "bidir_ring") < \
                variant_latency(TPU, "all_gather", size, "ring")

    def test_missing_signal_deadlocks(self):
        q = EngineQueue(0, 0, (cmd.wait(("nope", 1, 0)), cmd.copy(0, 1, KB), cmd.signal()))
        with pytest.raises(RuntimeError, match="deadlock"):
            simulate(Schedule("t", (q,)), MI)


class TestSymmetricFastPath:
    @pytest.mark.parametrize("coll,variant", [
        ("all_gather", "pcpy"), ("all_gather", "bcst"), ("all_gather", "b2b"),
        ("all_gather", "prelaunch_pcpy"), ("all_to_all", "pcpy"),
    ])
    def test_bit_identical_on_mi300x(self, coll, variant):
        builder = allgather_schedule if coll == "all_gather" else alltoall_schedule
        sched = builder(MI, 4 * MB, variant)
        assert sched.symmetric
        full = simulate(sched, MI, symmetric=False)
        fast = simulate(sched, MI, symmetric=True)
        assert fast.latency == full.latency
        assert fast.per_device == full.per_device
        assert fast.engines_used == full.engines_used
        assert fast.hbm_bytes == full.hbm_bytes

    @pytest.mark.parametrize("coll,variant", [
        ("all_gather", "ring"), ("all_gather", "bidir_ring"),
        ("all_gather", "prelaunch_ring"), ("all_to_all", "ring"),
    ])
    def test_bit_identical_on_torus_rings(self, coll, variant):
        builder = allgather_schedule if coll == "all_gather" else alltoall_schedule
        sched = builder(TPU, 4 * MB, variant)
        assert sched.symmetric
        full = simulate(sched, TPU, symmetric=False)
        fast = simulate(sched, TPU, symmetric=True)
        assert fast.latency == full.latency
        assert fast.per_device == full.per_device

    def test_swap_not_marked_symmetric(self):
        """Executor alternation gives devices different command counts."""
        assert not alltoall_schedule(MI, 4 * MB, "swap").symmetric

    def test_multihop_direct_not_marked_symmetric(self):
        """Transit traffic shares links across devices on the torus."""
        assert not allgather_schedule(TPU, 4 * MB, "pcpy").symmetric

    @pytest.mark.parametrize("n", [9, 15])
    def test_odd_grid_ring_not_marked_symmetric(self, n):
        """On odd-by-odd grids the snake ring's wraparound is multi-hop, so
        devices are NOT symmetric; the builder must force the full sim."""
        topo = tpu_v5e_pod(n)
        sched = allgather_schedule(topo, 1 * MB, "ring")
        assert not sched.symmetric
        # sanity: the full sim really differs from a (wrong) symmetric run
        full = simulate(sched, topo, symmetric=False)
        forced = simulate(sched, topo, symmetric=True)
        assert forced.latency < full.latency


class TestUtilization:
    def test_busy_and_timelines_exposed(self):
        r = simulate(allgather_schedule(MI, 64 * MB, "pcpy"), MI)
        assert any(k.startswith("link:") for k in r.busy)
        assert any(k.startswith("engine:") for k in r.busy)
        assert any(k.startswith("host:") for k in r.busy)
        for k, iv in r.timelines.items():
            for s, e in iv:
                assert e >= s >= 0.0
        assert 0.0 < r.utilization(next(k for k in r.busy if k.startswith("link:"))) <= 1.0

    def test_link_busy_tracks_wire_time(self):
        size = 256 * MB
        r = simulate(allgather_schedule(MI, size, "pcpy"), MI)
        shard = size // MI.n_devices
        wire = shard / (MI.link_bw * MI.calib.dma_link_efficiency)
        dev = r.representative if r.representative is not None else 0
        assert r.link_busy_seconds(dev) == pytest.approx(7 * wire, rel=1e-6)


class TestDerivedDispatch:
    SIZES = [2 ** i for i in range(10, 33)]

    def test_mi300x_ag_matches_paper_tables(self):
        """Table 2 structure: b2b smallest, bcst mid, pcpy large (prelaunch'd)."""
        entries = derive_dispatch(MI, "all_gather", self.SIZES)
        variants = [e.variant.replace("prelaunch_", "") for e in entries]
        assert variants == ["b2b", "bcst", "pcpy"]
        assert all(e.variant.startswith("prelaunch_") for e in entries[:-1])

    def test_mi300x_aa_matches_paper_tables(self):
        """Table 3 structure: b2b smallest, swap mid, pcpy large."""
        entries = derive_dispatch(MI, "all_to_all", self.SIZES)
        variants = [e.variant.replace("prelaunch_", "") for e in entries]
        assert variants == ["b2b", "swap", "pcpy"]

    def test_tpu_table_prefers_rings_at_bandwidth(self):
        """On the torus the neighbor-only rings win once wire dominates."""
        entries = derive_dispatch(tpu_v5e_pod(16), "all_gather",
                                  [2 ** i for i in range(10, 31)])
        assert entries[0].variant.endswith("b2b")
        assert "ring" in entries[-1].variant
