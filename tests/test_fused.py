"""Fused compute-collective overlap invariants (DESIGN.md §15).

Byte/FLOP conservation: the fused GEMM+reduce-scatter and all-gather+GEMM
schedules move exactly the bytes and compute exactly the FLOPs of their
sequential control arms, whatever the overlap depth or reduce placement —
fusing changes *when* work runs, never how much.  Fused-never-slower is
checked across the swept size grid on BOTH modeled fabrics (the §15
acceptance claim), and the reduce-placement crossover (CU wins small,
engine wins large) is pinned on MI300X where the band is wide.

CI runs this file un-skipped (a guard step fails if collection comes back
empty); the hypothesis-sampled conservation cases skip locally when
hypothesis is unavailable, the pinned-grid cases always run.
"""
import dataclasses

import pytest

from repro.core.dma import (link_traffic, mi300x_platform, reduce_work,
                            simulate, tpu_v5e_pod, variant_latency)
from repro.core.dma.collectives import (FUSED_AG_VARIANTS, FUSED_RS_VARIANTS,
                                        GEMM_FLOPS_PER_BYTE,
                                        fused_ag_gemm_schedule,
                                        fused_gemm_rs_schedule)
from repro.core.dma.commands import CmdKind
from repro.core.dma.dispatch import (candidate_variants, derive_dispatch,
                                     pick_variant)

KB, MB = 1024, 1024 * 1024
TOPO = mi300x_platform()
TPU = tpu_v5e_pod(16)

#: The §15 acceptance grid: every swept size, latency- through
#: bandwidth-bound (2^10 .. 2^30).
GRID = [1 << p for p in range(10, 31, 2)]

_BUILDERS = {"fused_gemm_rs": fused_gemm_rs_schedule,
             "fused_ag_gemm": fused_ag_gemm_schedule}


def _flops(schedule) -> int:
    return sum(c.size for q in schedule.queues for c in q.commands
               if c.kind is CmdKind.COMPUTE)


# ---------------------------------------------------------------------------
# Conservation: pinned grid (always runs)

@pytest.mark.parametrize("topo", [TOPO, TPU], ids=["mi300x", "tpu16"])
@pytest.mark.parametrize("collective,variant", [
    ("fused_gemm_rs", "fused_cu_d2"),
    ("fused_gemm_rs", "fused_engine_d8"),
    ("fused_ag_gemm", "fused_d4"),
])
def test_fused_conserves_bytes_and_flops(topo, collective, variant):
    """Same wire bytes, same reduction work, same GEMM FLOPs as the seq
    control arm — overlap re-times the work, it never re-sizes it."""
    build = _BUILDERS[collective]
    for size in (64 * KB, 16 * MB):
        seq = build(topo, size, "seq")
        fused = build(topo, size, variant)
        assert link_traffic(fused) == link_traffic(seq)
        # Chunk *counts* track the overlap depth's granularity; reduced
        # *bytes* are grain-invariant.
        assert {d: b for d, (_, b) in reduce_work(fused).items()} == \
            {d: b for d, (_, b) in reduce_work(seq).items()}
        assert _flops(fused) == _flops(seq)
        # And the absolute FLOP count: every device computes its full
        # n-shard GEMM at GEMM_FLOPS_PER_BYTE arithmetic intensity.
        n = topo.n_devices
        shard = max(1, size // n)
        assert _flops(fused) == GEMM_FLOPS_PER_BYTE * n * n * shard


@pytest.mark.parametrize("topo", [TOPO, TPU], ids=["mi300x", "tpu16"])
def test_fused_rs_reduction_work_per_device(topo):
    """Every device reduces exactly (n-1) shards, any placement/depth."""
    n = topo.n_devices
    size = 4 * MB
    shard = size // n
    for variant in ("seq", "fused_cu_d4", "fused_engine_d2"):
        work = reduce_work(fused_gemm_rs_schedule(topo, size, variant))
        assert set(work) == set(range(n))
        for _, total in work.values():
            assert total == (n - 1) * shard


# ---------------------------------------------------------------------------
# Fused never slower than sequential (acceptance: every swept size, both
# fabrics).  variant_latency is memoized, so the grid is cheap.

@pytest.mark.parametrize("topo", [TOPO, TPU], ids=["mi300x", "tpu16"])
@pytest.mark.parametrize("variant", ["fused_cu_d2", "fused_cu_d4",
                                     "fused_engine_d2", "fused_engine_d4"])
def test_fused_rs_never_slower_than_seq(topo, variant):
    for size in GRID:
        seq = variant_latency(topo, "fused_gemm_rs", size, "seq")
        fused = variant_latency(topo, "fused_gemm_rs", size, variant)
        assert fused < seq, (size, variant, fused, seq)


@pytest.mark.parametrize("topo", [TOPO, TPU], ids=["mi300x", "tpu16"])
@pytest.mark.parametrize("variant", ["fused_d2", "fused_d4"])
def test_fused_ag_never_slower_than_seq(topo, variant):
    for size in GRID:
        seq = variant_latency(topo, "fused_ag_gemm", size, "seq")
        fused = variant_latency(topo, "fused_ag_gemm", size, variant)
        assert fused < seq, (size, variant, fused, seq)


# ---------------------------------------------------------------------------
# Reduce placement crossover (DESIGN.md §15): pinned on MI300X, where the
# CU band is wide (tpu16's is a single grid point).

def test_reduce_placement_crossover_mi300x():
    cu_small = variant_latency(TOPO, "fused_gemm_rs", 16 * KB, "fused_cu_d4")
    eng_small = variant_latency(TOPO, "fused_gemm_rs", 16 * KB,
                                "fused_engine_d4")
    assert cu_small < eng_small
    cu_large = variant_latency(TOPO, "fused_gemm_rs", 256 * MB, "fused_cu_d4")
    eng_large = variant_latency(TOPO, "fused_gemm_rs", 256 * MB,
                                "fused_engine_d4")
    assert eng_large < cu_large


def test_dispatch_renders_placement_bands_mi300x():
    """The allow_fused sweep itself exposes the crossover as a size band."""
    sizes = [1 << p for p in range(10, 31)]
    entries = derive_dispatch(TOPO, "fused_gemm_rs", sizes, allow_fused=True,
                              allow_prelaunch=False)
    winners = {s: pick_variant(entries, s) for s in sizes}
    cu = [s for s, v in winners.items() if "_cu_" in v]
    eng = [s for s, v in winners.items() if "_engine_" in v]
    assert cu and eng
    assert max(cu) < min(eng)


# ---------------------------------------------------------------------------
# Simulator integrity: symmetric fast path bit-identity, empty-compute
# schedules never touch the CU timeline, variant/gate validation.

@pytest.mark.parametrize("collective,variant", [
    ("fused_gemm_rs", "fused_cu_d4"),
    ("fused_gemm_rs", "opt_fused_engine_d2"),
    ("fused_ag_gemm", "fused_d4"),
])
def test_fused_symmetric_matches_full(collective, variant):
    for topo in (TOPO, TPU):
        sched = _BUILDERS[collective](topo, 1 * MB, variant)
        assert sched.symmetric
        fast = simulate(sched, topo)
        full = simulate(dataclasses.replace(sched, symmetric=False), topo)
        assert fast.latency == full.latency


def test_unfused_schedule_has_no_cu_spans():
    """Empty-compute path: a plain collective never creates CU activity —
    the resource class is compiled in but entirely inert (the bundled-table
    regen check in CI pins the latencies themselves)."""
    from repro.core.dma import allgather_schedule
    from repro.core.dma.trace import chrome_trace
    res = simulate(allgather_schedule(TOPO, 1 * MB, "pipe_bidir_ring"), TOPO,
                   record_trace=True)
    names = {e.get("args", {}).get("track", "") for e in
             chrome_trace(res)["traceEvents"] if e.get("ph") == "X"}
    assert not any(t.startswith("cu") for t in names)


def test_fused_trace_renders_cu_spans():
    res = simulate(fused_gemm_rs_schedule(TOPO, 1 * MB, "fused_cu_d4"), TOPO,
                   record_trace=True)
    from repro.core.dma.trace import chrome_trace
    text = str(chrome_trace(res))
    assert "cu" in text and "compute" in text


def test_fused_variant_validation():
    with pytest.raises(ValueError, match="unknown fused"):
        fused_gemm_rs_schedule(TOPO, 1 * MB, "fused_cu_d3")
    with pytest.raises(ValueError, match="unknown fused"):
        fused_ag_gemm_schedule(TOPO, 1 * MB, "fused_engine_d4")
    with pytest.raises(ValueError, match="allow_fused"):
        candidate_variants(TOPO, "fused_gemm_rs")
    assert set(candidate_variants(TOPO, "fused_gemm_rs", allow_fused=True,
                                  allow_prelaunch=False)) == \
        set(FUSED_RS_VARIANTS)
    assert set(candidate_variants(TOPO, "fused_ag_gemm", allow_fused=True,
                                  allow_prelaunch=False)) == \
        set(FUSED_AG_VARIANTS)


# ---------------------------------------------------------------------------
# Hypothesis-sampled conservation across depth x placement x granularity
# (skips locally without hypothesis; CI installs it).

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(size=st.integers(min_value=1024, max_value=1 << 28),
           variant=st.sampled_from([v for v in FUSED_RS_VARIANTS
                                    if v != "seq"]))
    def test_fused_rs_traffic_invariant_under_variant(size, variant):
        seq = fused_gemm_rs_schedule(TOPO, size, "seq")
        fused = fused_gemm_rs_schedule(TOPO, size, variant)
        assert link_traffic(fused) == link_traffic(seq)
        assert _flops(fused) == _flops(seq)

    @settings(max_examples=20, deadline=None)
    @given(size=st.integers(min_value=1024, max_value=1 << 28),
           variant=st.sampled_from([v for v in FUSED_AG_VARIANTS
                                    if v != "seq"]))
    def test_fused_ag_traffic_invariant_under_variant(size, variant):
        seq = fused_ag_gemm_schedule(TPU, size, "seq")
        fused = fused_ag_gemm_schedule(TPU, size, variant)
        assert link_traffic(fused) == link_traffic(seq)
        assert _flops(fused) == _flops(seq)
