"""Hierarchical shard_map MoE dispatch vs a no-drop dense oracle."""
import pytest

LATTE_MOE_TEST = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.configs import get_config
from repro.core.latte_moe import make_latte_moe
from repro.models import moe as moe_mod

N = 8
mesh = make_mesh((N,), ("x",))

cfg = get_config("mixtral-8x7b").reduced()       # 4 experts top-2 reduced
cfg = dataclasses.replace(
    cfg, d_model=64,
    moe=dataclasses.replace(cfg.moe, n_experts=8, top_k=2, d_ff_expert=32,
                            capacity_factor=64.0))   # no drops
rng = jax.random.PRNGKey(0)
p = moe_mod.init_moe(cfg, rng)
B, S, D = 8, 16, cfg.d_model
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)

# dense no-drop oracle: per-token weighted mix of expert FFNs
def oracle(p, x):
    T = B * S
    xf = x.reshape(T, D)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    tp, te = jax.lax.top_k(probs, cfg.moe.top_k)
    tp = tp / tp.sum(-1, keepdims=True)
    h = jnp.einsum("td,edf->tef", xf, p["wg"])
    u = jnp.einsum("td,edf->tef", xf, p["wu"])
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["wd"])   # [T,E,D]
    w = jnp.zeros((T, cfg.moe.n_experts)).at[jnp.arange(T)[:, None], te].add(tp)
    return jnp.einsum("te,ted->td", w, y_all).reshape(B, S, D)

ref = oracle(p, x)
fn = make_latte_moe(cfg, mesh, "x")
out, aux = jax.jit(fn)(p, x)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-4, err
assert np.isfinite(float(aux))

# verify the collective actually present: pairwise all-to-all appears in HLO
txt = jax.jit(fn).lower(p, x).compile().as_text()
assert "collective-permute" in txt or "all-to-all" in txt
print("LATTE_MOE_OK err=", err)
"""


@pytest.mark.slow
def test_latte_moe_matches_dense_oracle(subproc):
    out = subproc(LATTE_MOE_TEST, n_devices=8, timeout=600)
    assert "LATTE_MOE_OK" in out
