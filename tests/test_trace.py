"""Chrome-trace recording and export tests (DESIGN.md §14).

The §14 acceptance invariants live here:

* **Recording is free** — ``record_trace=True`` forces the full event loop
  (symmetric §6 and closed-form chunk §8.3/§9.2 fast paths decline) but
  latency and every per-device phase stay *bit-identical* to the
  unrecorded run, across baseline/``opt_``/``pipe_``/hierarchical/fault
  runs; ``record_trace=False`` attaches no trace.
* **Valid trace-event JSON** — every rendered event carries
  ``ph``/``ts``/``pid``/``tid``, ``ts >= 0``, ``dur >= 0``.
* **Byte conservation** — data-span byte totals reproduce the schedule's
  ``link_traffic`` invariant exactly.
* **Flow semantics** — every flow arrow runs strictly forward in time
  (acyclic) and lands on a recorded wait slice or wait instant.
* **Zero-duration policy** — zero-cost grants are synthesized as instant
  events, never dropped; span+instant counts reconcile with the
  ``host_events``/``engine_atomics`` counters (property-tested).
* **Golden trace** — the 2-device ring all-gather render is pinned
  byte-for-byte in ``tests/golden/trace_ag_ring2.json``.
"""
import json
import os

import pytest

from repro.core.dma import (FaultPlan, Straggler, allgather_schedule,
                            alltoall_schedule, chrome_trace, link_traffic,
                            mi300x_platform, reduce_scatter_schedule,
                            run_composed, simulate, tag_name, tpu_v5e_pod,
                            write_chrome_trace)
from repro.core.dma.topology import mi300x_cluster

KB, MB = 1024, 1024 * 1024
MI = mi300x_platform()
TPU = tpu_v5e_pod(16)
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "trace_ag_ring2.json")

#: (builder, topo, size, variant) grid covering every stream family the
#: bit-identity contract names: baseline, optimized, pipelined, chunked,
#: reduce, hierarchical.
GRID = [
    (allgather_schedule, MI, 1 * MB, "pcpy"),
    (allgather_schedule, MI, 4 * MB, "opt_bcst"),
    (allgather_schedule, MI, 4 * MB, "pipe_bidir_ring"),
    (alltoall_schedule, MI, 2 * MB, "opt_pcpy"),
    (alltoall_schedule, TPU, 1 * MB, "ring"),
    (reduce_scatter_schedule, TPU, 2 * MB, "pipe_ring_rs"),
    (allgather_schedule, mi300x_cluster(2), 4 * MB, "hier_pipe"),
]

def _fault_plan(sched) -> FaultPlan:
    names = {tag_name(t) for q in sched.queues for c in q.commands
             for t in (c.tag, c.fused_tag) if t is not None}
    return FaultPlan(drop_tags=(sorted(names)[0],),
                     stragglers=(Straggler(device=0, engine=None,
                                           slowdown=1.5),))


def _recorded(builder, topo, size, variant, faults=None):
    sched = builder(topo, size, variant)
    plain = simulate(sched, topo, faults=faults)
    rec = simulate(sched, topo, faults=faults, record_trace=True)
    return sched, plain, rec


# ---------------------------------------------------------------- identity --

@pytest.mark.parametrize("builder,topo,size,variant", GRID,
                         ids=[g[3] for g in GRID])
def test_recording_is_latency_bit_identical(builder, topo, size, variant):
    _, plain, rec = _recorded(builder, topo, size, variant)
    assert rec.latency == plain.latency
    assert rec.per_device == plain.per_device
    assert rec.host_events == plain.host_events
    assert rec.engine_atomics == plain.engine_atomics
    assert plain.trace is None and rec.trace is not None


def test_recording_is_bit_identical_under_faults():
    plan = _fault_plan(allgather_schedule(TPU, 4 * MB, "pipe_b2b"))
    _, plain, rec = _recorded(allgather_schedule, TPU, 4 * MB, "pipe_b2b",
                              faults=plan)
    assert rec.latency == plain.latency
    assert rec.timelines == plain.timelines     # both force the full loop
    assert any(s.retry for s in rec.trace.spans)


def test_composed_recording_is_bit_identical():
    sched = allgather_schedule(MI, 1 * MB, "ring")
    plain = run_composed([sched, sched], MI, [0.0, 1e-6])
    rec = run_composed([sched, sched], MI, [0.0, 1e-6], record_trace=True)
    assert rec.makespan == plain.makespan
    assert [o.latency for o in rec.outcomes] == \
        [o.latency for o in plain.outcomes]
    assert rec.result.trace is not None and plain.result.trace is None
    assert {s.schedule for s in rec.result.trace.spans} == {0, 1}


# ------------------------------------------------------------- JSON shape --

def _all_events():
    _, _, rec = _recorded(allgather_schedule, MI, 4 * MB, "pipe_bidir_ring")
    return chrome_trace(rec)["traceEvents"]


def test_chrome_trace_events_are_well_formed():
    events = _all_events()
    assert events, "empty trace"
    for e in events:
        assert {"ph", "ts", "pid", "tid"} <= e.keys()
        assert e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 0
    phases = {e["ph"] for e in events}
    assert "X" in phases and "M" in phases


def test_chrome_trace_rejects_unrecorded_result():
    res = simulate(allgather_schedule(MI, 1 * MB, "ring"), MI)
    with pytest.raises(ValueError, match="record_trace=True"):
        chrome_trace(res)


def test_write_chrome_trace_round_trips(tmp_path):
    _, _, rec = _recorded(allgather_schedule, MI, 1 * MB, "ring")
    path = write_chrome_trace(rec, str(tmp_path / "t.json"))
    with open(path) as f:
        loaded = json.load(f)
    assert loaded == json.loads(json.dumps(chrome_trace(rec)))


# ------------------------------------------------------- byte conservation --

DATA_KIND_NAMES = {"copy", "bcst", "swap"}


def _span_traffic(trace) -> dict[tuple, int]:
    out: dict[tuple, int] = {}
    for s in trace.spans:
        if s.kind not in DATA_KIND_NAMES or s.retry:
            continue
        src, dsts = s.args["src"], s.args["dsts"]
        for dst in dsts:
            out[(src, dst)] = out.get((src, dst), 0) + s.size
        if s.kind == "swap":
            key = (dsts[0], src)
            out[key] = out.get(key, 0) + s.size
    return out


@pytest.mark.parametrize("builder,topo,size,variant", GRID,
                         ids=[g[3] for g in GRID])
def test_data_span_bytes_match_link_traffic(builder, topo, size, variant):
    sched, _, rec = _recorded(builder, topo, size, variant)
    assert _span_traffic(rec.trace) == link_traffic(sched)


# ------------------------------------------------------------------ flows --

def test_flows_are_acyclic_and_land_on_waits():
    _, _, rec = _recorded(allgather_schedule, MI, 4 * MB, "pipe_bidir_ring")
    trace = rec.trace
    assert trace.flows, "pipelined run recorded no flow arrows"
    wait_ends = {(s.resource, s.end) for s in trace.spans
                 if s.kind == "wait"}
    wait_ends.update((i.resource, i.time) for i in trace.instants
                     if i.kind == "wait")
    ids = [f.id for f in trace.flows]
    assert len(ids) == len(set(ids))
    for f in trace.flows:
        assert f.src_time < f.dst_time          # strictly forward: acyclic
        assert (f.dst_resource, f.dst_time) in wait_ends


# ---------------------------------------------- zero-duration reconciliation

RECONCILE_GRID = GRID + [
    (allgather_schedule, TPU, 1 * MB, "ring"),      # zero-cost TPU doorbells
    (allgather_schedule, MI, 8 * MB, "opt_prelaunch_b2b"),
]


@pytest.mark.parametrize("builder,topo,size,variant", RECONCILE_GRID,
                         ids=[f"{g[3]}-{g[1].name}" for g in RECONCILE_GRID])
def test_trace_counts_reconcile_with_counters(builder, topo, size, variant):
    """The §14 zero-duration policy, pinned: every host event and engine
    atomic the simulator counted appears in the trace as a span or a
    synthesized instant — nothing is dropped when a cost is zero."""
    _, plain, rec = _recorded(builder, topo, size, variant)
    trace = rec.trace
    events = [*trace.spans, *trace.instants]

    def count(kind):
        return sum(1 for e in events if e.kind == kind
                   and not getattr(e, "retry", False))

    control_events = sum(e.args["events"] for e in events
                         if e.kind == "control"
                         and not getattr(e, "retry", False))
    full_doorbells = sum(1 for e in events if e.kind == "doorbell"
                         and e.args["full"])
    host_total = control_events + full_doorbells + count("sync")
    assert host_total == sum(plain.host_events.values())
    assert count("signal") == sum(plain.engine_atomics.values())


# ----------------------------------------------------------------- golden --

def test_golden_two_device_ring_allgather():
    topo = tpu_v5e_pod(2)
    sched = allgather_schedule(topo, 64 * KB, "ring")
    rec = simulate(sched, topo, record_trace=True)
    rendered = json.loads(json.dumps(chrome_trace(rec), sort_keys=True))
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert rendered == golden
