"""Dispatch-table cache fingerprinting (backend.py): stale tables must MISS.

The on-disk/bundled dispatch tables are keyed by a fingerprint of the cache
version, the full topology repr (calibration included) and the sweep inputs.
The v6 bump (hierarchical multi-node collectives, DESIGN.md §11)
invalidates every v5-and-older table — those sweeps never offered the
``hier_`` candidates and never saw the NIC calibration, so serving them
silently would pin the backend to single-node policies.  These tests pin
the fingerprint-mismatch path: stale entries are ignored, current entries
round trip, and a calibration change alone — including a reduce-only or
NIC-only recalibration — also misses.
"""
import dataclasses
import hashlib
import json

from repro.core import backend
from repro.core.dma.dispatch import DispatchEntry
from repro.core.dma.topology import Calibration, mi300x_cluster, tpu_v5e_pod


def _key_for_version(topo, sizes, version: int) -> str:
    """The cache key an OLDER backend version would have written."""
    return hashlib.sha1(
        f"v{version}|{topo!r}|{sizes!r}|{backend._SWEEP_CHUNKS!r}"
        .encode()).hexdigest()[:16]


def _isolate(tmp_path, monkeypatch, bundled: dict | None = None):
    """Point the cache dir and the bundled package copy into tmp_path."""
    monkeypatch.setattr(backend, "_TABLE_CACHE_DIR", str(tmp_path / "cache"))
    bundled_path = tmp_path / "bundled.json"
    if bundled is not None:
        bundled_path.write_text(json.dumps(bundled))
    monkeypatch.setattr(backend, "_BUNDLED_TABLES", str(bundled_path))


_POISON = [[{"lo": 1024, "hi": None, "variant": "STALE", "chunk": None}]] * 4


def test_cache_version_is_v7():
    """The optimized/pipelined single-node re-derivation (DESIGN.md §15 /
    ROADMAP latte item) requires the v7 fingerprint."""
    assert backend._TABLE_CACHE_VERSION == 7


def test_stale_versioned_disk_tables_rejected(tmp_path, monkeypatch):
    """v2-v6 disk entries (pre-optimized single-node sweeps) must never be
    served: their file names carry the old fingerprint, so the v7 lookup
    misses."""
    _isolate(tmp_path, monkeypatch)
    topo = tpu_v5e_pod(16)
    sizes = backend._SWEEP_SIZES
    (tmp_path / "cache").mkdir()
    for old in (2, 3, 4, 5, 6):
        stale = _key_for_version(topo, sizes, old)
        assert stale != backend._table_key(topo, sizes)
        path = tmp_path / "cache" / f"tables_{topo.name}_{stale}.json"
        path.write_text(json.dumps(_POISON))
    assert backend._load_table_cache(topo, sizes) is None


def test_stale_versioned_bundled_tables_rejected(tmp_path, monkeypatch):
    """Same for the bundled package copy: old-fingerprint keys miss."""
    topo = tpu_v5e_pod(16)
    sizes = backend._SWEEP_SIZES
    _isolate(tmp_path, monkeypatch, bundled={
        _key_for_version(topo, sizes, v): _POISON for v in (2, 3, 4, 5, 6)})
    assert backend._load_table_cache(topo, sizes) is None


def test_current_fingerprint_round_trips(tmp_path, monkeypatch):
    """The miss above is the fingerprint, not a broken store: tables written
    under the CURRENT key are served back verbatim."""
    _isolate(tmp_path, monkeypatch)
    topo = tpu_v5e_pod(16)
    sizes = backend._SWEEP_SIZES
    tables = ((DispatchEntry(1024, None, "prelaunch_pipe_bidir_ring", None),),
              (DispatchEntry(1024, None, "prelaunch_swap", 1024 * 1024),),
              (DispatchEntry(1024, None, "prelaunch_pipe_bidir_ring_rs", None),),
              (DispatchEntry(1024, None, "prelaunch_bidir_ring_rs", None),))
    backend._store_table_cache(topo, sizes, tables)
    assert backend._load_table_cache(topo, sizes) == tables


def test_calibration_change_alone_misses(tmp_path, monkeypatch):
    """topo!r embeds the Calibration: a recalibration misses without any
    version bump."""
    _isolate(tmp_path, monkeypatch)
    topo = tpu_v5e_pod(16)
    sizes = backend._SWEEP_SIZES
    tables = ((DispatchEntry(1024, None, "ring", None),),
              (DispatchEntry(1024, None, "swap", None),),
              (DispatchEntry(1024, None, "ring_rs", None),),
              (DispatchEntry(1024, None, "ring_rs", None),))
    backend._store_table_cache(topo, sizes, tables)
    recal = tpu_v5e_pod(16, calib=Calibration(control=1e-9))
    assert recal.name == topo.name          # same file-name stem...
    assert backend._load_table_cache(recal, sizes) is None  # ...different key


def test_reduce_calibration_only_change_misses(tmp_path, monkeypatch):
    """A REDUCE-only recalibration (DESIGN.md §10: reduce_setup /
    reduce_bytes_per_s, untouched by any pre-v5 sweep input) must miss on
    its own — the reduce calibration is part of the v5 fingerprint via
    topo!r."""
    _isolate(tmp_path, monkeypatch)
    topo = tpu_v5e_pod(16)
    sizes = backend._SWEEP_SIZES
    tables = ((DispatchEntry(1024, None, "ring", None),),
              (DispatchEntry(1024, None, "swap", None),),
              (DispatchEntry(1024, None, "pipe_ring_rs", None),),
              (DispatchEntry(1024, None, "ring_rs", None),))
    backend._store_table_cache(topo, sizes, tables)
    recal = tpu_v5e_pod(16, calib=dataclasses.replace(
        topo.calib, reduce_bytes_per_s=topo.calib.reduce_bytes_per_s * 2))
    assert recal.name == topo.name
    assert backend._table_key(recal, sizes) != backend._table_key(topo, sizes)
    assert backend._load_table_cache(recal, sizes) is None
    assert backend._load_table_cache(topo, sizes) == tables  # original serves


def test_nic_calibration_only_change_misses(tmp_path, monkeypatch):
    """A NIC-only recalibration (DESIGN.md §11: nic_latency /
    nic_bytes_per_s) must miss on its own — the inter-node tier is part of
    the v6 fingerprint via topo!r, so tables swept under one RDMA fabric
    are never served for another."""
    _isolate(tmp_path, monkeypatch)
    topo = mi300x_cluster(2)
    sizes = backend._SWEEP_SIZES
    tables = ((DispatchEntry(1024, None, "hier_ring", None),),
              (DispatchEntry(1024, None, "hier_ring", None),),
              (DispatchEntry(1024, None, "hier_ring_rs", None),),
              (DispatchEntry(1024, None, "hier_pipe_rs", None),))
    backend._store_table_cache(topo, sizes, tables)
    recal = mi300x_cluster(2, calib=dataclasses.replace(
        topo.calib, nic_latency=topo.calib.nic_latency * 2))
    assert recal.name == topo.name
    assert backend._table_key(recal, sizes) != backend._table_key(topo, sizes)
    assert backend._load_table_cache(recal, sizes) is None
    assert backend._load_table_cache(topo, sizes) == tables  # original serves


def test_bundled_tables_carry_current_fingerprint_and_reduce_winners():
    """The shipped _dispatch_tables.json was regenerated for v6: its key
    matches the current fingerprint, it carries all four tables, the AG
    table contains a pipelined winner and the RS/AR tables carry pipelined
    reduce winners (the sweep really offered the §10 candidates)."""
    with open(backend._BUNDLED_TABLES) as f:
        bundled = json.load(f)
    topo = tpu_v5e_pod(16)
    key = backend._table_key(topo, backend._SWEEP_SIZES)
    assert key in bundled
    ag, aa, rs, ar = backend._parse_tables(bundled[key])
    assert any("pipe_" in e.variant for e in ag)
    assert any("pipe_" in e.variant for e in rs)
    assert any("pipe_" in e.variant for e in ar)
    # every winner must strip to a known JAX implementation
    strip = backend.CommBackend()._strip
    for e in ag:
        assert strip(e.variant) in backend._AG_IMPL, e.variant
    for e in aa:
        assert strip(e.variant) in backend._AA_IMPL, e.variant
    for e in rs:
        assert strip(e.variant) in backend._RS_IMPL, e.variant
    for e in ar:
        assert strip(e.variant) in backend._AR_IMPL, e.variant


def test_bundled_multinode_tables_present_and_hier_winners():
    """Every MULTINODE_TOPOS spec ships a bundled v6 table whose winners are
    all hierarchical streams mapping (stripped) into the JAX impl maps —
    multinode derivation in CI is a cache load, never a re-sweep."""
    with open(backend._BUNDLED_TABLES) as f:
        bundled = json.load(f)
    strip = backend.CommBackend()._strip
    for spec, build in backend.MULTINODE_TOPOS.items():
        topo = build()
        key = backend._table_key(topo, backend._SWEEP_SIZES)
        assert key in bundled, spec
        ag, rs, ar = backend._parse_tables(bundled[key])
        for e in ag:
            assert "hier_" in e.variant, (spec, e.variant)
            assert strip(e.variant) in backend._AG_IMPL, (spec, e.variant)
        for e in rs:
            assert "hier_" in e.variant, (spec, e.variant)
            assert strip(e.variant) in backend._RS_IMPL, (spec, e.variant)
        for e in ar:
            assert "hier_" in e.variant, (spec, e.variant)
            assert strip(e.variant) in backend._AR_IMPL, (spec, e.variant)
