"""Hierarchical multi-node collectives (DESIGN.md §11) invariants.

Three families of checks:

* **Tier-split byte conservation** — ``link_traffic`` split at node
  boundaries: a hierarchical all-gather / reduce-scatter moves exactly
  ``(P - 1) * M * shard`` bytes per device over intra-node links and
  ``(M - 1) * shard`` bytes per device through the sender NICs (M nodes,
  P devices per node), whatever the rendering (ring vs pipelined) or
  chunk granularity.
* **Reduction-work conservation** — hier RS/AR reduce exactly
  ``(N - 1) * shard`` bytes per device (DESIGN.md §10 extended across the
  inter tier).
* **Bit-identity** — the symmetric representative-device fast path
  (§11.3) must agree *exactly* with the full event loop, per variant and
  per sweep candidate, on both multi-node fabrics.  This is the contract
  that lets dispatch derivation simulate one device instead of N.
"""
import pytest

from repro.core.dma import (allgather_schedule, allreduce_schedule,
                            candidate_variants, link_traffic,
                            reduce_scatter_schedule, reduce_work, simulate)
from repro.core.dma.dispatch import sweep_candidate_latencies, variant_latency
from repro.core.dma.sweep import rep_latency, sweep_variant_latencies
from repro.core.dma.topology import mi300x_cluster, tpu_v5e_multislice

CLUSTER = mi300x_cluster(2)          # 2 nodes x 8 GPUs, RDMA NICs
TPU64 = tpu_v5e_multislice(64)       # 4 slices x 16 chips, DCN NICs

MB = 1024 * 1024

_SCHED = {"all_gather": allgather_schedule,
          "reduce_scatter": reduce_scatter_schedule,
          "all_reduce": allreduce_schedule}


def _tier_bytes(topo, sched):
    """(intra-node bytes, cross-node bytes) summed over link_traffic."""
    intra = nic = 0
    for (src, dst), b in link_traffic(sched).items():
        if topo.node_of(src) == topo.node_of(dst):
            intra += b
        else:
            nic += b
    return intra, nic


# ---------------------------------------------------------------- traffic

@pytest.mark.parametrize("topo", [CLUSTER, TPU64], ids=lambda t: t.name)
@pytest.mark.parametrize("collective,variant", [
    ("all_gather", "hier_ring"),
    ("all_gather", "hier_pipe"),
    ("reduce_scatter", "hier_ring_rs"),
    ("reduce_scatter", "hier_pipe_rs"),
])
@pytest.mark.parametrize("size", [64 * 1024, 16 * MB])
def test_hier_tier_split_byte_conservation(topo, collective, variant, size):
    """Intra bytes = N*(P-1)*M*shard, NIC bytes = N*(M-1)*shard in total:
    the two-tier decomposition sends each shard across the node ring once
    and each gathered block around the local ring once — no tier leaks
    traffic into the other."""
    sched = _SCHED[collective](topo, size, variant)
    n, m, p = topo.n_devices, topo.n_nodes, topo.node_devices
    shard = size // n
    intra, nic = _tier_bytes(topo, sched)
    assert intra == n * (p - 1) * m * shard
    assert nic == n * (m - 1) * shard


@pytest.mark.parametrize("variant", ["hier_ring", "hier_pipe"])
def test_hier_traffic_invariant_under_chunking(variant):
    """Chunk granularity re-slices commands but must not move bytes
    between tiers (the §8.1 invariant holds per tier)."""
    size = 8 * MB
    base = _tier_bytes(CLUSTER, allgather_schedule(CLUSTER, size, variant))
    chunked = _tier_bytes(CLUSTER, allgather_schedule(
        CLUSTER, size, variant, max_chunk_bytes=256 * 1024))
    assert base == chunked


# -------------------------------------------------------------- reduction

@pytest.mark.parametrize("topo", [CLUSTER, TPU64], ids=lambda t: t.name)
@pytest.mark.parametrize("collective", ["reduce_scatter", "all_reduce"])
@pytest.mark.parametrize("variant", ["hier_ring_rs", "hier_pipe_rs"])
def test_hier_reduction_work_conserved(topo, collective, variant):
    """Every device reduces exactly (N-1)*shard bytes: (P-1)*M*shard in
    the intra phase plus (M-1)*shard in the inter phase — the two tiers
    partition the flat invariant, they do not duplicate work."""
    size = 4 * MB
    sched = _SCHED[collective](topo, size, variant)
    n = topo.n_devices
    shard = size // n
    work = reduce_work(sched)
    assert set(work) == set(range(n))
    for dev, (_, reduced) in work.items():
        assert reduced == (n - 1) * shard, dev


# ------------------------------------------------------------ bit-identity

@pytest.mark.parametrize("collective,variant", [
    ("all_gather", "hier_ring"),
    ("all_gather", "opt_prelaunch_hier_pipe"),
    ("reduce_scatter", "hier_pipe_rs"),
    ("all_reduce", "opt_prelaunch_hier_ring_rs"),
])
@pytest.mark.parametrize("size", [64 * 1024, 16 * MB])
def test_hier_symmetric_matches_full_event_loop(collective, variant, size):
    """Representative-device simulation == full N-device event loop,
    bit-for-bit, on the 2-node MI300X cluster.  Any translation-variant
    tie-break (e.g. two queues racing one link) breaks this equality."""
    sched = _SCHED[collective](CLUSTER, size, variant)
    assert sched.symmetric
    sym = simulate(sched, CLUSTER).latency
    full = simulate(sched, CLUSTER, symmetric=False).latency
    assert sym == full


@pytest.mark.parametrize("collective,variant", [
    ("all_gather", "hier_pipe"),
    ("all_reduce", "hier_ring_rs"),
])
def test_hier_symmetric_matches_full_event_loop_tpu64(collective, variant):
    """Same equality on the 64-chip multislice (4 DCN-joined tori) — the
    torus intra tier plus 4-way inter ring exercises deeper tag nesting
    than the 2-node cluster."""
    size = 2 * MB
    sched = _SCHED[collective](TPU64, size, variant)
    assert sched.symmetric
    assert (simulate(sched, TPU64).latency
            == simulate(sched, TPU64, symmetric=False).latency)


@pytest.mark.parametrize("topo", [CLUSTER, TPU64], ids=lambda t: t.name)
def test_vectorized_sweep_bit_identical(topo):
    """The dispatch sweep fast path (rep-only builds + argmin grid,
    DESIGN.md §11.3) returns exactly the per-point simulate() latencies
    for every multi-node candidate — winners can never differ between the
    fast and slow paths."""
    sizes = (64 * 1024, 1 * MB, 16 * MB)
    for collective in ("all_gather", "reduce_scatter", "all_reduce"):
        variants = candidate_variants(
            topo, collective, allow_pipelined=True, allow_optimized=True,
            allow_reduce=collective != "all_gather")
        for v in variants:
            fast = sweep_candidate_latencies(topo, collective, sizes, v, None)
            ref = [variant_latency(topo, collective, s, v) for s in sizes]
            assert fast == ref, (collective, v)


def test_rep_latency_refuses_non_symmetric():
    """Flat fan-outs on a multi-node fabric are not translation invariant
    (symmetric=False): the fast path must decline, not guess."""
    assert rep_latency(CLUSTER, "all_gather", 1 * MB, "pcpy") is None
    assert sweep_variant_latencies(
        CLUSTER, "all_gather", (1 * MB, 4 * MB), "pcpy", None) is None


# -------------------------------------------------------------- topology

def test_multinode_topology_structure():
    """Node bookkeeping + routing: cross-node transfers are one NIC hop at
    NIC bandwidth, neighbors never cross nodes, and the ring order is
    node-major so ring collectives stay on intra links."""
    topo = TPU64
    assert topo.n_nodes == 4 and topo.node_devices == 16
    assert topo.node_of(17) == 1 and topo.local_rank(17) == 1
    # cross-node: single nic hop, sender-side resource, NIC bandwidth
    path, bw = topo.wire_path(3, 40)
    assert path == ((f"nic:3", topo.calib.nic_latency),)
    assert bw == topo.calib.nic_bytes_per_s
    # intra-node: directed links at DMA-link bandwidth
    path, bw = topo.wire_path(0, 1)
    assert all(key.startswith("link:") for key, _ in path)
    assert bw == topo.link_bw * topo.calib.dma_link_efficiency
    # neighbors stay inside the node
    for dev in (0, 17, 63):
        node = topo.node_of(dev)
        assert all(topo.node_of(nb) == node for nb in topo.neighbors(dev))
    # node-major ring: consecutive devices share a node except at the
    # n_nodes boundaries
    ring = topo.ring_order()
    crossings = sum(topo.node_of(a) != topo.node_of(b)
                    for a, b in zip(ring, ring[1:] + ring[:1]))
    assert crossings == topo.n_nodes
