"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.dma import (allgather_schedule, alltoall_schedule, kv_fetch_schedule,
                            mi300x_platform, simulate)
from repro.data.pipeline import DataConfig, synth_batch
from repro.models.layers import apply_rotary, rope_angles
from repro.serve.kvcache import blocks_to_kv, kv_to_blocks
from repro.train.checkpoint import restore_checkpoint, save_checkpoint

TOPO = mi300x_platform()

sizes = st.integers(min_value=1024, max_value=1 << 32)
variants_ag = st.sampled_from(["pcpy", "bcst", "b2b", "prelaunch_pcpy",
                               "prelaunch_bcst", "prelaunch_b2b"])
variants_aa = st.sampled_from(["pcpy", "swap", "b2b", "prelaunch_swap"])


@settings(max_examples=40, deadline=None)
@given(size=sizes, v=variants_ag)
def test_allgather_positive_finite_latency(size, v):
    r = simulate(allgather_schedule(TOPO, size, v), TOPO)
    assert 0 < r.latency < 10.0
    for b in r.per_device.values():
        assert b.control >= 0 and b.schedule >= 0 and b.copy >= 0 and b.sync >= 0


@settings(max_examples=40, deadline=None)
@given(size=sizes, v=variants_aa)
def test_alltoall_traffic_conserved(size, v):
    """Every ordered (src, dst) pair is served exactly once, any variant."""
    sched = alltoall_schedule(TOPO, size, v)
    pairs = set()
    for q in sched.queues:
        for c in q.data_commands:
            src = c.src
            for dst in c.dsts:
                if c.kind.value == "swap":
                    assert (src, dst) not in pairs and (dst, src) not in pairs
                    pairs.add((src, dst))
                    pairs.add((dst, src))
                else:
                    assert (src, dst) not in pairs
                    pairs.add((src, dst))
    n = TOPO.n_devices
    assert len(pairs) == n * (n - 1)


@settings(max_examples=30, deadline=None)
@given(size=st.integers(min_value=1024, max_value=1 << 28), v=variants_ag)
def test_prelaunch_never_slower(size, v):
    if v.startswith("prelaunch"):
        return
    base = simulate(allgather_schedule(TOPO, size, v), TOPO).latency
    pre = simulate(allgather_schedule(TOPO, size, f"prelaunch_{v}"), TOPO).latency
    assert pre <= base


@settings(max_examples=25, deadline=None)
@given(n_blocks=st.integers(1, 512), block_bytes=st.integers(256, 1 << 22))
def test_kv_fetch_b2b_fewer_signals_than_pcpy(n_blocks, block_bytes):
    pcpy = kv_fetch_schedule(TOPO, n_blocks, block_bytes, "pcpy")
    b2b = kv_fetch_schedule(TOPO, n_blocks, block_bytes, "b2b")
    sig = lambda s: sum(q.n_signals for q in s.queues)
    assert sig(b2b) <= sig(pcpy)
    assert sig(pcpy) == n_blocks


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), step=st.integers(0, 1000))
def test_data_pipeline_deterministic(seed, step):
    cfg = DataConfig(vocab=1024, seq_len=64, batch=2, seed=seed)
    a = synth_batch(cfg, step)["tokens"]
    b = synth_batch(cfg, step)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(jnp.max(a)) < 1024 and int(jnp.min(a)) >= 0


@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 64), kv=st.sampled_from([1, 2, 4]),
       hd=st.sampled_from([8, 16]), layers=st.integers(1, 3),
       bt=st.sampled_from([4, 16]))
def test_kv_block_roundtrip(s, kv, hd, layers, bt):
    rng = np.random.default_rng(0)
    k = rng.normal(size=(layers, 1, s, kv, hd)).astype(np.float32)
    v = rng.normal(size=(layers, 1, s, kv, hd)).astype(np.float32)
    kb, vb = kv_to_blocks(k, v, bt)
    k2, v2 = blocks_to_kv(kb, vb, s)
    np.testing.assert_array_equal(k, k2)
    np.testing.assert_array_equal(v, v2)


@settings(max_examples=10, deadline=None)
@given(hd=st.sampled_from([16, 32, 64]), s=st.integers(2, 32))
def test_rotary_preserves_norm(hd, s):
    x = jax.random.normal(jax.random.PRNGKey(s), (1, s, 2, hd))
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    cos, sin = rope_angles(pos, hd, 10_000.0)
    y = apply_rotary(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)),
                       min_size=1, max_size=4),
       seed=st.integers(0, 1 << 16))
def test_checkpoint_roundtrip(tmp_path_factory, shapes, seed):
    rng = np.random.default_rng(seed)
    tree = {"a": [jnp.asarray(rng.normal(size=s).astype(np.float32)) for s in shapes],
            "b": {"step": jnp.int32(seed % 97)}}
    path = str(tmp_path_factory.mktemp("ckpt") / "t.npz")
    save_checkpoint(path, tree)
    restored = restore_checkpoint(path, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
