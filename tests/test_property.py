"""Hypothesis property tests on system invariants.

The DMA strategies sample the FULL variant space: the six pre-PR-2 baseline
variants, the neighbor-ring renderings, the ``opt_`` optimized command
streams (DESIGN.md §7), chunk granularities (§8.1) and the per-chunk-signaled
pipelined rings (§9).  Invariants: latency positivity, traffic conservation,
per-link byte invariance under chunking/pipelining, monotone completion in
chunk count for non-pipelined streams, and per-chunk beating final-chunk-only
signaling for the pipelined rings.

CI runs this file un-skipped (the fast job installs ``hypothesis`` and a
guard step fails if collection comes back empty); locally the module skips
when hypothesis is unavailable.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.dma import (allgather_schedule, allreduce_schedule,
                            alltoall_schedule, chunk_sizes, kv_fetch_schedule,
                            link_traffic, mi300x_platform, reduce_scatter_schedule,
                            reduce_work, simulate, tpu_v5e_pod, variant_latency)
from repro.core.dma.claims import (pipe_vs_final_chunk_ratio,
                                   rs_pipe_vs_final_chunk_ratio)
from repro.core.dma.collectives import AR_AG_VARIANT, _pipe_granularity
from repro.data.pipeline import DataConfig, synth_batch
from repro.models.layers import apply_rotary, rope_angles
from repro.serve.kvcache import blocks_to_kv, kv_to_blocks
from repro.train.checkpoint import restore_checkpoint, save_checkpoint

KB, MB = 1024, 1024 * 1024
TOPO = mi300x_platform()
TPU = tpu_v5e_pod(16)

sizes = st.integers(min_value=1024, max_value=1 << 32)
# The full all-gather variant space: baseline, ring renderings, optimized
# command streams (DESIGN.md §7) and the pipelined rings (§9).  The ring /
# pipe variants are legal on MI300X by explicit request — the simulator
# routes them over the fully-connected fabric.
variants_ag = st.sampled_from([
    "pcpy", "bcst", "b2b", "prelaunch_pcpy", "prelaunch_bcst", "prelaunch_b2b",
    "ring", "bidir_ring",
    "opt_pcpy", "opt_bcst", "opt_b2b", "opt_prelaunch_b2b",
    "opt_ring", "opt_bidir_ring",
    "pipe_b2b", "pipe_bidir_ring", "opt_pipe_b2b", "opt_pipe_bidir_ring",
    "prelaunch_pipe_b2b", "opt_prelaunch_pipe_bidir_ring",
])
variants_aa = st.sampled_from([
    "pcpy", "swap", "b2b", "prelaunch_swap", "ring",
    "opt_pcpy", "opt_swap", "opt_b2b", "opt_ring",
    "pipe_b2b", "opt_pipe_b2b",
])
# Direct (non-forwarding) all-to-all variants: each ordered pair is served by
# exactly one command — the rotation rings forward, so they are checked via
# per-link byte invariance instead.
variants_aa_direct = st.sampled_from([
    "pcpy", "swap", "b2b", "prelaunch_swap", "opt_pcpy", "opt_swap", "opt_b2b",
])
chunk_grains = st.sampled_from([0, 256 * KB, 1 * MB, 4 * MB])
pipe_depths = st.sampled_from([1, 2, 4, 8])
# The full reduce-scatter variant space (DESIGN.md §10): the ring reduce
# family with every prelaunch_/opt_/pipe_ composition.
variants_rs = st.sampled_from([
    "ring_rs", "bidir_ring_rs", "pipe_ring_rs", "pipe_bidir_ring_rs",
    "prelaunch_ring_rs", "prelaunch_bidir_ring_rs",
    "opt_ring_rs", "opt_bidir_ring_rs",
    "opt_pipe_ring_rs", "prelaunch_pipe_bidir_ring_rs",
    "opt_prelaunch_pipe_ring_rs", "opt_prelaunch_pipe_bidir_ring_rs",
])
variants_rs_base = st.sampled_from([
    "ring_rs", "bidir_ring_rs", "pipe_ring_rs", "pipe_bidir_ring_rs"])
topologies = st.sampled_from([TOPO, TPU])


_link_traffic = link_traffic


@settings(max_examples=40, deadline=None)
@given(size=sizes, v=variants_ag)
def test_allgather_positive_finite_latency(size, v):
    r = simulate(allgather_schedule(TOPO, size, v), TOPO)
    assert 0 < r.latency < 10.0
    for b in r.per_device.values():
        assert b.control >= 0 and b.schedule >= 0 and b.copy >= 0 and b.sync >= 0


@settings(max_examples=25, deadline=None)
@given(size=st.integers(min_value=1024, max_value=1 << 28), v=variants_aa)
def test_alltoall_positive_finite_latency(size, v):
    r = simulate(alltoall_schedule(TOPO, size, v), TOPO)
    assert 0 < r.latency < 10.0


@settings(max_examples=30, deadline=None)
@given(size=st.integers(min_value=1024, max_value=1 << 28),
       v=st.sampled_from(["pcpy", "bcst", "b2b", "ring", "bidir_ring",
                          "pipe_b2b", "pipe_bidir_ring"]))
def test_prelaunch_never_slower(size, v):
    """Arming queues ahead of time (§4.5) moves control/schedule off the
    critical path — it may never pessimize, pipelined variants included."""
    base = simulate(allgather_schedule(TOPO, size, v), TOPO).latency
    pre = simulate(allgather_schedule(TOPO, size, f"prelaunch_{v}"), TOPO).latency
    assert pre <= base


@settings(max_examples=30, deadline=None)
@given(size=sizes, v=variants_ag)
def test_allgather_delivers_n_minus_one_shards(size, v):
    """Conservation: every device receives exactly n-1 shards, whatever the
    variant/route/chunking (rings forward shard-sized payloads, so inbound
    bytes per device are (n-1) * shard for every all-gather rendering)."""
    sched = allgather_schedule(TOPO, size, v)
    n = TOPO.n_devices
    shard = max(1, size // n)
    inbound = {d: 0 for d in range(n)}
    for (_, dst), nbytes in _link_traffic(sched).items():
        inbound[dst] += nbytes
    assert inbound == {d: (n - 1) * shard for d in range(n)}


@settings(max_examples=40, deadline=None)
@given(size=sizes, v=variants_aa_direct)
def test_alltoall_traffic_conserved(size, v):
    """Every ordered (src, dst) pair receives exactly one shard, any direct
    variant — stated in bytes so it holds under chunking (§8.1), which
    splits a pair's shard across many commands."""
    sched = alltoall_schedule(TOPO, size, v)
    traffic = _link_traffic(sched)
    n = TOPO.n_devices
    shard = max(1, size // n)
    assert set(traffic) == {(a, b) for a in range(n) for b in range(n) if a != b}
    assert set(traffic.values()) == {shard}


@pytest.mark.slow   # duplicates the pinned-grid byte/monotonicity coverage; the fast job keeps the claim-guarded §9/§10 cases
@settings(max_examples=30, deadline=None)
@given(size=st.integers(min_value=1 * MB, max_value=1 << 31), v=variants_ag,
       grain_a=chunk_grains, grain_b=chunk_grains)
def test_per_link_bytes_invariant_under_chunking(size, v, grain_a, grain_b):
    """Chunk granularity (and pipeline chunking, §9) never changes WHAT moves:
    per-(src, dst) byte totals are identical at any max_chunk_bytes."""
    a = _link_traffic(allgather_schedule(TOPO, size, v, max_chunk_bytes=grain_a))
    b = _link_traffic(allgather_schedule(TOPO, size, v, max_chunk_bytes=grain_b))
    assert a == b


@pytest.mark.slow   # duplicates the pinned-grid byte/monotonicity coverage; the fast job keeps the claim-guarded §9/§10 cases
@settings(max_examples=20, deadline=None)
@given(size=st.integers(min_value=1 * MB, max_value=1 << 30), v=variants_ag,
       depth_a=pipe_depths, depth_b=pipe_depths)
def test_per_link_bytes_invariant_under_pipe_depth(size, v, depth_a, depth_b):
    a = _link_traffic(allgather_schedule(TOPO, size, v, pipe_depth=depth_a))
    b = _link_traffic(allgather_schedule(TOPO, size, v, pipe_depth=depth_b))
    assert a == b


@pytest.mark.slow   # duplicates the pinned-grid byte/monotonicity coverage; the fast job keeps the claim-guarded §9/§10 cases
@settings(max_examples=15, deadline=None)
@given(size=st.sampled_from([64 * MB, 256 * MB, 1 << 30, 1 << 31]),
       v=st.sampled_from(["pcpy", "b2b", "bcst", "prelaunch_pcpy"]))
def test_completion_monotone_in_chunk_count(size, v):
    """Non-pipelined streams: finer chunks (more commands) never complete
    sooner — per-chunk packet/issue costs only add.  (Pipelined streams are
    exempt by design: chunk count trades fill latency against per-chunk
    cost, DESIGN.md §9.1; opt_ streams are exempt because the §7.2 slot
    gate flips eligibility across the chunk-size boundary.)"""
    prev = 0.0
    for grain in (0, 16 * MB, 4 * MB, 1 * MB, 256 * KB):
        lat = variant_latency(TOPO, "all_gather", size, v, grain)
        assert lat >= prev * (1 - 1e-9), grain
        prev = lat


@settings(max_examples=12, deadline=None)
@given(size=st.sampled_from([512 * KB, 1 * MB, 2 * MB, 4 * MB, 8 * MB]),
       depth=st.sampled_from([2, 4]))
def test_pipe_beats_final_chunk_only_signaling(size, depth):
    """§9 acceptance invariant on the TPU torus: at >= 2 chunks, per-chunk
    signaling strictly beats final-chunk-only signaling of the same
    pipelined schedule across the mid-size band."""
    assert pipe_vs_final_chunk_ratio(TPU, size, depth) > 1.0


@settings(max_examples=30, deadline=None)
@given(size=sizes, v=variants_rs)
def test_rs_per_link_bytes_match_allgather_rings(size, v):
    """Conservation: a reduce-scatter moves exactly what its ring moves —
    every device receives n-1 shard-sized partials, whatever the
    variant/chunking/signaling grain (DESIGN.md §10)."""
    sched = reduce_scatter_schedule(TOPO, size, v)
    n = TOPO.n_devices
    shard = max(1, size // n)
    inbound = {d: 0 for d in range(n)}
    for (_, dst), nbytes in _link_traffic(sched).items():
        inbound[dst] += nbytes
    assert inbound == {d: (n - 1) * shard for d in range(n)}


@pytest.mark.slow   # duplicates the pinned-grid byte/monotonicity coverage; the fast job keeps the claim-guarded §9/§10 cases
@settings(max_examples=25, deadline=None)
@given(size=st.integers(min_value=1 * MB, max_value=1 << 31), v=variants_rs,
       grain_a=chunk_grains, grain_b=chunk_grains,
       depth_a=pipe_depths, depth_b=pipe_depths)
def test_rs_per_link_bytes_invariant_under_chunking_and_depth(
        size, v, grain_a, grain_b, depth_a, depth_b):
    """Chunk granularity AND pipeline depth never change WHAT a
    reduce-scatter moves: per-(src, dst) byte totals are identical."""
    a = _link_traffic(reduce_scatter_schedule(
        TOPO, size, v, max_chunk_bytes=grain_a, pipe_depth=depth_a))
    b = _link_traffic(reduce_scatter_schedule(
        TOPO, size, v, max_chunk_bytes=grain_b, pipe_depth=depth_b))
    assert a == b


@settings(max_examples=30, deadline=None)
@given(size=sizes, v=variants_rs, grain=chunk_grains, depth=pipe_depths,
       topo=topologies)
def test_rs_reduction_work_conserved(size, v, grain, depth, topo):
    """Conservation of reduction work — the §10 invariant class that caught
    PR 4's bidir off-by-one: each device performs exactly
    (n-1) * shard_chunks chunk reductions totalling (n-1) * shard bytes,
    under chunking AND pipe depth AND signaling grain."""
    sched = reduce_scatter_schedule(topo, size, v, max_chunk_bytes=grain,
                                    pipe_depth=depth)
    n = topo.n_devices
    shard = max(1, size // n)
    g = _pipe_granularity(shard, depth, grain) if "pipe_" in v else grain
    shard_chunks = len(chunk_sizes(shard, g))
    assert reduce_work(sched) == \
        {d: ((n - 1) * shard_chunks, (n - 1) * shard) for d in range(n)}


@settings(max_examples=12, deadline=None)
@given(size=st.sampled_from([512 * KB, 1 * MB, 2 * MB, 4 * MB, 8 * MB]),
       depth=st.sampled_from([1, 2, 4, 8]),
       v=st.sampled_from(["pipe_ring_rs", "pipe_bidir_ring_rs"]))
def test_pipe_rs_never_slower_than_final_chunk_only(size, depth, v):
    """§10 acceptance invariant: reducing each chunk as it lands never
    loses to final-chunk-only signaling of the same schedule (strictly
    wins at >= 2 chunks — pinned in tests/test_sim.py)."""
    assert rs_pipe_vs_final_chunk_ratio(TPU, size, depth, v) >= 1.0 - 1e-9


@settings(max_examples=15, deadline=None)
@given(size=st.integers(min_value=64 * KB, max_value=1 << 28),
       v=variants_rs_base, topo=topologies)
def test_allreduce_not_slower_than_sequential_rs_then_ag(size, v, topo):
    """The composed all-reduce (armed gather chained off the terminal
    reductions, DESIGN.md §10) never loses to running reduce-scatter and
    all-gather back to back."""
    ar = simulate(allreduce_schedule(topo, size, v), topo).latency
    rs = simulate(reduce_scatter_schedule(topo, size, v), topo).latency
    ag = simulate(allgather_schedule(topo, size, AR_AG_VARIANT[v]), topo).latency
    assert ar <= (rs + ag) * (1 + 1e-9)


@settings(max_examples=25, deadline=None)
@given(n_blocks=st.integers(1, 512), block_bytes=st.integers(256, 1 << 22))
def test_kv_fetch_b2b_fewer_signals_than_pcpy(n_blocks, block_bytes):
    pcpy = kv_fetch_schedule(TOPO, n_blocks, block_bytes, "pcpy")
    b2b = kv_fetch_schedule(TOPO, n_blocks, block_bytes, "b2b")
    sig = lambda s: sum(q.n_signals for q in s.queues)
    assert sig(b2b) <= sig(pcpy)
    assert sig(pcpy) == n_blocks


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), step=st.integers(0, 1000))
def test_data_pipeline_deterministic(seed, step):
    cfg = DataConfig(vocab=1024, seq_len=64, batch=2, seed=seed)
    a = synth_batch(cfg, step)["tokens"]
    b = synth_batch(cfg, step)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(jnp.max(a)) < 1024 and int(jnp.min(a)) >= 0


@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 64), kv=st.sampled_from([1, 2, 4]),
       hd=st.sampled_from([8, 16]), layers=st.integers(1, 3),
       bt=st.sampled_from([4, 16]))
def test_kv_block_roundtrip(s, kv, hd, layers, bt):
    rng = np.random.default_rng(0)
    k = rng.normal(size=(layers, 1, s, kv, hd)).astype(np.float32)
    v = rng.normal(size=(layers, 1, s, kv, hd)).astype(np.float32)
    kb, vb = kv_to_blocks(k, v, bt)
    k2, v2 = blocks_to_kv(kb, vb, s)
    np.testing.assert_array_equal(k, k2)
    np.testing.assert_array_equal(v, v2)


@settings(max_examples=10, deadline=None)
@given(hd=st.sampled_from([16, 32, 64]), s=st.integers(2, 32))
def test_rotary_preserves_norm(hd, s):
    x = jax.random.normal(jax.random.PRNGKey(s), (1, s, 2, hd))
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    cos, sin = rope_angles(pos, hd, 10_000.0)
    y = apply_rotary(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)),
                       min_size=1, max_size=4),
       seed=st.integers(0, 1 << 16))
def test_checkpoint_roundtrip(tmp_path_factory, shapes, seed):
    rng = np.random.default_rng(seed)
    tree = {"a": [jnp.asarray(rng.normal(size=s).astype(np.float32)) for s in shapes],
            "b": {"step": jnp.int32(seed % 97)}}
    path = str(tmp_path_factory.mktemp("ckpt") / "t.npz")
    save_checkpoint(path, tree)
    restored = restore_checkpoint(path, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
