"""Per-Pallas-kernel validation: shape/dtype sweeps against the pure-jnp
oracles (interpret mode); the distributed remote-DMA kernels run in a
subprocess with 8 emulated devices."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import paged_decode_attention_ref
from repro.kernels.paged_kv_gather.ops import gather_blocks
from repro.kernels.paged_kv_gather.ref import paged_kv_gather_ref


class TestPagedKVGather:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n_pool,bt,dkv,n_blocks", [
        (32, 16, 128, 8),
        (64, 16, 256, 17),
        (8, 8, 512, 8),
        (128, 32, 128, 1),
    ])
    def test_matches_oracle(self, dtype, n_pool, bt, dkv, n_blocks):
        rng = jax.random.PRNGKey(n_pool + n_blocks)
        pool = jax.random.normal(rng, (n_pool, bt, dkv)).astype(dtype)
        tbl = jax.random.permutation(rng, n_pool)[:n_blocks].astype(jnp.int32)
        out = gather_blocks(pool, tbl, interpret=True)
        ref = paged_kv_gather_ref(pool, tbl)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_repeated_blocks(self):
        pool = jnp.arange(16 * 8 * 128, dtype=jnp.float32).reshape(16, 8, 128)
        tbl = jnp.array([3, 3, 0, 15], jnp.int32)
        out = gather_blocks(pool, tbl, interpret=True)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))
        np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(pool[15]))


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 2e-2)])
    @pytest.mark.parametrize("B,KV,G,hd,bt,mb", [
        (2, 2, 4, 128, 16, 4),
        (1, 1, 8, 128, 16, 2),
        (4, 4, 2, 256, 8, 3),
    ])
    def test_matches_oracle(self, dtype, tol, B, KV, G, hd, bt, mb):
        ks = jax.random.split(jax.random.PRNGKey(B * 31 + mb), 4)
        npool = mb * B + 2
        q = jax.random.normal(ks[0], (B, KV, G, hd)).astype(dtype)
        kp = jax.random.normal(ks[1], (npool, bt, KV, hd)).astype(dtype)
        vp = jax.random.normal(ks[2], (npool, bt, KV, hd)).astype(dtype)
        tables = jax.random.randint(ks[3], (B, mb), 0, npool)
        lengths = jnp.asarray(np.random.default_rng(0).integers(1, mb * bt, B),
                              jnp.int32)
        out = decode_attention(q, kp, vp, tables, lengths, interpret=True)
        ref = paged_decode_attention_ref(q, kp, vp, tables, lengths)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol, rtol=tol)

    def test_softcap(self):
        ks = jax.random.split(jax.random.PRNGKey(9), 4)
        q = jax.random.normal(ks[0], (2, 2, 4, 128))
        kp = jax.random.normal(ks[1], (8, 16, 2, 128))
        vp = jax.random.normal(ks[2], (8, 16, 2, 128))
        tables = jax.random.randint(ks[3], (2, 4), 0, 8)
        lengths = jnp.array([60, 33], jnp.int32)
        out = decode_attention(q, kp, vp, tables, lengths, softcap=30.0, interpret=True)
        ref = paged_decode_attention_ref(q, kp, vp, tables, lengths, softcap=30.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    def test_length_mask_excludes_tail(self):
        """Changing K/V beyond `length` must not change the output."""
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        q = jax.random.normal(ks[0], (1, 1, 4, 128))
        kp = jax.random.normal(ks[1], (4, 16, 1, 128))
        vp = jax.random.normal(ks[2], (4, 16, 1, 128))
        tables = jnp.array([[0, 1, 2, 3]], jnp.int32)
        lengths = jnp.array([20], jnp.int32)
        out1 = decode_attention(q, kp, vp, tables, lengths, interpret=True)
        kp2 = kp.at[2:].set(999.0)
        vp2 = vp.at[2:].set(-999.0)
        out2 = decode_attention(q, kp2, vp2, tables, lengths, interpret=True)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


DIST_TEST = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.kernels.ring_all_gather.ops import ring_all_gather
from repro.kernels.ring_all_gather.ref import all_gather_ref
from repro.kernels.ring_all_to_all.ops import pallas_all_to_all
from repro.kernels.ring_all_to_all.ref import all_to_all_ref

N = 8
mesh = make_mesh((N,), ("x",))
for dtype in (jnp.float32, jnp.bfloat16):
    x = jax.random.normal(jax.random.PRNGKey(0), (N * 4, 128)).astype(dtype)
    for variant in ("pcpy", "b2b", "bcst", "bcst_b2b"):
        y = ring_all_gather(x, mesh, "x", variant=variant, interpret=True)
        assert np.array_equal(np.asarray(y), np.asarray(all_gather_ref(x, N))), (variant, dtype)
    xa = jax.random.normal(jax.random.PRNGKey(1), (N, N, 2, 128)).astype(dtype)
    for variant in ("per_round", "b2b"):
        y = pallas_all_to_all(xa, mesh, "x", variant=variant, interpret=True)
        assert np.array_equal(np.asarray(y), np.asarray(all_to_all_ref(xa))), (variant, dtype)
print("DIST_OK")
"""


def _has_pallas_tpu_interpret() -> bool:
    """The remote-DMA kernels use TPU semaphores + remote async copies, which
    only run off-TPU under the pallas TPU interpret mode (pltpu.InterpretParams,
    jax >= 0.5).  The generic interpreter of older jax has no lowering for
    ``get_barrier_semaphore`` and friends on CPU."""
    from jax.experimental.pallas import tpu as pltpu
    return hasattr(pltpu, "InterpretParams")


@pytest.mark.skipif(
    not _has_pallas_tpu_interpret(),
    reason="remote-DMA Pallas kernels need real TPUs or pallas TPU interpret "
           "mode (jax >= 0.5); this jax's generic interpreter lacks TPU "
           "semaphore primitives on CPU")
def test_remote_dma_collective_kernels(subproc):
    out = subproc(DIST_TEST, n_devices=8)
    assert "DIST_OK" in out
