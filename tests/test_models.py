"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned arch runs one forward + one train step + one decode step on CPU
with correct shapes and no NaNs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.train.loop import init_train_state, make_train_step
from repro.train.optimizer import AdamWConfig

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16):
    batch = {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch = {"embeds": jax.random.normal(RNG, (B, S, cfg.d_model)),
                 "labels": jax.random.randint(RNG, (B, S), 0, cfg.vocab)}
    if cfg.family == "audio":
        batch["encoder_feats"] = jax.random.normal(
            RNG, (B, cfg.encdec.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_decode(arch_id):
    cfg = get_config(arch_id).reduced()
    assert cfg.n_layers <= max(2, len(cfg.layer_pattern or ()), 2)
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)

    logits, aux, caches = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert caches is None

    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))

    caches = model.init_caches(B, 32)
    dl, new_caches = model.decode_step(
        params, {"tokens": jnp.zeros((B, 1), jnp.int32), "pos": jnp.int32(3)}, caches)
    assert dl.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(dl, np.float32)).all()
    # caches must be structurally preserved
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch_id", ["qwen2-0.5b", "olmoe-1b-7b", "rwkv6-1.6b",
                                     "zamba2-2.7b", "whisper-tiny"])
def test_smoke_train_step(arch_id):
    cfg = get_config(arch_id).reduced()
    model = build_model(cfg)
    state = init_train_state(model, RNG)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                      total_steps=10)))
    batch = make_batch(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["opt"]["step"]) == 1


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ["deepseek-7b", "gemma2-27b", "mixtral-8x7b"])
def test_decode_matches_forward(arch_id):
    """Teacher-forcing consistency: decoding token-by-token from a prefill
    cache must reproduce the full-forward logits at each position.

    MoE archs need a no-drop capacity factor: capacity-based dispatch drops
    tokens depending on the batch's routing pressure, which legitimately
    differs between full-sequence and single-token execution."""
    import dataclasses as _dc
    cfg = get_config(arch_id).reduced()
    if cfg.moe:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full_logits, _, _ = model.forward(params, {"tokens": toks}, remat=False)

    caches = model.init_caches(B, S + 2)
    for t in range(S):
        dl, caches = model.decode_step(
            params, {"tokens": toks[:, t:t + 1], "pos": jnp.int32(t)}, caches)
        np.testing.assert_allclose(
            np.asarray(dl[:, 0], np.float32), np.asarray(full_logits[:, t], np.float32),
            rtol=0.15, atol=0.05)


def test_gemma2_softcap_applied():
    cfg = get_config("gemma2-27b").reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    logits, _, _ = model.forward(params, make_batch(cfg))
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3


def test_mixtral_sliding_window_masks_distant_tokens():
    """With window w, logits at position p must not depend on tokens < p-w.

    Needs a no-drop MoE capacity: with capacity-based dispatch, changing
    token 0 changes routing pressure and can evict OTHER tokens' expert
    slots — a legitimate global effect that would mask the attention check.
    """
    import dataclasses
    cfg = get_config("mixtral-8x7b").reduced()   # window=16
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    S = 24
    t1 = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % cfg.vocab)   # outside window of last pos
    # test the 1-layer variant (with 2 layers info propagates via hiddens)
    cfg1 = dataclasses.replace(cfg, n_layers=1)
    model1 = build_model(cfg1)
    p1 = model1.init(RNG)
    l1, _, _ = model1.forward(p1, {"tokens": t1}, remat=False)
    l2, _, _ = model1.forward(p1, {"tokens": t2}, remat=False)
    np.testing.assert_allclose(np.asarray(l1[0, -1], np.float32),
                               np.asarray(l2[0, -1], np.float32), atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, 4]), np.asarray(l2[0, 4]))


def test_qwen2vl_mrope_text_equals_rope_shape():
    cfg = get_config("qwen2-vl-72b").reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 8
    emb = jax.random.normal(RNG, (B, S, cfg.d_model))
    pos3 = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
    l1, _, _ = model.forward(params, {"embeds": emb, "positions": pos3,
                                      "labels": jnp.zeros((B, S), jnp.int32)})
    assert l1.shape == (B, S, cfg.vocab)


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ["rwkv6-1.6b", "zamba2-2.7b"])
def test_ssm_state_streaming_equivalence(arch_id):
    """Processing [first half] then [second half with carried state] must
    equal processing the full sequence (recurrence correctness)."""
    cfg = get_config(arch_id).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full, _, _ = model.forward(params, {"tokens": toks}, remat=False)
    # stream one token at a time through decode_step
    caches = model.init_caches(B, S + 2)
    outs = []
    for t in range(S):
        dl, caches = model.decode_step(
            params, {"tokens": toks[:, t:t + 1], "pos": jnp.int32(t)}, caches)
        outs.append(np.asarray(dl[:, 0], np.float32))
    stream = np.stack(outs, axis=1)
    np.testing.assert_allclose(stream, np.asarray(full, np.float32), rtol=0.15, atol=0.05)
