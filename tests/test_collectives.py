"""JAX-level latte collectives vs XLA references (8 emulated devices,
subprocess) + CommBackend dispatch behavior."""
import types
import warnings

import pytest

from repro.core import backend
from repro.core.backend import (CommBackend, StaleTablesWarning,
                                tpu_dispatch_tables)


LATTE_TEST = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core import collectives as coll
from repro.core.backend import CommBackend

N = 8
mesh = make_mesh((N,), ("x",))

x = jax.random.normal(jax.random.PRNGKey(0), (N, 4, 32), jnp.float32)
def wrap_ag(fn):
    f = shard_map(lambda a: fn(a[0], "x"), mesh=mesh, in_specs=P("x", None, None),
                  out_specs=P(None, None, None), check_vma=False)
    return np.asarray(jax.jit(f)(x))
ref = np.asarray(x)
for name, fn in (("ring", coll.ring_all_gather),
                 ("bidir", coll.bidir_ring_all_gather),
                 ("reference", coll.reference_all_gather)):
    assert np.allclose(wrap_ag(fn), ref), name

xa = jax.random.normal(jax.random.PRNGKey(1), (N, N, 2, 16), jnp.float32)
def wrap_aa(fn):
    f = shard_map(lambda a: fn(a[0], "x")[None], mesh=mesh,
                  in_specs=P("x", None, None, None),
                  out_specs=P("x", None, None, None), check_vma=False)
    return np.asarray(jax.jit(f)(xa))
expect = np.swapaxes(np.asarray(xa), 0, 1)
assert np.allclose(wrap_aa(coll.pairwise_all_to_all), expect)
assert np.allclose(wrap_aa(coll.reference_all_to_all), expect)

# reduce collectives (DESIGN.md §10): ring RS/AR vs the XLA references
xr = jax.random.normal(jax.random.PRNGKey(2), (N, N, 2, 8), jnp.float32)
expect_rs = np.asarray(xr).sum(axis=0)          # row i = device i's chunk
def wrap_rs(fn):
    f = shard_map(lambda a: fn(a[0], "x")[None], mesh=mesh,
                  in_specs=P("x", None, None, None),
                  out_specs=P("x", None, None, None), check_vma=False)
    return np.asarray(jax.jit(f)(xr))
assert np.allclose(wrap_rs(coll.ring_reduce_scatter), expect_rs, atol=1e-4)
assert np.allclose(wrap_rs(coll.reference_reduce_scatter), expect_rs, atol=1e-4)
def wrap_ar(fn):
    f = shard_map(lambda a: fn(a[0], "x"), mesh=mesh,
                  in_specs=P("x", None, None, None),
                  out_specs=P(None, None, None), check_vma=False)
    return np.asarray(jax.jit(f)(xr))
assert np.allclose(wrap_ar(coll.ring_all_reduce), expect_rs, atol=1e-4)
assert np.allclose(wrap_ar(coll.reference_all_reduce), expect_rs, atol=1e-4)

# CommBackend end-to-end inside shard_map (size-dispatched); stale-table
# acknowledgment keeps the subprocess log warning-free (test_backend covers
# the warning itself).
be = CommBackend("latte", axis_devices=N, allow_stale_tables=True)
y = np.asarray(jax.jit(shard_map(lambda a: be.all_gather(a[0], "x"),
      mesh=mesh, in_specs=P("x", None, None), out_specs=P(None, None, None),
      check_vma=False))(x))
assert np.allclose(y, ref)
z = np.asarray(jax.jit(shard_map(lambda a: be.reduce_scatter(a[0], "x")[None],
      mesh=mesh, in_specs=P("x", None, None, None),
      out_specs=P("x", None, None, None), check_vma=False))(xr))
assert np.allclose(z, expect_rs, atol=1e-4)
w = np.asarray(jax.jit(shard_map(lambda a: be.all_reduce(a[0], "x"),
      mesh=mesh, in_specs=P("x", None, None, None),
      out_specs=P(None, None, None), check_vma=False))(xr))
assert np.allclose(w, expect_rs, atol=1e-4)
print("LATTE_OK")
"""


@pytest.mark.slow
def test_latte_collectives_match_reference(subproc):
    assert "LATTE_OK" in subproc(LATTE_TEST, n_devices=8)


def test_dispatch_tables_structure():
    ag, aa, rs, ar = tpu_dispatch_tables(16)
    assert ag[0].lo == 1024 and ag[-1].hi is None
    # contiguous, non-overlapping
    for a, b in zip(ag, ag[1:]):
        assert a.hi == b.lo
    # v7 tables sweep the full single-node variant space (opt_/prelaunch_/
    # pipe_), so the latency-bound winner is an optimized prelaunched stream
    # rather than the baseline b2b of the v6 baseline-only sweep.
    assert ag[0].variant.startswith("opt_")
    # reduce tables (DESIGN.md §10) carry reduce-family winners only
    for table in (rs, ar):
        assert table[0].lo == 1024 and table[-1].hi is None
        for a, b in zip(table, table[1:]):
            assert a.hi == b.lo
        assert all(e.variant.endswith("_rs") for e in table)


class _AnyImpl(dict):
    """Stands in for the _*_IMPL maps: any winner resolves to a stub so the
    dispatch path runs outside shard_map."""

    def get(self, key, default=None):
        return lambda x, axis_name: ("dispatched", key)


def _stub_array(nbytes: int):
    return types.SimpleNamespace(size=nbytes,
                                 dtype=types.SimpleNamespace(itemsize=1))


def test_latte_dispatch_silent_on_current_tables(monkeypatch):
    """The bundled tables are re-derived with the full single-node variant
    space (v7), so the default latte backend dispatches on current winners
    without warning."""
    monkeypatch.setattr(backend, "_AG_IMPL", _AnyImpl())
    be = CommBackend("latte")
    with warnings.catch_warnings():
        warnings.simplefilter("error", StaleTablesWarning)
        out = be.all_gather(_stub_array(1 << 20), "x")
    assert out[0] == "dispatched"


def test_latte_dispatch_warns_on_stale_fingerprint(monkeypatch, tmp_path):
    """A genuinely stale bundled fingerprint must stay loud: when the
    bundled tables miss the current key the default backend re-derives on
    the fly AND warns."""
    monkeypatch.setattr(backend, "_AG_IMPL", _AnyImpl())
    be = CommBackend("latte")
    be.all_gather(_stub_array(1 << 20), "x")    # warm the table memo
    monkeypatch.setattr(backend, "_BUNDLED_TABLES", str(tmp_path / "gone.json"))
    backend._bundled_current.cache_clear()
    try:
        with pytest.warns(StaleTablesWarning, match="do not match this"):
            out = be.all_gather(_stub_array(1 << 20), "x")
        assert out[0] == "dispatched"   # still returns the table's winner
        # acknowledging silences it even on a stale fingerprint
        acked = CommBackend("latte", allow_stale_tables=True)
        with warnings.catch_warnings():
            warnings.simplefilter("error", StaleTablesWarning)
            out = acked.all_gather(_stub_array(1 << 20), "x")
        assert out[0] == "dispatched"
    finally:
        backend._bundled_current.cache_clear()


def test_reference_backend_never_consults_tables():
    ref = CommBackend("reference")
    with warnings.catch_warnings():
        warnings.simplefilter("error", StaleTablesWarning)
        ref.kv_fetch_plan(16, 16 * 1024)


def test_kv_fetch_plan_threshold():
    be = CommBackend("latte")
    small = be.kv_fetch_plan(16, 16 * 1024)
    big = be.kv_fetch_plan(1024, 64 * 1024)
    assert small == {"mode": "b2b", "fanout": 1, "optimized": True}
    assert big["fanout"] > 1
    assert big["optimized"]     # latte plans the optimized command stream
    ref = CommBackend("reference")
    ref_plan = ref.kv_fetch_plan(16, 16 * 1024)
    assert ref_plan["mode"] == "pcpy"
    assert not ref_plan["optimized"]
