"""Serving engine + host KV store: all fetch backends move identical bytes
and produce identical generations; block math; modeled-latency ordering."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine
from repro.serve.host_store import HostKVStore


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params), cfg


def test_fetch_backends_bitwise_equal():
    store = HostKVStore()
    rng = np.random.default_rng(0)
    kb = rng.normal(size=(5, 16, 2, 2, 16)).astype(np.float32)
    vb = rng.normal(size=(5, 16, 2, 2, 16)).astype(np.float32)
    store.save("k", kb, vb, 70)
    res = {b: store.fetch("k", b) for b in ("pcpy", "b2b", "opt_b2b", "kernel")}
    for b in ("b2b", "opt_b2b", "kernel"):
        np.testing.assert_array_equal(res["pcpy"].k_blocks, res[b].k_blocks)
        np.testing.assert_array_equal(res["pcpy"].v_blocks, res[b].v_blocks)
    assert res["b2b"].n_transfers < res["pcpy"].n_transfers
    assert res["b2b"].modeled_seconds < res["pcpy"].modeled_seconds
    # the optimized command stream only tightens the modeled latency
    assert res["opt_b2b"].modeled_seconds < res["b2b"].modeled_seconds


def test_engine_follows_kv_fetch_plan():
    """With no explicit fetch_backend, the engine uses the CommBackend plan:
    latte requests the optimized command stream (opt_b2b)."""
    store = HostKVStore()
    rng = np.random.default_rng(3)
    kb = rng.normal(size=(4, 16, 2, 2, 16)).astype(np.float32)
    vb = rng.normal(size=(4, 16, 2, 2, 16)).astype(np.float32)
    store.save("ctx", kb, vb, 60)
    n_blocks, block_bytes = store.blocks_for("ctx")
    assert n_blocks == 4 and block_bytes == kb[0].nbytes + vb[0].nbytes

    from repro.core.backend import CommBackend
    from repro.serve.engine import ServeEngine

    class _Probe(ServeEngine):      # plan resolution without model weights
        def __init__(self, comm, st):
            self.comm, self.store = comm, st

    assert _Probe(CommBackend("latte"), store)._planned_backend(["ctx"]) == "opt_b2b"
    assert _Probe(CommBackend("reference"), store)._planned_backend(["ctx"]) == "pcpy"


def test_generation_identical_across_backends(engine):
    eng, cfg = engine
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab, (2, 40)).astype(np.int32)
    keys = ["a", "b"]
    miss = eng.generate(prompts, keys, 6)
    assert not miss.request_stats[0].cache_hit
    for backend in ("pcpy", "b2b", "kernel"):
        hit = eng.generate(prompts, keys, 6, fetch_backend=backend)
        assert hit.request_stats[0].cache_hit
        np.testing.assert_array_equal(hit.tokens, miss.tokens)


def test_requires_decoder_family():
    cfg = get_config("rwkv6-1.6b").reduced()
    model = build_model(cfg)
    with pytest.raises(ValueError):
        ServeEngine(model, None)


def test_store_membership_and_tokens(engine):
    eng, cfg = engine
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab, (1, 24)).astype(np.int32)
    assert "ctx-z" not in eng.store
    eng.first_token(prompts, ["ctx-z"])
    assert "ctx-z" in eng.store
    assert eng.store.tokens_for("ctx-z") == 24


# ------------------------------------------------------------------------- #
# Modeled continuous-batching loop (DESIGN.md §12) at load -> 0             #
# ------------------------------------------------------------------------- #

def test_serving_simulator_unloaded_matches_fig16_exactly():
    """A lone request through the §12 batching loop reproduces the Fig. 16
    single-request TTFT bitwise: the K=1 composition is bit-identical to
    ``simulate``, and the loop adds the same batch-API/decode/framework
    terms ``serving_model.ttft`` does, in the same order."""
    from repro.core.serving_model import PAPER_LLMS, ttft
    from repro.serve.engine import ServingConfig, ServingSimulator
    from repro.serve.workload import Request

    sim = ServingSimulator(ServingConfig())
    for prompt, arrival, out in ((2048, 0.0, 1), (4096, 0.0, 1),
                                 (2048, 1.5, 4), (8192, 0.37, 8)):
        req = Request(rid=0, arrival=arrival, prompt_tokens=prompt,
                      output_tokens=out)
        got = sim.run([req]).timings[0].ttft
        want = ttft(PAPER_LLMS[2], prompt, "opt_b2b")["total"]
        assert got == want


def test_serving_simulator_unloaded_fig16_bands_still_hold():
    """Fig. 16's headline TTFT-speedup band, re-derived with the batching
    loop supplying the optimized-path numbers: loop-fed opt_b2b TTFT vs the
    closed-form pcpy baseline must still show the paper's GPU-side gain."""
    from repro.core.serving_model import PAPER_LLMS, ttft
    from repro.serve.engine import ServingConfig, ServingSimulator
    from repro.serve.workload import Request

    spec = PAPER_LLMS[0]      # smallest model: the paper's best case
    sim = ServingSimulator(ServingConfig(spec=spec))
    req = Request(rid=0, arrival=0.0, prompt_tokens=4096, output_tokens=1)
    loop_ttft = sim.run([req]).timings[0].ttft
    assert loop_ttft == ttft(spec, 4096, "opt_b2b")["total"]
    speedup = ttft(spec, 4096, "pcpy")["total"] / loop_ttft
    assert 1.2 <= speedup <= 1.7    # fig16 total-TTFT band (paper: ~1.5x)


def test_serving_admission_validation():
    from repro.serve.engine import ServingConfig, ServingSimulator

    with pytest.raises(ValueError):
        ServingSimulator(ServingConfig(admission="lifo"))
    with pytest.raises(ValueError):
        ServingSimulator().run([])
