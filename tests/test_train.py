"""Optimizer, LR schedule, data pipeline, checkpoint error handling."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, data_iterator, synth_batch
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.optimizer import AdamWConfig, adamw_update, global_norm, init_opt_state, lr_at


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200,
                      min_lr_ratio=1.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(params)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, metrics = adamw_update(cfg, params, huge, opt)
    assert float(metrics["grad_norm"]) > 1e8  # reported unclipped


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.int32(10))) - 1e-3) < 1e-9
    end = float(lr_at(cfg, jnp.int32(100)))
    assert abs(end - 1e-4) < 1e-8
    mid = float(lr_at(cfg, jnp.int32(55)))
    assert end < mid < 1e-3


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


def test_data_induction_motifs_present():
    cfg = DataConfig(vocab=512, seq_len=256, batch=16, seed=1, induction_prob=1.0)
    toks = np.asarray(synth_batch(cfg, 0)["tokens"])
    # at least one row contains a repeated 16-token motif
    motif_len = max(4, 256 // 16)
    found = 0
    for b in range(16):
        row = toks[b]
        for start in range(0, 128 - motif_len):
            pat = row[start:start + motif_len]
            for dst in range(128, 256 - motif_len):
                if np.array_equal(row[dst:dst + motif_len], pat):
                    found += 1
                    break
            else:
                continue
            break
    assert found >= 8


def test_data_iterator_advances():
    cfg = DataConfig(vocab=128, seq_len=32, batch=2, seed=0)
    it = data_iterator(cfg)
    a = np.asarray(next(it)["tokens"])
    b = np.asarray(next(it)["tokens"])
    assert not np.array_equal(a, b)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.zeros((4, 4))}
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, tree, step=3)
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"w": jnp.zeros((4, 5))})
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"w": jnp.zeros((4, 4)), "extra": jnp.zeros(1)})
