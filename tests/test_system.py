"""End-to-end behaviour tests for the system: training learns, serving with
host-cached KV matches prefill, the benchmark harness's claim set passes,
and the dry-run lowers representative (arch x shape x mesh) combos."""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.dma.claims import evaluate_claims
from repro.data.pipeline import DataConfig, data_iterator
from repro.models import build_model
from repro.train.loop import train_loop
from repro.train.optimizer import AdamWConfig


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, batch=4, seed=0)
    _, hist = train_loop(model, data_iterator(dc), steps=40,
                         opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40),
                         log_every=39)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5


def test_all_paper_claims():
    bad = [c for c in evaluate_claims() if not c.ok]
    assert not bad, [c.name for c in bad]


def test_serving_end_to_end():
    cfg = get_config("deepseek-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.serve.engine import ServeEngine
    eng = ServeEngine(model, params)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (2, 32)).astype(np.int32)
    miss = eng.generate(prompts, ["x", "y"], 5)
    hit = eng.generate(prompts, ["x", "y"], 5, fetch_backend="b2b")
    np.testing.assert_array_equal(miss.tokens, hit.tokens)
    assert hit.request_stats[0].cache_hit


DRYRUN_TEST = r"""
from repro.launch.dryrun import run_one
for arch, shape, mp in (("qwen2-0.5b", "train_4k", False),
                        ("olmoe-1b-7b", "decode_32k", True),
                        ("rwkv6-1.6b", "long_500k", False)):
    r = run_one(arch, shape, multi_pod=mp, verbose=False)
    assert r.status == "ok", (arch, shape, mp, r.reason)
    assert r.flops > 0
print("DRYRUN_OK")
"""


@pytest.mark.slow
def test_dryrun_lowers_and_compiles(subproc):
    out = subproc(DRYRUN_TEST, n_devices=512, timeout=900)
    assert "DRYRUN_OK" in out


DRYRUN_SKIP_TEST = r"""
from repro.launch.dryrun import run_one
r = run_one("deepseek-7b", "long_500k", verbose=False)
assert r.status == "skipped", r.status
r = run_one("mixtral-8x7b", "long_500k", verbose=False)
assert r.status == "ok", r.reason   # SWA qualifies for long-context decode
print("SKIP_OK")
"""


@pytest.mark.slow
def test_dryrun_long_context_policy(subproc):
    out = subproc(DRYRUN_SKIP_TEST, n_devices=512, timeout=900)
    assert "SKIP_OK" in out
