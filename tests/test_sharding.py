"""Sharding rules: divisibility guards, spec inference over every arch's
param tree, batch/cache specs."""
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.launch.input_specs import input_specs
from repro.models import build_model
from repro.sharding.rules import (ShardingRules, batch_specs, cache_specs,
                                  infer_param_specs)


class FakeMesh:
    """Duck-typed mesh exposing only .shape (axis sizes)."""

    def __init__(self, shape: dict):
        self.shape = shape


RULES = ShardingRules(mesh=FakeMesh({"data": 16, "model": 16}), dp="data")
RULES_MP = ShardingRules(mesh=FakeMesh({"pod": 2, "data": 16, "model": 16}),
                         dp=("pod", "data"))


def _axis_size(rules, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return rules.mesh.shape[axes]
    n = 1
    for a in axes:
        n *= rules.mesh.shape[a]
    return n


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.parametrize("rules", [RULES, RULES_MP], ids=["single", "multipod"])
def test_param_specs_divisible(arch_id, rules):
    """Every sharded dimension must divide the product of its mesh axes."""
    cfg = get_config(arch_id)
    model = build_model(cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = infer_param_specs(params_shape, cfg, rules)

    def check(path, leaf, spec):
        assert isinstance(spec, P)
        assert len(spec) == len(leaf.shape), (path, spec, leaf.shape)
        for dim, axes in zip(leaf.shape, spec):
            if axes is not None:
                assert dim % _axis_size(rules, axes) == 0, (path, spec, leaf.shape)

    jax.tree_util.tree_map_with_path(check, params_shape, specs)


def test_divisibility_fallback():
    """Dims that don't divide the mesh axis fall back to replicated:
    whisper's vocab (51865) is odd -> embedding must NOT be vocab-sharded,
    while qwen2's 151936-vocab embedding IS sharded."""
    for arch, embed_sharded in (("whisper-tiny", False), ("qwen2-0.5b", True)):
        cfg = get_config(arch)
        model = build_model(cfg)
        ps = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = infer_param_specs(ps, cfg, RULES)
        if embed_sharded:
            assert specs["embed"][0] == "model"
        else:
            assert specs["embed"][0] is None


def test_moe_expert_parallel_vs_tp():
    olmoe = get_config("olmoe-1b-7b")      # 64 experts % 16 == 0 -> EP
    mix = get_config("mixtral-8x7b")       # 8 experts, not divisible -> TP
    for cfg, expect_ep in ((olmoe, True), (mix, False)):
        model = build_model(cfg)
        ps = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = infer_param_specs(ps, cfg, RULES)
        wg = specs["blocks"][0]["moe"]["wg"]
        if expect_ep:
            assert wg[-3] == "model", wg
        else:
            assert wg[-3] is None and wg[-1] == "model", wg


@pytest.mark.parametrize("shape_id", ["train_4k", "decode_32k", "long_500k"])
def test_batch_and_cache_specs(shape_id):
    cfg = get_config("mixtral-8x7b")
    shape = get_shape(shape_id)
    model = build_model(cfg)
    specs = input_specs(cfg, shape, model)
    bs = batch_specs(specs["batch"], cfg, shape, RULES)
    if shape.global_batch >= 16:
        assert bs["tokens"][0] == "data"
    else:
        assert bs["tokens"][0] is None
    if specs["caches"] is not None:
        cs = cache_specs(specs["caches"], cfg, shape, RULES)
        leaves = jax.tree.leaves(cs, is_leaf=lambda x: isinstance(x, P))
        assert all(isinstance(s, P) for s in leaves)
