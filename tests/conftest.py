import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run python code in a subprocess with N emulated host devices (jax
    locks the device count at first init, so multi-device tests fork)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}")
    return r.stdout


@pytest.fixture
def subproc():
    return run_subprocess
