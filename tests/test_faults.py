"""Fault injection, watchdog/retry and degraded-mode tests (DESIGN.md §13).

The §13 acceptance invariants live here:

* **No-fault identity (§13.1)** — an empty :class:`FaultPlan` is normalized
  away by the simulator entry points, so passing one is *bit-identical* to
  no plan at all, property-tested across baseline/optimized/pipelined
  variants on both fabrics and the hierarchical multi-node renderings.
* **Determinism (§13.1)** — a fault run replays exactly from the plan's
  seed alone (blake2b draws, no process-hash or iteration-order leakage).
* **Watchdog/retry (§13.2)** — dropped doorbells are recovered by
  re-issued producers with bounded attempts; exhaustion raises a
  structured :class:`SimFault` (and the fault-free deadlock diagnosis
  carries the same structure, §13.3).
* **Validation** — malformed commands, calibrations, topologies and fault
  plans fail loudly at construction instead of mistiming silently.

CI's fast job runs this file un-skipped (hypothesis is installed there) and
a collection guard fails if the §13 test IDs vanish; locally the module
skips when hypothesis is unavailable.
"""
import dataclasses

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # local runs without hypothesis fall back to the
    HAVE_HYPOTHESIS = False  # pinned example grid below; CI installs it.

from repro.core.dma import (FaultPlan, LinkDerate, NicFlap, SimFault,
                            Straggler, allgather_schedule, allreduce_schedule,
                            commands as cmd, dispatch_robustness,
                            mi300x_platform, run_composed, simulate,
                            straggler_plan, tpu_v5e_pod)
from repro.core.dma.commands import EngineQueue, Schedule
from repro.core.dma.topology import mi300x_cluster, tpu_v5e_multislice

KB, MB = 1024, 1024 * 1024
MI = mi300x_platform()
TPU = tpu_v5e_pod(16)

#: (topology, builder, variant) arms of the no-fault identity property —
#: baseline, optimized, prelaunched, ring and pipelined renderings on both
#: single-node fabrics (the hierarchical arms run fixed-size below).
IDENTITY_ARMS = (
    (MI, allgather_schedule, "pcpy"),
    (MI, allgather_schedule, "opt_pcpy"),
    (MI, allgather_schedule, "prelaunch_bcst"),
    (TPU, allgather_schedule, "ring"),
    (TPU, allgather_schedule, "pipe_b2b"),
    (TPU, allgather_schedule, "opt_prelaunch_pipe_bidir_ring"),
    (TPU, allreduce_schedule, "pipe_bidir_ring_rs"),
)


# ------------------------------------------------------------------ §13.1 --


def _check_no_fault_identity(size, arm):
    topo, builder, variant = IDENTITY_ARMS[arm]
    sched = builder(topo, size, variant)
    clean = simulate(sched, topo)
    empty = simulate(sched, topo, faults=FaultPlan())
    assert empty == clean
    assert empty.fault_report is None


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(size=st.integers(min_value=1024, max_value=1 << 26),
           arm=st.integers(min_value=0, max_value=len(IDENTITY_ARMS) - 1))
    def test_empty_fault_plan_bit_identical(size, arm):
        _check_no_fault_identity(size, arm)
else:
    @pytest.mark.parametrize("size", [1024, 96 * KB, 1 * MB, 32 * MB])
    @pytest.mark.parametrize("arm", range(len(IDENTITY_ARMS)))
    def test_empty_fault_plan_bit_identical(size, arm):
        _check_no_fault_identity(size, arm)


@pytest.mark.parametrize("topo,variant", [
    (tpu_v5e_multislice(64), "hier_ring"),
    (tpu_v5e_multislice(64), "hier_pipe"),
    (mi300x_cluster(2), "hier_ring"),
])
def test_empty_fault_plan_bit_identical_hier(topo, variant):
    sched = allgather_schedule(topo, 8 * MB, variant)
    assert simulate(sched, topo, faults=FaultPlan()) == simulate(sched, topo)


def test_fault_runs_seed_deterministic():
    sched = allgather_schedule(TPU, 8 * MB, "pipe_b2b", pipe_depth=4)
    plan = FaultPlan(drop_rate=0.02, delay_rate=0.05, seed=3)
    a = simulate(sched, TPU, faults=plan)
    b = simulate(sched, TPU, faults=plan)
    assert a == b                      # results AND fault reports replay
    assert a.fault_report == b.fault_report
    other = simulate(sched, TPU, faults=dataclasses.replace(plan, seed=4))
    assert other.fault_report.dropped != a.fault_report.dropped


def test_draws_are_pure_functions_of_the_seed():
    tags = [("ag", d, k) for d in range(8) for k in range(8)]
    p1, p2 = FaultPlan(drop_rate=0.3, seed=1), FaultPlan(drop_rate=0.3, seed=1)
    assert ([p1.drops_signal(t, 0) for t in tags]
            == [p2.drops_signal(t, 0) for t in tags])
    p3 = FaultPlan(drop_rate=0.3, seed=2)
    assert ([p1.drops_signal(t, 0) for t in tags]
            != [p3.drops_signal(t, 0) for t in tags])


# ------------------------------------------------------------ fault kinds --


def _check_straggler_never_speeds_up(size, slowdown):
    sched = allgather_schedule(TPU, size, "ring")
    base = simulate(sched, TPU).latency
    faulted = simulate(sched, TPU, faults=straggler_plan(0, slowdown)).latency
    assert faulted >= base


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(size=st.integers(min_value=16 * KB, max_value=1 << 24),
           slowdown=st.floats(min_value=1.0, max_value=8.0))
    def test_straggler_never_speeds_up(size, slowdown):
        _check_straggler_never_speeds_up(size, slowdown)
else:
    @pytest.mark.parametrize("size,slowdown",
                             [(16 * KB, 1.0), (1 * MB, 2.5), (16 * MB, 8.0)])
    def test_straggler_never_speeds_up(size, slowdown):
        _check_straggler_never_speeds_up(size, slowdown)


def test_straggler_slowdown_is_monotone():
    sched = allgather_schedule(TPU, 8 * MB, "pipe_b2b")
    base = simulate(sched, TPU).latency
    s4 = simulate(sched, TPU, faults=straggler_plan(0, 4.0)).latency
    s8 = simulate(sched, TPU, faults=straggler_plan(0, 8.0)).latency
    assert base < s4 < s8


def test_link_derate_window_slows_transfers():
    sched = allgather_schedule(MI, 4 * MB, "pcpy")
    base = simulate(sched, MI).latency
    plan = FaultPlan(link_derates=(LinkDerate("link:1>0", 0.25),))
    assert simulate(sched, MI, faults=plan).latency > base
    # A window entirely after the run changes nothing numerically.
    late = FaultPlan(link_derates=(
        LinkDerate("link:1>0", 0.25, start=10.0, end=20.0),))
    assert simulate(sched, MI, faults=late).latency == base


def test_nic_flap_holds_cross_node_transfers():
    topo = mi300x_cluster(2)
    sched = allgather_schedule(topo, 8 * MB, "hier_ring")
    base = simulate(sched, topo).latency
    plan = FaultPlan(nic_flaps=(NicFlap(0, 0.0, base),))
    assert simulate(sched, topo, faults=plan).latency > base


def test_delayed_signals_add_latency():
    sched = allgather_schedule(TPU, 1 * MB, "pipe_b2b", pipe_depth=4)
    base = simulate(sched, TPU).latency
    plan = FaultPlan(delay_rate=1.0, delay_s=30e-6)
    r = simulate(sched, TPU, faults=plan)
    assert r.latency > base
    assert r.fault_report.delayed and not r.fault_report.dropped


# ------------------------------------------------------------------ §13.2 --


def test_dropped_signal_retries_then_recovers():
    sched = allgather_schedule(MI, 1 * MB, "ring")  # chained tagged waits
    clean = simulate(sched, MI)
    plan = FaultPlan(drop_tags=("ag",))     # every first "ag" raise is lost
    r = simulate(sched, MI, faults=plan)
    rep = r.fault_report
    assert rep.dropped and rep.retries
    # Every *waited-on* drop is recovered by exactly one retry; the ring's
    # final-step tags are raised but never waited, so they drop unretried.
    assert rep.recovered == len(rep.retries)
    assert len(rep.retries) <= len(rep.dropped)
    assert all(rec.raised and rec.attempt == 1 for rec in rep.retries)
    assert rep.retry_seconds > 0
    assert r.latency > clean.latency


def test_retry_exhaustion_raises_structured_simfault():
    sched = allgather_schedule(MI, 1 * MB, "ring")
    plan = FaultPlan(drop_rate=1.0, max_attempts=2)
    with pytest.raises(SimFault, match="deadlock") as ei:
        simulate(sched, MI, faults=plan)
    err = ei.value
    assert err.waiters                      # structured blocked-queue rows
    assert err.retries                      # watchdog history rode along
    assert all(not rec.raised for rec in err.retries)
    assert all(rec.attempt < plan.max_attempts for rec in err.retries)


def test_small_drop_rate_overhead_is_bounded():
    sched = allgather_schedule(TPU, 8 * MB, "pipe_b2b", pipe_depth=4)
    clean = simulate(sched, TPU).latency
    r = simulate(sched, TPU, faults=FaultPlan(drop_rate=0.005, seed=0))
    assert r.latency / clean < 1.6          # the fig_faults claim band
    assert r.fault_report.recovered == len(r.fault_report.dropped)


# ------------------------------------------------------------------ §13.3 --


def test_fault_free_deadlock_diagnosis_is_structured():
    # Device 0 waits on ("ag", 1, 0); device 1 raised ("ag", 1, 1) — a
    # classic off-by-one.  The diagnosis must name the nearest raised tag.
    q0 = EngineQueue(device=0, engine=0,
                     commands=(cmd.wait(("ag", 1, 0)), cmd.signal()))
    q1 = EngineQueue(device=1, engine=0,
                     commands=(cmd.signal(("ag", 1, 1)), cmd.signal()))
    sched = Schedule("deadlock_case", (q0, q1))
    with pytest.raises(SimFault, match="deadlock") as ei:
        simulate(sched, MI)
    err = ei.value
    assert len(err.waiters) == 1
    w = err.waiters[0]
    assert (w.device, w.engine, w.tag) == (0, 0, ("ag", 1, 0))
    assert w.nearest == ("ag", 1, 1)
    assert not err.retries                  # no fault plan, no retry history
    assert "parked on unsignaled tags" in str(err)


# -------------------------------------------------------------- validation --


def test_command_validation_rejects_bad_sizes():
    with pytest.raises(ValueError, match="negative size"):
        cmd.copy(0, 1, -4)
    with pytest.raises(ValueError, match="positive size"):
        cmd.copy(0, 1, 0)


def test_calibration_validation():
    with pytest.raises(ValueError):
        dataclasses.replace(MI.calib, engine_bw=0.0)
    with pytest.raises(ValueError):
        dataclasses.replace(MI.calib, control=-1e-6)
    with pytest.raises(ValueError):
        dataclasses.replace(MI.calib, dma_link_efficiency=1.5)


def test_topology_validation():
    with pytest.raises(ValueError):
        dataclasses.replace(MI, n_devices=0)
    with pytest.raises(ValueError):
        dataclasses.replace(MI, link_bw=0.0)
    with pytest.raises(ValueError):
        dataclasses.replace(MI, n_nodes=3)   # must divide n_devices (8)


def test_pipe_depth_validation():
    with pytest.raises(ValueError, match="pipe_depth"):
        allgather_schedule(TPU, 1 * MB, "pipe_b2b", pipe_depth=0)


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(drop_rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(watchdog_s=0.0)
    with pytest.raises(ValueError):
        FaultPlan(max_attempts=0)
    with pytest.raises(ValueError):
        Straggler(0, slowdown=0.5)
    with pytest.raises(ValueError):
        LinkDerate("host:0", 0.5)            # not a wire resource
    with pytest.raises(ValueError):
        LinkDerate("link:0>1", 0.0)
    with pytest.raises(ValueError):
        NicFlap(0, 2.0, 1.0)


# ------------------------------------------------------------------ §13.4 --


def test_waitable_degraded_excludes_permanent_faults():
    plan = FaultPlan(
        stragglers=(Straggler(1),),
        link_derates=(LinkDerate("hostlink:2:h2d", 0.1, 0.0, 1.0),
                      LinkDerate("link:3>0", 0.5)),        # unbounded
        nic_flaps=(NicFlap(4, 0.0, 2.0),))
    # Only transient windows are worth deferring around: the windowed
    # hostlink derate and the NIC flap, never the straggler or the
    # unbounded derate (KV homes are pinned — deferring would starve).
    assert plan.waitable_degraded(0.5) == frozenset({2, 4})
    assert plan.waitable_degraded(1.5) == frozenset({4})
    assert plan.waitable_degraded(3.0) == frozenset()
    assert plan.degraded_devices(0.5) == frozenset({1, 2, 3, 4})


def test_shifted_moves_windows_into_round_frames():
    plan = FaultPlan(link_derates=(LinkDerate("link:0>1", 0.5, 1.0, 2.0),))
    assert plan.derate_factor("link:0>1", 0.5) == 1.0
    shifted = plan.shifted(1.0)
    assert shifted.derate_factor("link:0>1", 0.5) == 0.5
    # Windowless plans pass through untouched (same object).
    windowless = straggler_plan(0)
    assert windowless.shifted(5.0) is windowless


def test_run_composed_accepts_faults():
    scheds = [allgather_schedule(MI, 1 * MB, "pcpy"),
              allgather_schedule(MI, 2 * MB, "pcpy")]
    clean = run_composed(scheds, MI)
    empty = run_composed(scheds, MI, faults=FaultPlan())
    assert empty == clean
    faulted = run_composed(scheds, MI, faults=straggler_plan(0, 4.0))
    assert faulted.makespan > clean.makespan
    assert faulted.result.fault_report is not None


def test_serving_simulator_accepts_faults():
    from repro.serve.engine import ServingConfig, ServingSimulator
    from repro.serve.workload import synthetic_workload

    reqs = synthetic_workload(12, 500.0, seed=3)
    clean = ServingSimulator(ServingConfig()).run(reqs)
    empty = ServingSimulator(ServingConfig(), faults=FaultPlan()).run(reqs)
    assert empty == clean
    slow = ServingSimulator(ServingConfig(),
                            faults=straggler_plan(0, 8.0)).run(reqs)
    assert slow.makespan > clean.makespan


# ------------------------------------------------------------------ §13.5 --


def test_dispatch_robustness_deterministic_and_detects_straggler_flip():
    sizes = [256 * KB, 512 * KB, 2 * MB]
    kw = dict(allow_optimized=True, allow_pipelined=True)
    a = dispatch_robustness(TPU, "all_gather", sizes, **kw)
    b = dispatch_robustness(TPU, "all_gather", sizes, **kw)
    assert a == b                           # fully deterministic audit
    assert a.n_points == len(sizes) * len(a.scenarios)
    assert any(f.scenario.startswith("straggler") for f in a.fragile)
    assert all(f.regret >= 1.0 for f in a.fragile)
    assert list(a.fragile) == sorted(a.fragile,
                                     key=lambda f: (f.size, f.scenario))
