#!/usr/bin/env python3
"""Docs sanity checker (CI): the documentation cross-links must not rot.

Checks, over the whole repo:

1. Every ``DESIGN.md §N`` / ``DESIGN.md §N.M`` citation in source docstrings
   and comments resolves to a real ``## §N`` / ``### §N.M`` heading.
2. README.md exists and every ``benchmarks/<x>.py`` / ``src/...`` /
   ``tests/...`` path it mentions exists on disk.
3. The markdown files README.md links to exist.

Exit code 0 when everything resolves; 1 with a line per broken reference.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SOURCE_DIRS = ("src", "benchmarks", "tests", "examples", "tools")

CITATION = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)")
REPO_PATH = re.compile(r"\b((?:src|benchmarks|tests|examples|tools)/[\w./-]+\.\w+)")
MD_LINK = re.compile(r"\]\(([\w./-]+\.md)\)")


def design_anchors(design_text: str) -> set[str]:
    return set(re.findall(r"^#{2,}\s+§(\d+(?:\.\d+)?)\b", design_text, re.M))


def check() -> list[str]:
    errors: list[str] = []

    design_path = ROOT / "DESIGN.md"
    if not design_path.exists():
        return ["DESIGN.md is missing"]
    anchors = design_anchors(design_path.read_text())

    for d in SOURCE_DIRS:
        for py in sorted((ROOT / d).rglob("*.py")):
            text = py.read_text()
            for line_no, line in enumerate(text.splitlines(), 1):
                for sec in CITATION.findall(line):
                    # A dotted citation must resolve to its exact §N.M
                    # heading; only undotted ones resolve at section level.
                    if sec not in anchors:
                        errors.append(
                            f"{py.relative_to(ROOT)}:{line_no}: cites "
                            f"DESIGN.md §{sec}, no such heading")

    readme = ROOT / "README.md"
    if not readme.exists():
        errors.append("README.md is missing")
    else:
        text = readme.read_text()
        for rel in sorted({*REPO_PATH.findall(text)}):
            if not (ROOT / rel).exists():
                errors.append(f"README.md references missing file {rel}")
        for rel in sorted({*MD_LINK.findall(text)}):
            if not (ROOT / rel).exists():
                errors.append(f"README.md links to missing doc {rel}")

    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(f"docs-sanity: {e}", file=sys.stderr)
    if not errors:
        print("docs-sanity: all DESIGN.md anchors and README references resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
