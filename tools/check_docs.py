#!/usr/bin/env python3
"""Docs sanity checker (CI): the documentation cross-links must not rot.

Checks, over the whole repo:

1. Every ``DESIGN.md §N`` / ``DESIGN.md §N.M`` citation in source docstrings
   and comments resolves to a real ``## §N`` / ``### §N.M`` heading.
2. README.md exists and every ``benchmarks/<x>.py`` / ``src/...`` /
   ``tests/...`` path it mentions exists on disk.
3. The markdown files README.md links to exist.
4. Every claim name defined in ``claims.py`` is mentioned in README.md's
   figure→benchmark→claims map (literally, or covered by a ``prefix_*``
   wildcard the map uses for claim families) — a claim band without a
   documented entry point is how reproduction results silently rot.
5. No benchmark artifact is tracked by git: perf reports belong under the
   untracked ``artifacts/`` directory (``benchmarks/sim_perf.py`` writes
   there), and a committed ``sim_perf*.json`` reads like a pinned result
   while actually being one machine's stale wall-clock numbers.

Exit code 0 when everything resolves; 1 with a line per broken reference.
"""
from __future__ import annotations

import fnmatch
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SOURCE_DIRS = ("src", "benchmarks", "tests", "examples", "tools")

CITATION = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)")
REPO_PATH = re.compile(r"\b((?:src|benchmarks|tests|examples|tools)/[\w./-]+\.\w+)")
MD_LINK = re.compile(r"\]\(([\w./-]+\.md)\)")
CLAIM_NAME = re.compile(r"Claim\(\s*\"([A-Za-z0-9_]+)\"")
README_WILDCARD = re.compile(r"`([a-z0-9_]+_\*)`")


def design_anchors(design_text: str) -> set[str]:
    return set(re.findall(r"^#{2,}\s+§(\d+(?:\.\d+)?)\b", design_text, re.M))


def check() -> list[str]:
    errors: list[str] = []

    design_path = ROOT / "DESIGN.md"
    if not design_path.exists():
        return ["DESIGN.md is missing"]
    anchors = design_anchors(design_path.read_text())

    for d in SOURCE_DIRS:
        for py in sorted((ROOT / d).rglob("*.py")):
            text = py.read_text()
            for line_no, line in enumerate(text.splitlines(), 1):
                for sec in CITATION.findall(line):
                    # A dotted citation must resolve to its exact §N.M
                    # heading; only undotted ones resolve at section level.
                    if sec not in anchors:
                        errors.append(
                            f"{py.relative_to(ROOT)}:{line_no}: cites "
                            f"DESIGN.md §{sec}, no such heading")

    readme = ROOT / "README.md"
    if not readme.exists():
        errors.append("README.md is missing")
    else:
        text = readme.read_text()
        for rel in sorted({*REPO_PATH.findall(text)}):
            if not (ROOT / rel).exists():
                errors.append(f"README.md references missing file {rel}")
        for rel in sorted({*MD_LINK.findall(text)}):
            if not (ROOT / rel).exists():
                errors.append(f"README.md links to missing doc {rel}")
        errors.extend(check_claim_coverage(text))

    errors.extend(check_no_tracked_artifacts())
    return errors


#: Tracked-path patterns that are benchmark output, not source: anything
#: matching these in ``git ls-files`` is a stale artifact that slipped in.
ARTIFACT_PATTERNS = ("artifacts/*", "sim_perf*.json", "*/sim_perf*.json")


def check_no_tracked_artifacts() -> list[str]:
    try:
        tracked = subprocess.run(
            ["git", "ls-files"], cwd=ROOT, capture_output=True, text=True,
            check=True).stdout.splitlines()
    except (OSError, subprocess.CalledProcessError):
        return []    # not a git checkout (e.g. an sdist) — nothing to guard
    return [
        f"benchmark artifact {path!r} is tracked by git — perf reports "
        "belong under the untracked artifacts/ directory"
        for path in sorted(tracked)
        if any(fnmatch.fnmatch(path, pat) for pat in ARTIFACT_PATTERNS)]


def check_claim_coverage(readme_text: str) -> list[str]:
    """Every claim name in claims.py must appear in README.md — literally
    or via a ``prefix_*`` wildcard in the figure→claims map.

    Whole-word matching only: ``fault_pipe`` is NOT covered by a mention
    of ``fault_pipe_grace`` (the substring check that let the PR-8 docs
    drift through).  Wildcards must be live — a ``prefix_*`` that matches
    no claim is a stale map row and fails too.
    """
    claims_path = ROOT / "src" / "repro" / "core" / "dma" / "claims.py"
    if not claims_path.exists():
        return ["src/repro/core/dma/claims.py is missing"]
    names = sorted(set(CLAIM_NAME.findall(claims_path.read_text())))
    wildcards = README_WILDCARD.findall(readme_text)
    mentioned = set(re.findall(r"[A-Za-z0-9_]+", readme_text))
    errors = []
    for name in names:
        if name in mentioned:
            continue
        if any(fnmatch.fnmatch(name, w) for w in wildcards):
            continue
        errors.append(
            f"claims.py defines claim {name!r} but README.md's "
            "figure→benchmark→claims map never mentions it")
    for w in sorted(set(wildcards)):
        if not any(fnmatch.fnmatch(name, w) for name in names):
            errors.append(
                f"README.md wildcard `{w}` matches no claim in claims.py "
                "— stale figure-map row")
    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(f"docs-sanity: {e}", file=sys.stderr)
    if not errors:
        print("docs-sanity: all DESIGN.md anchors and README references resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
