"""Train a small LM end-to-end on CPU: a few hundred steps on the synthetic
pipeline, loss must drop, checkpoint round-trips.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import os
import tempfile

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig, data_iterator
from repro.models import build_model
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.loop import train_loop
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    dc = DataConfig(vocab=cfg.vocab, seq_len=128, batch=8, seed=0)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)

    def log(i, m):
        print(f"step {m['step']:4d} loss {m['loss']:.4f} lr {m['lr']:.2e}")

    state, hist = train_loop(model, data_iterator(dc), steps=args.steps,
                             opt_cfg=opt, callback=log, log_every=25)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, "insufficient learning"
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, state, step=args.steps)
        restored = restore_checkpoint(path, state)
        leaves_a = jax.tree.leaves(state)
        leaves_b = jax.tree.leaves(restored)
        assert all((a == b).all() for a, b in zip(leaves_a, leaves_b))
        print("checkpoint round-trip OK")


if __name__ == "__main__":
    main()
