"""Run the Pallas remote-DMA collective kernels (ring all-gather with
pcpy/b2b/bcst sync variants; swap/b2b all-to-all) on 8 emulated devices in
interpret mode and validate against the pure-jnp oracles.

Re-executes itself with XLA_FLAGS=--xla_force_host_platform_device_count=8
if needed (jax locks the device count at first init).

    PYTHONPATH=src python examples/pallas_collectives.py
"""
import os
import subprocess
import sys

N = 8

if os.environ.get("_REPRO_PALLAS_CHILD") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N}"
    env["_REPRO_PALLAS_CHILD"] = "1"
    raise SystemExit(subprocess.call([sys.executable, os.path.abspath(__file__)], env=env))

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from jax.experimental.pallas import tpu as _pltpu   # noqa: E402
if not hasattr(_pltpu, "InterpretParams"):
    raise SystemExit(
        "these remote-DMA kernels need real TPUs or the pallas TPU interpret "
        "mode (jax >= 0.5); this jax's generic interpreter has no CPU "
        "lowering for TPU semaphore primitives")

from repro.compat import make_mesh                                 # noqa: E402
from repro.kernels.ring_all_gather.ops import ring_all_gather      # noqa: E402
from repro.kernels.ring_all_gather.ref import all_gather_ref       # noqa: E402
from repro.kernels.ring_all_to_all.ops import pallas_all_to_all    # noqa: E402
from repro.kernels.ring_all_to_all.ref import all_to_all_ref       # noqa: E402


def main():
    assert len(jax.devices()) == N
    mesh = make_mesh((N,), ("x",))
    x = jax.random.normal(jax.random.PRNGKey(0), (N * 8, 128), jnp.float32)
    print("== Pallas ring all-gather (remote DMA) ==")
    for variant in ("pcpy", "b2b", "bcst", "bcst_b2b"):
        y = ring_all_gather(x, mesh, "x", variant=variant, interpret=True)
        ok = np.allclose(np.asarray(y), np.asarray(all_gather_ref(x, N)))
        print(f"  {variant:9s}: {'OK' if ok else 'MISMATCH'}")
        assert ok

    xa = jax.random.normal(jax.random.PRNGKey(1), (N, N, 4, 128), jnp.float32)
    print("== Pallas all-to-all (swap / b2b) ==")
    for variant in ("per_round", "b2b"):
        y = pallas_all_to_all(xa, mesh, "x", variant=variant, interpret=True)
        ok = np.allclose(np.asarray(y), np.asarray(all_to_all_ref(xa)))
        print(f"  {variant:9s}: {'OK' if ok else 'MISMATCH'}")
        assert ok
    print("all kernel variants validated against oracles")


if __name__ == "__main__":
    main()
