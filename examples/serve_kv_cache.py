"""End-to-end driver (the paper's workload): batched LLM serving with
host-memory context caching, comparing KV-fetch backends (pcpy / b2b /
kernel) on TTFT and throughput — §5.3 at reduced scale, real execution.

    PYTHONPATH=src python examples/serve_kv_cache.py
"""
import numpy as np

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params)
    rng = np.random.default_rng(0)

    B, CTX, NEW = 4, 192, 24
    prompts = rng.integers(0, cfg.vocab, (B, CTX)).astype(np.int32)
    keys = [f"doc-{i}" for i in range(B)]

    print(f"model={cfg.name} batch={B} ctx={CTX} new={NEW}")
    miss = eng.generate(prompts, keys, NEW)                 # prefill + save
    print(f"miss : ttft={miss.request_stats[0].ttft_wall_s*1e3:7.2f}ms (prefill) "
          f"tok/s={miss.tokens_per_s_wall:7.1f}")
    rows = []
    for backend in ("pcpy", "b2b", "opt_b2b", "kernel"):
        res = eng.generate(prompts, keys, NEW, fetch_backend=backend)
        st = res.request_stats[0]
        assert (res.tokens == miss.tokens).all(), backend
        rows.append((backend, st.fetch_modeled_s, st.n_transfers))
        print(f"hit/{backend:6s}: fetch_modeled={st.fetch_modeled_s*1e6:8.1f}us "
              f"transfers={st.n_transfers:3d} tok/s={res.tokens_per_s_wall:7.1f} "
              f"(tokens identical)")
    pcpy = dict((r[0], r[1]) for r in rows)
    print(f"\nb2b fetch speedup over pcpy (modeled): {pcpy['pcpy']/pcpy['b2b']:.2f}x")


if __name__ == "__main__":
    main()
