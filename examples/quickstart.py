"""Quickstart: the paper's DMA collective model + dispatch in 60 seconds.

Runs the calibrated MI300X engine model over the size spectrum, shows the
phase breakdown of a single DMA copy (Fig. 7), the best-variant dispatch
(Tables 2/3), and validates a latte collective against the XLA reference on
the local device mesh.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import make_mesh, shard_map
from jax.sharding import PartitionSpec as P

from repro.core.dma import (
    allgather_schedule, alltoall_schedule, mi300x_platform, paper_dispatch,
    rccl_aa_calibration, rccl_ag_calibration, simulate, single_copy_breakdown,
)
from repro.core.dma.rccl_model import rccl_collective_latency
from repro.core import collectives as coll

KB, MB = 1024, 1024 * 1024


def main():
    topo = mi300x_platform()

    print("== Fig.7: phases of a single DMA copy ==")
    for size in (4 * KB, 64 * KB, 1 * MB, 2 * MB):
        b = single_copy_breakdown(size, topo)
        print(f"  {size >> 10:5d}KB total={b.total*1e6:6.1f}us "
              f"copy={b.copy*1e6:5.1f}us non-copy={b.noncopy_fraction:5.1%}")

    print("\n== DMA all-gather vs RCCL across sizes (paper Fig. 13) ==")
    for size in (4 * KB, 256 * KB, 4 * MB, 256 * MB):
        variant = paper_dispatch("all_gather", size)
        dma = simulate(allgather_schedule(topo, size, variant), topo).latency
        rccl = rccl_collective_latency(topo, size, rccl_ag_calibration())
        print(f"  {size >> 10:7d}KB best={variant:15s} dma={dma*1e6:9.1f}us "
              f"rccl={rccl*1e6:9.1f}us speedup={rccl/dma:5.2f}x")

    print("\n== latte collective == reference on the local mesh ==")
    n = len(jax.devices())
    mesh = make_mesh((n,), ("x",))
    x = jax.random.normal(jax.random.PRNGKey(0), (n * 4, 32), jnp.float32)
    ring = jax.jit(shard_map(lambda a: coll.ring_all_gather(a, "x").reshape(-1, a.shape[-1]),
                             mesh=mesh, in_specs=P("x", None),
                             out_specs=P(None, None), check_vma=False))
    ok = np.allclose(np.asarray(ring(x)), np.asarray(x))
    print(f"  ring all-gather matches reference: {ok}")
    assert ok


if __name__ == "__main__":
    main()
